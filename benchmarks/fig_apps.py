"""Application traffic plane — end-to-end train-step time and serving
QPS/tail-latency per transport, on BOTH engines (the ROADMAP item-1
headline; the paper's §5 figures compare raw collectives, this one
compares what they add up to for an LM).

Three scenarios per (model config x transport), sized from the smoke
``ArchConfig``s via ``apps.collectives_lowering`` (collective bytes
are pure config math — see ``tests/test_apps.py`` for the anchors):

- **train** — one training step on a ``data=4 x model=2`` mesh
  (tp-allreduce + MoE all-to-all fan-mesh where applicable +
  dp-gradsync), executed phase by phase (``apps.metrics.run_phased``)
  with step time = sum of phase maxima;
- **serve** — the open-loop generator (``apps.traffic``): seeded
  Poisson arrivals onto 4 TP-2 replicas, prefill/decode collectives +
  2-copy KV replication per request, reported as offered vs achieved
  QPS with p50/p99/p999 request latency (mean over ``--seeds``
  arrival seeds);
- **scale-out** — the replica weight broadcast (bf16 shards to every
  replica), the pure one-to-many op where the transport gap is
  widest.

Every point runs on the packet engine AND the flow engine; the derived
column carries the packet-vs-flow divergence (gate: <= 10%,
``tools/check_apps.py``).  Packet batches are ``--workers`` aware.
"""
from __future__ import annotations

from repro.apps.collectives_lowering import (MeshShape,
                                             train_step_workload,
                                             weight_bcast_workload)
from repro.apps.metrics import jct, split_phases, step_time
from repro.apps.traffic import ArrivalSpec, ServingGenerator
from repro.configs.base import get_config
from repro.core import fattree
from repro.core.engine import make_engine

CONFIGS = ("mixtral_8x7b", "llama3_2_3b")
TRANSPORTS = ("gleam", "multiunicast", "ring", "binary-tree")

TRAIN_MESH = MeshShape(data=4, model=2)
TRAIN_SEQ, TRAIN_BATCH = 256, 32

N_REPLICAS, TP = 4, 2
PROMPT_LEN, DECODE_LEN, KV_REPLICAS = 128, 16, 2
SERVE_RATE, SERVE_N = 2e4, 32


def _train_sweep(engine_name, cfg, workers, timeout=180.0):
    """All transports' train steps as ONE phase-split batch; returns
    {transport: step_seconds}."""
    eng = make_engine(engine_name, fattree.testbed(
        n_hosts=TRAIN_MESH.n_chips))
    groups = []
    for tr in TRANSPORTS:
        wl = train_step_workload(cfg, TRAIN_MESH, seq=TRAIN_SEQ,
                                 batch=TRAIN_BATCH, transport=tr)
        groups.append((tr, split_phases(wl)))
    flat = [p for _, ps in groups for p in ps]
    results = iter(eng.run_workloads(flat, timeout=timeout,
                                     workers=workers))
    out = {}
    for tr, ps in groups:
        ops, recs = [], []
        for p in ps:
            ops.extend(p.ops)
            recs.extend(next(results))
        out[tr] = step_time(ops, recs)
    return out


def _serve_sweep(engine_name, cfg, workers, seeds, timeout=180.0):
    """Mean serving report per transport over ``seeds`` arrival seeds;
    returns {transport: dict(qps, p50, p99, p999)}."""
    out = {}
    for tr in TRANSPORTS:
        gen = ServingGenerator(cfg, N_REPLICAS, TP,
                               prompt_len=PROMPT_LEN,
                               decode_len=DECODE_LEN,
                               kv_replicas=KV_REPLICAS, transport=tr)
        acc = {"qps": 0.0, "p50": 0.0, "p99": 0.0, "p999": 0.0}
        for seed in range(seeds):
            eng = make_engine(engine_name, fattree.testbed(
                n_hosts=N_REPLICAS * TP))
            rep = gen.run(eng, ArrivalSpec(rate=SERVE_RATE, n=SERVE_N,
                                           seed=seed),
                          timeout=timeout, workers=workers)
            acc["qps"] += rep.achieved_qps / seeds
            for q in ("p50", "p99", "p999"):
                acc[q] += rep.quantiles[q] / seeds
        out[tr] = acc
    return out


def _scaleout_sweep(engine_name, cfg, workers, timeout=180.0):
    """Replica weight-bcast time per transport (one batch)."""
    eng = make_engine(engine_name, fattree.testbed(
        n_hosts=N_REPLICAS * TP))
    wls = [weight_bcast_workload(cfg, N_REPLICAS, TP, transport=tr)
           for tr in TRANSPORTS]
    results = eng.run_workloads(wls, timeout=timeout, workers=workers)
    return {tr: max(jct(r) for r in recs)
            for tr, recs in zip(TRANSPORTS, results)}


def run(rows, engine="packet", workers=0, seeds=2, configs=CONFIGS):
    # both engines always run — the packet-vs-flow divergence IS the
    # result; --engine only picks which flow solver to compare against
    flow_engine = engine if engine.startswith("flow") else "flow"
    for name in configs:
        cfg = get_config(name, smoke=True)

        tp_ = _train_sweep("packet", cfg, workers)
        tf_ = _train_sweep(flow_engine, cfg, None)
        for tr in TRANSPORTS:
            div = abs(tp_[tr] - tf_[tr]) / tp_[tr]
            rows.append((f"figapps/train_{name}_{tr}/packet_ms",
                         tp_[tr] * 1e3,
                         f"flow={tf_[tr] * 1e3:.4f}ms "
                         f"div={100 * div:.1f}% (mesh dp4xtp2 "
                         f"seq={TRAIN_SEQ} batch={TRAIN_BATCH})"))

        sp = _serve_sweep("packet", cfg, workers, seeds)
        sf = _serve_sweep(flow_engine, cfg, None, seeds)
        for tr in TRANSPORTS:
            div = abs(sp[tr]["qps"] - sf[tr]["qps"]) / sp[tr]["qps"]
            rows.append((
                f"figapps/serve_{name}_{tr}/packet_qps",
                sp[tr]["qps"],
                f"offered={SERVE_RATE:.0f}/s "
                f"p50={sp[tr]['p50'] * 1e6:.1f}us "
                f"p99={sp[tr]['p99'] * 1e6:.1f}us "
                f"p999={sp[tr]['p999'] * 1e6:.1f}us "
                f"flow_qps={sf[tr]['qps']:.0f} div={100 * div:.1f}% "
                f"(seeds={seeds})"))

        wp = _scaleout_sweep("packet", cfg, workers)
        wf = _scaleout_sweep(flow_engine, cfg, None)
        for tr in TRANSPORTS:
            div = abs(wp[tr] - wf[tr]) / wp[tr]
            rows.append((f"figapps/scaleout_{name}_{tr}/packet_ms",
                         wp[tr] * 1e3,
                         f"flow={wf[tr] * 1e3:.4f}ms "
                         f"div={100 * div:.1f}% "
                         f"({N_REPLICAS} replicas x tp{TP})"))
    return rows
