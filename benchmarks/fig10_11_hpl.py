"""Figs. 10-11 — HPL JCT: Panel Broadcast (PB) and Row Swap (RS), Gleam
vs the original HPL algorithms (`increasing-ring` for PB, `long` for RS).

Paper claims (communication-only): PB -67%, RS(uniform) -18%,
RS(centralized) -46%.  With computation included: -12% / -4.67% / -9.55%.

Model: 4-node testbed; per-epoch panel volume decays linearly (§2.2).
- PB: one-to-all bcast, source rotates per epoch (Appendix B).  The
  HPL baseline is the same op over ``transport="ring"`` with chunks=1
  (store-and-forward per hop) — one Workload IR declaration, two
  transports.
- RS: the `long` algorithm is a spread+exchange (bandwidth-optimal when
  data is uniform, degraded when centralized); with Gleam the owner
  multicasts its rows — volume independent of distribution.
- Computation time is modeled per-epoch as compute-bound DGEMM time
  8x the uniform communication epoch (HPL is compute-dominated; the
  constant only scales the combined-JCT rows, not the comm-only rows).

Each epoch is one Workload (an independent scenario: epochs run
back-to-back, not concurrently), so the whole PB schedule is a single
``run_workloads`` call per transport.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import Workload

MEMBERS = ["h0", "h1", "h2", "h3"]
EPOCHS = 8
FIRST_BYTES = 16 << 20


def _epoch_bytes(e):
    return max(int(FIRST_BYTES * (1 - e / EPOCHS)), 1 << 12)


def _pb_total(transport: str, engine: str) -> float:
    """Panel broadcast: source rotates per epoch (Appendix B).  Gleam
    rotates on ONE registered group; the ring overlay relays in the
    rotated member order with store-and-forward hops (chunks=1)."""
    eng = make_engine(engine, fattree.testbed())
    workloads = []
    for e in range(EPOCHS):
        wl = Workload(f"fig11/pb_epoch{e}/{transport}")
        if transport == "gleam":
            # ONE registered group; Appendix-B source switching rotates
            wl.bcast(MEMBERS, _epoch_bytes(e),
                     source=MEMBERS[e % len(MEMBERS)])
        else:
            # overlay relays in the HPL rotation order
            order = MEMBERS[e % 4:] + MEMBERS[:e % 4]
            wl.bcast(order, _epoch_bytes(e), transport=transport, chunks=1)
        workloads.append(wl)
    recss = eng.run_workloads(workloads, timeout=60.0)
    return sum(recs[0].jct(len(MEMBERS) - 1) for recs in recss)


def pb_gleam(engine="packet"):
    return _pb_total("gleam", engine)


def pb_ring(engine="packet"):
    return _pb_total("ring", engine)


def rs_gleam(distribution, engine="packet"):
    """Row swap: every column node multicasts its rows to the column.
    Gleam JCT is distribution-independent: the owner sends once."""
    eng = make_engine(engine, fattree.testbed())
    workloads = []
    for e in range(EPOCHS):
        wl = Workload(f"fig11/rs_epoch{e}")
        wl.bcast(MEMBERS, _epoch_bytes(e))
        workloads.append(wl)
    recss = eng.run_workloads(workloads, timeout=60.0)
    return sum(recs[0].jct(len(MEMBERS) - 1) for recs in recss)


def rs_long(distribution):
    """`long` algorithm: spread (scatter) + allgather exchange.  Uniform
    data: each node ships ~1/n of the volume in the spread phase.
    Centralized: one node owns everything — the spread phase ships the
    full volume through one link before the exchange can start."""
    net_bw = 100 * fattree.GBPS
    total = 0.0
    for e in range(EPOCHS):
        nbytes = _epoch_bytes(e)
        n = len(MEMBERS)
        if distribution == "uniform":
            spread = (nbytes / n) * (n - 1) / net_bw
        else:                      # centralized: full volume from one node
            spread = nbytes * (n - 1) / n / net_bw * 2.2
        exchange = nbytes * (n - 1) / n / net_bw
        hop_overhead = 1.5e-6 * n
        total += spread + exchange + hop_overhead
    return total


def run(rows, engine="packet"):
    pb_g, pb_r = pb_gleam(engine), pb_ring(engine)
    rows.append(("fig11/pb_comm/gleam_ms", pb_g * 1e3, ""))
    rows.append(("fig11/pb_comm/ring_ms", pb_r * 1e3,
                 f"reduction={100 * (1 - pb_g / pb_r):.0f}% (paper 67%)"))
    for dist, paper in (("uniform", 18), ("centralized", 46)):
        rg, rl = rs_gleam(dist, engine), rs_long(dist)
        rows.append((f"fig11/rs_{dist}/gleam_ms", rg * 1e3, ""))
        rows.append((f"fig11/rs_{dist}/long_ms", rl * 1e3,
                     f"reduction={100 * (1 - rg / rl):.0f}% "
                     f"(paper {paper}%)"))
    # combined JCT (computation included): compute ~ 8x uniform comm epoch
    compute = 8 * (pb_g / EPOCHS) * EPOCHS
    rows.append(("fig10/pb_total/gleam_ms", (compute + pb_g) * 1e3, ""))
    rows.append(("fig10/pb_total/ring_ms", (compute + pb_r) * 1e3,
                 f"reduction="
                 f"{100 * (1 - (compute + pb_g) / (compute + pb_r)):.1f}% "
                 f"(paper 12%)"))
    return rows
