"""Membership churn — JCT and failure-recovery time vs churn rate and
group size, on BOTH engines (the headline for the §3.4 membership
control plane; no counterpart figure in the paper, which evaluates a
static world).

Scenario: one 1MB Gleam bcast per point, with timed membership events
riding the op (Workload-IR ``MemberEvent``s):

- the **churn axis** alternates graceful ``leave``s and ``join``s at
  interval ``1/rate`` — at low rates the events land after the message
  completes (churn is invisible to JCT, as it should be), at high rates
  the tree is rebuilt mid-stream;
- the **recovery axis** crashes one receiver (``fail``) mid-stream: the
  dead port freezes the aggregated-ACK minimum, the sender wedges once
  its go-back-N window drains, and the master's isolation envelope
  (+``fail_detect``) un-wedges it.  Recovery time is reported as the
  JCT penalty over the same point without the failure.

Every point runs on the packet engine (per-packet control plane: real
MFT-update envelopes, QP re-arm, isolation) AND the flow engine
(piecewise-membership segments), and the derived column carries the
packet-vs-flow divergence — the acceptance gate is <= 10%.  Packet
points of one group size run as a single ``run_many`` batch
(``--workers`` aware).
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import GroupOp, MemberEvent

NBYTES = 1 << 20
SIZES = (16, 64)
CHURN_RATES = (0.0, 2e3, 1e4, 5e4)      # membership events / second
N_EVENTS = 4                            # alternating leave / join
FAIL_AT = 30e-6                         # crash 30us into the stream
SPARES = N_EVENTS                       # joinable hosts beyond the group


def churn_events(group: int, rate: float):
    """Alternating leave/join schedule at interval ``1/rate``: members
    leave from the tail, spare hosts h{group}.. join in their stead."""
    if rate <= 0:
        return ()
    dt = 1.0 / rate
    evs = []
    for i in range(N_EVENTS):
        at = (i + 1) * dt
        if i % 2 == 0:
            evs.append(MemberEvent("leave", f"h{group - 1 - i // 2}", at))
        else:
            evs.append(MemberEvent("join", f"h{group + i // 2}", at))
    return tuple(evs)


def _points(group):
    members = [f"h{i}" for i in range(group)]
    pts = [(f"r{rate:g}", GroupOp("bcast", members, NBYTES,
                                  events=churn_events(group, rate)))
           for rate in CHURN_RATES]
    pts.append(("fail", GroupOp(
        "bcast", members, NBYTES,
        events=(MemberEvent("fail", f"h{group - 1}", FAIL_AT),))))
    return pts


def _sweep(engine_name, group, workers, timeout=120.0):
    """All of one group size's points as one independent-scenario batch;
    returns {label: jct_seconds}."""
    topo = fattree.testbed(n_hosts=group + SPARES)
    eng = make_engine(engine_name, topo)
    pts = _points(group)
    recs = []

    def scenario(op):
        def fn(e):
            recs.append(e.stage(op))
        return fn

    eng.run_many([scenario(op) for _, op in pts], timeout=timeout,
                 workers=workers)
    return {label: rec.jct(len(op.surviving_receivers()))
            for (label, op), rec in zip(pts, recs)}


def run(rows, engine="packet", workers=0, sizes=SIZES):
    # both engines always run — the packet-vs-flow divergence IS the
    # result; --engine only picks which flow solver to compare against
    flow_engine = engine if engine.startswith("flow") else "flow"
    for group in sizes:
        jct_p = _sweep("packet", group, workers)
        jct_f = _sweep(flow_engine, group, None)
        for rate in CHURN_RATES:
            label = f"r{rate:g}"
            jp, jf = jct_p[label], jct_f[label]
            div = abs(jp - jf) / jp if jp > 0 else 0.0
            rows.append((f"figchurn/jct_g{group}_{label}/packet_ms",
                         jp * 1e3,
                         f"events={len(churn_events(group, rate))} "
                         f"flow={jf * 1e3:.4f}ms div={100 * div:.1f}%"))
        # recovery: the fail point's JCT penalty over the static point
        rp = jct_p["fail"] - jct_p["r0"]
        rf = jct_f["fail"] - jct_f["r0"]
        div = abs(jct_p["fail"] - jct_f["fail"]) / jct_p["fail"]
        rows.append((f"figchurn/recovery_g{group}/packet_ms", rp * 1e3,
                     f"flow={rf * 1e3:.4f}ms div={100 * div:.1f}% "
                     f"(fail@{FAIL_AT * 1e6:.0f}us, detect=1ms)"))
    return rows
