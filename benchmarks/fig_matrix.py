"""Matrix sweep — churn x loss x faults cross-product at Fig. 14 scale
(the headline for the ISSUE-10 batched dynamic-segment solver; no
counterpart figure in the paper, which evaluates each axis alone).

Every cell of the grid stages ``N_GROUPS`` contending bcasts on ONE
fabric with all three planes riding the same ops:

- **churn** — alternating ``leave``/``join`` ``MemberEvent``s at
  interval ``1/rate`` (tail members leave, per-group spares join);
- **loss** — the engine-level calibrated loss/DCQCN model
  (``loss_rate=``), folded into the SAME per-segment solves by the
  batched solver (churn-under-loss is native, not a post-hoc scale);
- **faults** — ``link_flap`` ``FaultEvent``s on member racks' plane-0
  uplinks (plane 1 keeps every member routable).

The full grid runs the flow engine on a 4096-host 3-layer fat-tree —
8 groups x 32 members per cell, every dynamic op cut into piecewise
segments.  Before the batched solver each segment cost one serial
``static_maxmin`` call from inside the staging loop; now per-scenario
timelines are bucketed by padded shape and solved device-resident in a
handful of vmapped calls (see docs/ARCHITECTURE.md "Dynamic-segment
solver"), which is what makes this cross-product tractable.

A small-scale twin of the same grid (16-host, 2 agg planes) runs on
BOTH engines and reports the packet-vs-flow JCT divergence per cell —
the acceptance gate is <= 15% (tools/check_matrix.py).

Standalone:

    PYTHONPATH=src python benchmarks/fig_matrix.py --engine flow
    PYTHONPATH=src python benchmarks/fig_matrix.py --smoke
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):      # `python benchmarks/fig_matrix.py`
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import FaultEvent, GroupOp, MemberEvent

NBYTES = 1 << 20
CHURN_RATES = (0.0, 5e4)                # membership events / second
LOSS_RATES = (0.0, 1e-3)               # per-packet loss probability
FLAPS = (0, 2)                         # link flaps riding each op
N_EVENTS = 4                           # alternating leave / join
SPARES = N_EVENTS                      # joinable hosts per group
N_GROUPS, GROUP = 8, 32                # full-scale cell shape
FAULT_AT = 3e-6
FAULT_GAP = 5e-6
FLAP_DURATION = 20e-6
NBYTES_SMALL = 1 << 19                 # packet-vs-flow parity twin
N_GROUPS_SMALL, GROUP_SMALL = 2, 4


def build_topo(smoke: bool = False):
    if smoke:
        # fig_faults' 16-host twin: 2 agg planes keep every leaf a
        # surviving uplink under any single flap
        return fattree.fat_tree(n_pods=2, leaves_per_pod=2,
                                hosts_per_leaf=4, aggs_per_pod=2)
    # Fig. 14's size class: 16 pods x 16 leaves x 16 hosts = 4096
    return fattree.fat_tree(n_pods=16, leaves_per_pod=16,
                            hosts_per_leaf=16, aggs_per_pod=4)


def _leaf_agg(host: str):
    """(leaf, plane-0 agg) of ``h{pod}.{leaf}.{idx}``."""
    pod, leaf, _ = host[1:].split(".")
    return f"L{pod}.{leaf}", f"A{pod}.0"


def cell_ops(hosts, n_groups, group, churn_rate, n_flaps,
             nbytes=NBYTES, spares=SPARES):
    """One matrix cell: ``n_groups`` contending bcasts over disjoint
    host blocks, each op carrying its cell's churn schedule and link
    flaps.  Also the workload builder for ``tools/bench.py``'s
    ``dyn_segments`` point (64 ops x 5 segments on a 1024-host tree)."""
    stride = group + spares
    assert n_groups * stride <= len(hosts), (n_groups, stride, len(hosts))
    ops = []
    for g in range(n_groups):
        block = hosts[g * stride:(g + 1) * stride]
        members, spare = block[:group], block[group:]
        events = []
        if churn_rate > 0:
            dt = 1.0 / churn_rate
            for i in range(N_EVENTS):
                if i % 2 == 0:
                    events.append(MemberEvent(
                        "leave", members[-1 - i // 2], (i + 1) * dt))
                else:
                    events.append(MemberEvent(
                        "join", spare[i // 2], (i + 1) * dt))
        leaves = []
        for m in members[1:]:           # distinct member racks
            la = _leaf_agg(m)
            if la not in leaves:
                leaves.append(la)
        faults = tuple(
            FaultEvent("link_flap", FAULT_AT + i * FAULT_GAP,
                       node=leaves[i % len(leaves)][0],
                       peer=leaves[i % len(leaves)][1],
                       duration=FLAP_DURATION)
            for i in range(n_flaps))
        ops.append(GroupOp("bcast", members, nbytes,
                           events=tuple(events), faults=faults))
    return ops


def _cells():
    return [(churn, flaps) for churn in CHURN_RATES for flaps in FLAPS]


def sweep_grid(engine_name, topo, n_groups, group, nbytes,
               workers=None, timeout=120.0, seeds=1, engine_kw=None):
    """The full (churn x flaps) grid for each loss level, one
    ``run_many`` batch per engine pass; {(churn, loss, flaps): jct}.

    Lossy packet points average ``seeds`` independent repetitions —
    the packet engine SAMPLES drops and RTO stalls while the flow
    model charges their expectation, so a single draw can sit a whole
    stall tail away from the mean (the fig15 convention)."""
    out = {}
    cells = _cells()
    for loss in LOSS_RATES:
        reps = seeds if (loss and engine_name == "packet") else 1
        kw = {"loss_rate": loss} if loss else {}
        kw.update(engine_kw or {})
        eng = make_engine(engine_name, topo, **kw)
        all_ops = [cell_ops(topo.hosts, n_groups, group, churn, flaps,
                            nbytes=nbytes)
                   for churn, flaps in cells]
        recss = []

        def scenario(ops):
            return lambda e: recss.append([e.stage(op) for op in ops])

        run_kw = {"workers": workers} if workers is not None else {}
        eng.run_many([scenario(ops) for ops in all_ops] * reps,
                     timeout=timeout, **run_kw)
        for i, (cell, ops) in enumerate(zip(cells, all_ops)):
            # cell metric: MEAN over the cell's group JCTs — linear in
            # the per-op values, so the sampled packet mean and the
            # flow engine's expected values are directly comparable
            # (max-over-groups would bias the sampled side up:
            # E[max] > max(E))
            js = [sum(rec.jct(len(op.surviving_receivers()))
                      for op, rec in zip(ops,
                                         recss[r * len(cells) + i]))
                  / len(ops) for r in range(reps)]
            out[(cell[0], loss, cell[1])] = sum(js) / reps
    return out


def run(rows, engine="flow", workers=0, smoke=False):
    flow_engine = engine if engine.startswith("flow") else "flow"
    # 1) full-scale grid, flow engine (4096 hosts; smoke: 16)
    topo = build_topo(smoke)
    n_groups, group = (N_GROUPS_SMALL, GROUP_SMALL) if smoke \
        else (N_GROUPS, GROUP)
    jct = sweep_grid(flow_engine, topo, n_groups, group, NBYTES)
    for (churn, loss, flaps), j in sorted(jct.items()):
        rows.append((
            f"figmatrix/jct_c{churn:g}_l{loss:g}_f{flaps}/flow_ms",
            j * 1e3,
            f"groups={n_groups}x{group} hosts={len(topo.hosts)} "
            f"events={N_EVENTS if churn else 0} flaps={flaps}"))
    # 2) small-scale packet-vs-flow parity twin (every cell, both
    # engines; the <= 15% gate lives in tools/check_matrix.py)
    small = build_topo(smoke=True)
    jp = sweep_grid("packet", small, N_GROUPS_SMALL, GROUP_SMALL,
                    NBYTES_SMALL, workers=workers, seeds=16)
    jf = sweep_grid(flow_engine, small, N_GROUPS_SMALL, GROUP_SMALL,
                    NBYTES_SMALL)
    for cell in sorted(jp):
        churn, loss, flaps = cell
        div = abs(jp[cell] - jf[cell]) / jp[cell] if jp[cell] else 0.0
        rows.append((
            f"figmatrix/parity_c{churn:g}_l{loss:g}_f{flaps}/packet_ms",
            jp[cell] * 1e3,
            f"flow={jf[cell] * 1e3:.4f}ms div={100 * div:.1f}% "
            f"(16-seed mean; the CI gate compares against the frozen "
            f"64-seed GT, tools/check_matrix.py)"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--engine", default="flow",
                    choices=("packet", "flow", "flow-np"),
                    help="flow backend for the grid (packet always "
                         "runs the small parity twin)")
    ap.add_argument("--smoke", action="store_true",
                    help="16-host grid instead of 4096 (CI smoke)")
    ap.add_argument("--workers", type=int, default=0,
                    help="packet-engine scenario workers (0 = per CPU)")
    args = ap.parse_args(argv)
    rows: list = []
    t0 = time.time()
    run(rows, engine=args.engine, workers=args.workers, smoke=args.smoke)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.3f},{d}")
    print(f"# fig_matrix done in {time.time() - t0:.1f}s",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
