"""Adapted-layer benchmark: broadcast/all-reduce schedule comparison on
the TPU ICI (no paper figure — this is Fig. 9's design space mapped onto
the mesh: multiple-unicast vs overlay-ring vs Gleam-tree vs in-fabric).

Two sources:
- analytic alpha-beta costs (core/collectives.schedule_cost) for the
  production mesh sizes (16, 256 chips; 50GB/s links, 1us hops);
- measured per-schedule HLO collective bytes on an 8-device host mesh
  (lower+compile, countable in the HLO — same methodology as §Roofline).
"""
from __future__ import annotations

import os
import subprocess
import sys

from repro.core.collectives import schedule_cost

SIZES = {"1MB": 1 << 20, "64MB": 64 << 20, "1GB": 1 << 30}
SCHEDULES = ("unicast", "ring", "gleam_tree", "infabric")


def measured_bytes():
    """Compile tree/ring/unicast broadcast on 8 host devices (subprocess:
    device count is locked at jax init) and count HLO collective bytes."""
    src = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import collectives as coll
from repro.launch.roofline import collective_bytes

mesh = jax.make_mesh((8,), ("model",))
x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)   # 4MB payload
for name, fn in [
    ("tree", lambda v: coll.tree_broadcast(v, "model")),
    ("ring", lambda v: coll.ring_broadcast(v, "model", chunks=4)),
    ("unicast", lambda v: coll.unicast_broadcast(v, "model")),
]:
    f = shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                  check_vma=False)
    c = jax.jit(f).lower(x).compile()
    cb = collective_bytes(c.as_text())
    print(f"{name},{cb['total_bytes']},{sum(cb['counts'].values())}")
"""
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    rows = {}
    for line in out.stdout.strip().splitlines():
        name, nbytes, nops = line.split(",")
        rows[name] = (int(nbytes), int(nops))
    return rows


def run(rows, engine="packet"):
    # engine is irrelevant here: costs are analytic (core/metrics) and
    # HLO-measured; accepted for orchestrator uniformity.
    for label, nbytes in SIZES.items():
        for n in (16, 256):
            for sched in SCHEDULES:
                t = schedule_cost(sched, n, nbytes, chunks=8)
                rows.append(
                    (f"collsched/{label}_n{n}/{sched}_us", t * 1e6,
                     "analytic alpha-beta"))
    try:
        meas = measured_bytes()
        for name, (nbytes, nops) in meas.items():
            rows.append((f"collsched/hlo_4mb_bcast_8dev/{name}_bytes",
                         nbytes, f"{nops} collective ops in HLO"))
    except Exception as e:  # noqa: BLE001
        rows.append(("collsched/hlo_measured/error", 0, str(e)[:80]))
    return rows
