"""Figs. 15-16 — loss tolerance: JCT and normalized goodput under packet
loss rates 1e-8 .. 1e-3, group sizes 64 and 512.

Paper claims: Gleam keeps lower JCT than ring/long at ALL loss rates;
goodput >= 90% at loss <= 1e-4, ~42% at 1e-3 (the multicast sender
retransmits when ANY receiver loses — more loss-sensitive than unicast,
Fig. 16), still 7x lower JCT than the baseline at 0.1%.

``--engine packet`` (default) is the per-packet reference.  Loss
recovery is exactly where a single seed is least trustworthy: which
packets the fabric discards decides whether one go-back-N round or a
timeout-recovery storm follows, so each (scheme, group, loss) point runs
``seeds`` independent repetitions and reports mean±std.  The
repetitions are scenarios of ONE ``run_many`` batch on one engine — the
engine quiesces between scenarios and gives scenario *i* the RNG stream
derived from ``(seed, i)``, so the repetitions double as the seed axis
and parallelize across worker processes (``workers``; see
``core/engine.py``).  Each point's packet network is still built lazily
and discarded after its batch — a 512-host PacketSim carries full
endpoint/switch/group state, so keeping ~16 of them resident would
multiply peak memory for nothing.

``--engine flow`` / ``flow-np`` runs the same sweep on the fluid model,
whose expected-value loss/DCQCN correction (``core/flowsim.py``) was
calibrated against the packet engine.  Two sections:

- **diff rows** — the calibration grid (gleam + multiunicast, groups
  4/8, loss 0..1e-2 at the Fig. 8 testbed).  Where the checked-in
  packet ground truth (``benchmarks/ref_fig15_flow.json``, written by
  ``tools/check_fig15.py --update``) has the point, the derived column
  carries the flow-vs-packet divergence — the same numbers the CI gate
  enforces at <= 15%.
- **scale rows** — the loss grid at Fig. 14 scale (512/4096-member
  groups on a 4096-host fat-tree), far beyond packet-level reach.  The
  fluid model is deterministic, so no seed axis.
"""
from __future__ import annotations

import json
import math
import os

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import GroupOp

NBYTES = 1 << 20
LOSS_RATES = (0.0, 1e-6, 1e-5, 1e-4, 1e-3)
RING_LOSS_RATES = (0.0, 1e-4, 1e-3)    # baseline at the extremes (slow)
SIZES = (64, 512)
DEFAULT_SEEDS = 3

# Flow-engine calibration grid: the points the loss model was fitted
# and gated on (tools/check_fig15.py, tests/test_loss_model.py).  The
# per-loss seed counts buy a stable packet mean where recovery is
# noisiest; zero loss needs no seed axis.
FID_GROUPS = (4, 8)
FID_TRANSPORTS = ("gleam", "multiunicast")
FID_LOSS_RATES = (0.0, 1e-5, 1e-4, 1e-3, 1e-2)
FID_SEEDS = {0.0: 1, 1e-5: 8, 1e-4: 16, 1e-3: 32, 1e-2: 32}
REF_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "ref_fig15_flow.json")

# Fig. 14-scale section: loss grid on a 4096-host 3-layer fat-tree.
SCALE_FABRIC = dict(n_pods=16, leaves_per_pod=16, hosts_per_leaf=16,
                    aggs_per_pod=16, bw=200 * fattree.GBPS)
SCALE_GROUPS = (512, 4096)


def _label(loss) -> str:
    return f"{loss:.0e}" if loss else "0"


def _point(group, loss, transport):
    """One staged (scheme, group, loss) point: engine + pending record.
    Both schemes are the SAME GroupOp — only the transport differs."""
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    eng = make_engine("packet", topo, loss_rate=loss, seed=11,
                      group_kw={"window": 512},
                      relay_kw={"window": 512})
    members = [f"h{i}" for i in range(group)]
    rec = eng.stage(GroupOp("bcast", members, NBYTES,
                            transport=transport, chunks=8))
    return eng, rec


def _sweep_point(group, loss, transport, seeds, workers, timeout):
    """(mean, std, per-seed JCTs) over ``seeds`` independent repetitions
    of one (scheme, group, loss) point, run as one run_many batch."""
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    eng = make_engine("packet", topo, loss_rate=loss, seed=11,
                      group_kw={"window": 512},
                      relay_kw={"window": 512})
    members = [f"h{i}" for i in range(group)]
    recs = []

    def scenario(e):
        recs.append(e.stage(GroupOp("bcast", members, NBYTES,
                                    transport=transport, chunks=8)))

    eng.run_many([scenario] * seeds, timeout=timeout, workers=workers)
    jcts = [r.jct(group - 1) for r in recs]
    mean = sum(jcts) / len(jcts)
    std = math.sqrt(sum((j - mean) ** 2 for j in jcts) / len(jcts))
    return mean, std, jcts


def gleam_jct(group, loss):
    """Single-seed JCT of the Gleam point (bench/bisect helper)."""
    eng, rec = _point(group, loss, "gleam")
    eng.run(timeout=120.0)
    return rec.jct(group - 1)


def ring_jct(group, loss):
    eng, rec = _point(group, loss, "ring")
    eng.run(timeout=240.0)
    return rec.jct(group - 1)


def flow_jct(group, loss, transport, engine="flow"):
    """Deterministic fluid JCT of one testbed (scheme, group, loss)
    point — the flow-side twin of ``_point`` (same topology, tuning
    and GroupOp; the engine name picks the solver backend)."""
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    eng = make_engine(engine, topo, loss_rate=loss, seed=11,
                      group_kw={"window": 512},
                      relay_kw={"window": 512})
    members = [f"h{i}" for i in range(group)]
    rec = eng.stage(GroupOp("bcast", members, NBYTES,
                            transport=transport, chunks=8))
    eng.run()
    return rec.jct(group - 1)


def packet_gt(group, loss, transport, workers=0):
    """Fixed-seed packet ground truth for one calibration-grid point:
    the multi-seed mean at that point's ``FID_SEEDS`` repetition count.
    Used by ``tools/check_fig15.py --update`` and the differential
    test harness — NOT by the flow sweep itself (it reads the frozen
    json so a model change shows up as divergence, not a moved target).
    """
    seeds = FID_SEEDS[loss]
    return _sweep_point(group, loss, transport, seeds, workers, 240.0)[0]


def _load_ref() -> dict:
    """Frozen packet ground truth (us) keyed ``g{n}_loss{label}/{t}``;
    empty when the reference json has not been generated yet."""
    try:
        with open(REF_PATH, encoding="utf-8") as fh:
            return json.load(fh)["packet_us"]
    except (OSError, KeyError, ValueError):
        return {}


def _run_flow(rows, engine):
    ref = _load_ref()
    # DIFF: the calibration grid, divergence vs frozen packet GT
    for transport in FID_TRANSPORTS:
        for group in FID_GROUPS:
            base = None
            for loss in FID_LOSS_RATES:
                us = flow_jct(group, loss, transport, engine) * 1e6
                base = us if base is None else base
                key = f"g{group}_loss{_label(loss)}/{transport}"
                want = ref.get(key)
                div = (f"div={100 * abs(us - want) / want:.1f}% "
                       f"vs packet ref" if want else "no packet ref")
                rows.append((f"fig15/diff_{key}_us", us,
                             f"{div} goodput={100 * base / us:.0f}%"))
    # SCALE: the loss grid at fig14 scale — one 4096-host fabric, every
    # (transport, group, loss) point on a fresh engine (loss rate is a
    # fabric property), each solved by the fluid model in one pass.
    topo = fattree.fat_tree(**SCALE_FABRIC)
    hosts = topo.hosts
    for transport in FID_TRANSPORTS:
        for group in SCALE_GROUPS:
            base = None
            for loss in FID_LOSS_RATES:
                eng = make_engine(engine, topo, loss_rate=loss, seed=11,
                                  group_kw={"window": 512},
                                  relay_kw={"window": 512})
                rec = eng.stage(GroupOp("bcast", hosts[:group], NBYTES,
                                        transport=transport, chunks=8))
                eng.run()
                ms = rec.jct(group - 1) * 1e3
                base = ms if base is None else base
                rows.append((f"fig15/scale_g{group}_loss{_label(loss)}/"
                             f"{transport}_ms", ms,
                             f"goodput={100 * base / ms:.0f}% "
                             f"hosts={len(hosts)}"))
    return rows


def run(rows, engine="packet", seeds=DEFAULT_SEEDS, workers=0,
        sizes=SIZES):
    if engine != "packet":
        return _run_flow(rows, engine)
    seeds = max(1, int(seeds))
    # STAGE: declare every point of the sweep before driving any of it
    gleam_pts = [(g, l) for g in sizes for l in LOSS_RATES]
    ring_pts = [(g, l) for g in sizes for l in RING_LOSS_RATES]
    # BATCH: drive the sweep; each point is a seeds-wide run_many batch
    # (lazy build-run-discard per point, see module docstring)
    jct_g = {(g, l): _sweep_point(g, l, "gleam", seeds, workers,
                                  120.0)[:2] for g, l in gleam_pts}
    jct_r = {(g, l): _sweep_point(g, l, "ring", seeds, workers,
                                  240.0)[:2] for g, l in ring_pts}
    # DERIVE rows (mean ms; derived column carries ±std and goodput)
    for group in sizes:
        base_g = jct_g[(group, 0.0)][0]
        for loss in LOSS_RATES:
            jg, sg = jct_g[(group, loss)]
            goodput = base_g / jg if jg > 0 else 0.0
            label = _label(loss)
            rows.append((f"fig15/jct_g{group}_loss{label}/gleam_ms",
                         jg * 1e3,
                         f"±{sg * 1e3:.4f}ms n={seeds} "
                         f"goodput={100 * goodput:.0f}%"))
        for loss in RING_LOSS_RATES:
            jr, sr = jct_r[(group, loss)]
            label = _label(loss)
            rows.append((f"fig15/jct_g{group}_loss{label}/ring_ms",
                         jr * 1e3, f"±{sr * 1e3:.4f}ms n={seeds}"))
    return rows
