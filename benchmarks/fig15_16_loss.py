"""Figs. 15-16 — loss tolerance: JCT and normalized goodput under packet
loss rates 1e-8 .. 1e-3, group sizes 64 and 512 (packet-level sim).

Paper claims: Gleam keeps lower JCT than ring/long at ALL loss rates;
goodput >= 90% at loss <= 1e-4, ~42% at 1e-3 (the multicast sender
retransmits when ANY receiver loses — more loss-sensitive than unicast,
Fig. 16), still 7x lower JCT than the baseline at 0.1%.

Structured stage-then-batch: the whole (scheme, group, loss) sweep is
declared as a point list up front and DRIVEN in one batch loop before
any row is derived.  Each point's packet network is built lazily
inside the loop and discarded after its run — a 512-host PacketSim
carries full endpoint/switch/group state, so keeping ~16 of them
resident (true up-front staging) would multiply peak memory for zero
batching benefit on a backend that can only run serially.  Loss
recovery (go-back-N, NACK aggregation) only exists in the packet
engine, so the sweep pins it regardless of ``--engine``.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import GroupOp

NBYTES = 1 << 20
LOSS_RATES = (0.0, 1e-6, 1e-5, 1e-4, 1e-3)
RING_LOSS_RATES = (0.0, 1e-4, 1e-3)    # baseline at the extremes (slow)
SIZES = (64, 512)


def _point(group, loss, transport):
    """One staged (scheme, group, loss) point: engine + pending record.
    Both schemes are the SAME GroupOp — only the transport differs."""
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    eng = make_engine("packet", topo, loss_rate=loss, seed=11,
                      group_kw={"window": 512},
                      relay_kw={"window": 512})
    members = [f"h{i}" for i in range(group)]
    rec = eng.stage(GroupOp("bcast", members, NBYTES,
                            transport=transport, chunks=8))
    return eng, rec


def gleam_jct(group, loss):
    eng, rec = _point(group, loss, "gleam")
    eng.run(timeout=120.0)
    return rec.jct(group - 1)


def ring_jct(group, loss):
    eng, rec = _point(group, loss, "ring")
    eng.run(timeout=240.0)
    return rec.jct(group - 1)


def run(rows, engine="packet"):
    if engine != "packet":
        rows.append(("fig15/note", 0.0,
                     f"engine={engine} unsupported; using packet"))
    # STAGE: declare every point of the sweep before driving any of it
    gleam_pts = [(g, l) for g in SIZES for l in LOSS_RATES]
    ring_pts = [(g, l) for g in SIZES for l in RING_LOSS_RATES]
    # BATCH: drive the sweep (lazy build-run-discard per point, see
    # module docstring)
    jct_g = {(g, l): gleam_jct(g, l) for g, l in gleam_pts}
    jct_r = {(g, l): ring_jct(g, l) for g, l in ring_pts}
    # DERIVE rows
    for group in SIZES:
        base_g = jct_g[(group, 0.0)]
        for loss in LOSS_RATES:
            jg = jct_g[(group, loss)]
            goodput = base_g / jg if jg > 0 else 0.0
            label = f"{loss:.0e}" if loss else "0"
            rows.append((f"fig15/jct_g{group}_loss{label}/gleam_ms",
                         jg * 1e3, f"goodput={100 * goodput:.0f}%"))
        for loss in RING_LOSS_RATES:
            jr = jct_r[(group, loss)]
            label = f"{loss:.0e}" if loss else "0"
            rows.append((f"fig15/jct_g{group}_loss{label}/ring_ms",
                         jr * 1e3, ""))
    return rows
