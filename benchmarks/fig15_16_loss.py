"""Figs. 15-16 — loss tolerance: JCT and normalized goodput under packet
loss rates 1e-8 .. 1e-3, group sizes 64 and 512 (packet-level sim).

Paper claims: Gleam keeps lower JCT than ring/long at ALL loss rates;
goodput >= 90% at loss <= 1e-4, ~42% at 1e-3 (the multicast sender
retransmits when ANY receiver loses — more loss-sensitive than unicast,
Fig. 16), still 7x lower JCT than the baseline at 0.1%.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.baselines import RingBcast
from repro.core.gleam import GleamNetwork

NBYTES = 1 << 20
LOSS_RATES = (0.0, 1e-6, 1e-5, 1e-4, 1e-3)
SIZES = (64, 512)


def gleam_jct(group, loss):
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    net = GleamNetwork(topo, loss_rate=loss, seed=11)
    members = [f"h{i}" for i in range(group)]
    g = net.multicast_group(members, window=512)
    g.register()
    rec = g.bcast(NBYTES)
    return g.run_until_delivered(rec, timeout=120.0)


def ring_jct(group, loss):
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    net = GleamNetwork(topo, loss_rate=loss, seed=11)
    members = [f"h{i}" for i in range(group)]
    b = RingBcast(net, members, chunks=8, window=512)
    b.start(NBYTES)
    return b.run(timeout=240.0)


def run(rows, engine="packet"):
    # Loss recovery (go-back-N, NACK aggregation) only exists in the
    # packet engine; the fluid model has no packets to drop.  Run the
    # packet engine regardless of the requested backend.
    if engine != "packet":
        rows.append(("fig15/note", 0.0,
                     f"engine={engine} unsupported; using packet"))
    for group in SIZES:
        base_g = None
        for loss in LOSS_RATES:
            jg = gleam_jct(group, loss)
            if loss == 0.0:
                base_g = jg
            goodput = base_g / jg if jg > 0 else 0.0
            label = f"{loss:.0e}" if loss else "0"
            rows.append((f"fig15/jct_g{group}_loss{label}/gleam_ms",
                         jg * 1e3, f"goodput={100 * goodput:.0f}%"))
        # baseline at the extremes only (slow at 512)
        for loss in (0.0, 1e-4, 1e-3):
            jr = ring_jct(group, loss)
            label = f"{loss:.0e}" if loss else "0"
            rows.append((f"fig15/jct_g{group}_loss{label}/ring_ms",
                         jr * 1e3, ""))
    return rows
