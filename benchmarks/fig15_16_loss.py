"""Figs. 15-16 — loss tolerance: JCT and normalized goodput under packet
loss rates 1e-8 .. 1e-3, group sizes 64 and 512 (packet-level sim).

Paper claims: Gleam keeps lower JCT than ring/long at ALL loss rates;
goodput >= 90% at loss <= 1e-4, ~42% at 1e-3 (the multicast sender
retransmits when ANY receiver loses — more loss-sensitive than unicast,
Fig. 16), still 7x lower JCT than the baseline at 0.1%.

Loss recovery is exactly where a single seed is least trustworthy: which
packets the fabric discards decides whether one go-back-N round or a
timeout-recovery storm follows, so each (scheme, group, loss) point runs
``seeds`` independent repetitions and reports mean±std.  The
repetitions are scenarios of ONE ``run_many`` batch on one engine — the
engine quiesces between scenarios and gives scenario *i* the RNG stream
derived from ``(seed, i)``, so the repetitions double as the seed axis
and parallelize across worker processes (``workers``; see
``core/engine.py``).

Each point's packet network is still built lazily and discarded after
its batch — a 512-host PacketSim carries full endpoint/switch/group
state, so keeping ~16 of them resident would multiply peak memory for
nothing.  Loss recovery (go-back-N, NACK aggregation) only exists in
the packet engine, so the sweep pins it regardless of ``--engine``.
"""
from __future__ import annotations

import math

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import GroupOp

NBYTES = 1 << 20
LOSS_RATES = (0.0, 1e-6, 1e-5, 1e-4, 1e-3)
RING_LOSS_RATES = (0.0, 1e-4, 1e-3)    # baseline at the extremes (slow)
SIZES = (64, 512)
DEFAULT_SEEDS = 3


def _point(group, loss, transport):
    """One staged (scheme, group, loss) point: engine + pending record.
    Both schemes are the SAME GroupOp — only the transport differs."""
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    eng = make_engine("packet", topo, loss_rate=loss, seed=11,
                      group_kw={"window": 512},
                      relay_kw={"window": 512})
    members = [f"h{i}" for i in range(group)]
    rec = eng.stage(GroupOp("bcast", members, NBYTES,
                            transport=transport, chunks=8))
    return eng, rec


def _sweep_point(group, loss, transport, seeds, workers, timeout):
    """(mean, std, per-seed JCTs) over ``seeds`` independent repetitions
    of one (scheme, group, loss) point, run as one run_many batch."""
    topo = fattree.testbed(n_hosts=group, bw=200 * fattree.GBPS)
    eng = make_engine("packet", topo, loss_rate=loss, seed=11,
                      group_kw={"window": 512},
                      relay_kw={"window": 512})
    members = [f"h{i}" for i in range(group)]
    recs = []

    def scenario(e):
        recs.append(e.stage(GroupOp("bcast", members, NBYTES,
                                    transport=transport, chunks=8)))

    eng.run_many([scenario] * seeds, timeout=timeout, workers=workers)
    jcts = [r.jct(group - 1) for r in recs]
    mean = sum(jcts) / len(jcts)
    std = math.sqrt(sum((j - mean) ** 2 for j in jcts) / len(jcts))
    return mean, std, jcts


def gleam_jct(group, loss):
    """Single-seed JCT of the Gleam point (bench/bisect helper)."""
    eng, rec = _point(group, loss, "gleam")
    eng.run(timeout=120.0)
    return rec.jct(group - 1)


def ring_jct(group, loss):
    eng, rec = _point(group, loss, "ring")
    eng.run(timeout=240.0)
    return rec.jct(group - 1)


def run(rows, engine="packet", seeds=DEFAULT_SEEDS, workers=0,
        sizes=SIZES):
    if engine != "packet":
        rows.append(("fig15/note", 0.0,
                     f"engine={engine} unsupported; using packet"))
    seeds = max(1, int(seeds))
    # STAGE: declare every point of the sweep before driving any of it
    gleam_pts = [(g, l) for g in sizes for l in LOSS_RATES]
    ring_pts = [(g, l) for g in sizes for l in RING_LOSS_RATES]
    # BATCH: drive the sweep; each point is a seeds-wide run_many batch
    # (lazy build-run-discard per point, see module docstring)
    jct_g = {(g, l): _sweep_point(g, l, "gleam", seeds, workers,
                                  120.0)[:2] for g, l in gleam_pts}
    jct_r = {(g, l): _sweep_point(g, l, "ring", seeds, workers,
                                  240.0)[:2] for g, l in ring_pts}
    # DERIVE rows (mean ms; derived column carries ±std and goodput)
    for group in sizes:
        base_g = jct_g[(group, 0.0)][0]
        for loss in LOSS_RATES:
            jg, sg = jct_g[(group, loss)]
            goodput = base_g / jg if jg > 0 else 0.0
            label = f"{loss:.0e}" if loss else "0"
            rows.append((f"fig15/jct_g{group}_loss{label}/gleam_ms",
                         jg * 1e3,
                         f"±{sg * 1e3:.4f}ms n={seeds} "
                         f"goodput={100 * goodput:.0f}%"))
        for loss in RING_LOSS_RATES:
            jr, sr = jct_r[(group, loss)]
            label = f"{loss:.0e}" if loss else "0"
            rows.append((f"fig15/jct_g{group}_loss{label}/ring_ms",
                         jr * 1e3, f"±{sr * 1e3:.4f}ms n={seeds}"))
    return rows
