"""Fig. 14 — HPL JCT over multicast scales N*N on a 16384-server 3-layer
fat-tree (200Gbps, 1:1 oversubscription), Gleam vs ring(PB)+long(RS).

Paper claims: Gleam reduces JCT 62% (8*8) .. 73% (128*128); Gleam's JCT
stays ~flat with scale while ring/long grow (their parallel-unicast count
expands linearly).

Model: N simultaneous PB groups (one per row) + N RS groups (one per
column), members row-/column-major on the fat-tree, declared as
Workload IR and solved in one max-min fair batch.  The PB baseline is
the same bcast ops over ``--transport`` (default ``ring`` — the HPL
increasing-ring; any registered transport works at this scale, the
point of the IR); `long` spreads then exchanges (volume-optimal when
uniform).

The sweep is stage-then-batch: every (scale, workload) scenario on the
same topology is staged on ONE engine and solved by a single
``run_many`` call — the shape-bucketed solver compiles once for the
whole sweep instead of once per point, and the topology (with its BFS
routing caches) is built once per size class.  ``--serial`` restores
the PR-1 behavior (fresh engine + solve per scenario) for A/B timing;
``tools/bench.py`` records both.

This figure is inherently beyond packet-level reach (the paper
parallelized ns-3 for it); requesting ``--engine packet`` falls back to
``flow`` with a note.

Standalone:

    PYTHONPATH=src python benchmarks/fig14_scale.py --engine flow
    PYTHONPATH=src python benchmarks/fig14_scale.py --engine flow --full
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
import time

if __package__ in (None, ""):      # `python benchmarks/fig14_scale.py`
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.engine import make_engine
from repro.core.fattree import GBPS, fat_tree
from repro.core.workload import TRANSPORT_CHOICES, Workload

VOLUME = 8 << 20                   # bytes per PB/RS message
CHUNKS = 8
SCALES = (8, 16, 32)               # 1024-host fat-tree
SCALES_FULL = (8, 16, 32, 64, 128)  # adds the 16384-host config


@functools.lru_cache(maxsize=2)
def _build(big: bool):
    """The two §5.3 size classes, cached: the topology AND its BFS
    routing caches are reused across every scale that fits."""
    if big:
        return fat_tree(n_pods=32, leaves_per_pod=16, hosts_per_leaf=32,
                        aggs_per_pod=16, bw=200 * GBPS)
    return fat_tree(n_pods=8, leaves_per_pod=8, hosts_per_leaf=16,
                    aggs_per_pod=8, bw=200 * GBPS)


def build(n):
    """Fat-tree with >= n*n hosts (paper: 16384 hosts, 64-port, 200G)."""
    need = n * n
    topo = _build(need > 1024)
    assert len(topo.hosts) >= need, (len(topo.hosts), need)
    return topo


def _flow_engine(name: str):
    """This figure needs a flow backend; coerce packet -> flow."""
    return "flow" if name == "packet" else name


@functools.lru_cache(maxsize=16)
def _workloads(big: bool, n: int, transport: str):
    """Workload IR for one sweep point, cached: ops are immutable
    (engines lower them into per-epoch records without touching the
    IR), so repeated passes — `tools/bench.py` runs the sweep twice to
    separate compile from steady state — reuse the same ~n*n GroupOps
    instead of re-declaring them."""
    hosts = _build(big).hosts
    return (gleam_workload(hosts, n),
            baseline_workload(hosts, n, transport))


# ------------------------------------------------------------- workloads

def gleam_workload(hosts, n) -> Workload:
    """N PB groups (rows) + N RS groups (columns), one bcast each."""
    wl = Workload(f"fig14/gleam_{n}x{n}")
    for row in range(n):
        wl.bcast(hosts[row * n:(row + 1) * n], VOLUME, key=row)
    for col in range(n):
        wl.bcast([hosts[row * n + col] for row in range(n)], VOLUME,
                 key=n + col)
    return wl


def baseline_workload(hosts, n, transport="ring") -> Workload:
    """PB over the baseline ``transport`` (one bcast op per row — the
    engines lower it to the relay schedule) + RS via the `long`
    neighbor exchange as a concurrent unicast mesh."""
    wl = Workload(f"fig14/{transport}_long_{n}x{n}")
    for row in range(n):
        wl.bcast(hosts[row * n:(row + 1) * n], VOLUME,
                 transport=transport, chunks=CHUNKS, key=row)
    for col in range(n):                       # long: neighbor exchange
        members = [hosts[row * n + col] for row in range(n)]
        for i in range(n - 1):
            wl.unicast(members[i], members[i + 1],
                       VOLUME * (n - 1) // n, key=n + col)
    return wl


def _values(n, g_recs, b_recs) -> tuple:
    jg = max(r.jct(n - 1) for r in g_recs)
    pb = max(r.jct(n - 1) for r in b_recs[:n])          # transport bcasts
    long_jct = max(r.jct(1) for r in b_recs[n:])        # `long` unicasts
    return jg, max(pb, long_jct)


# ---------------------------------------------- per-scenario entry points

def gleam_jct(n, engine="flow") -> float:
    """Standalone (fresh-engine, solve-per-call) gleam point."""
    eng = make_engine(_flow_engine(engine), build(n))
    recs = eng.run_workloads([gleam_workload(eng.topo.hosts, n)])[0]
    return max(r.jct(n - 1) for r in recs)


def ring_long_jct(n, engine="flow", transport="ring") -> float:
    """Standalone (fresh-engine, solve-per-call) baseline point."""
    eng = make_engine(_flow_engine(engine), build(n))
    recs = eng.run_workloads(
        [baseline_workload(eng.topo.hosts, n, transport)])[0]
    pb = max(r.jct(n - 1) for r in recs[:n])
    return max(pb, max(r.jct(1) for r in recs[n:]))


# ----------------------------------------------------------------- sweep

def run(rows, engine="flow", transport="ring", scales=None, batched=True):
    """Default scales stop at 32 (1024 hosts, seconds) in BOTH entry
    points; the 16384-host top end is opt-in (CLI --full).

    ``batched=True`` declares the whole sweep as Workloads on one
    engine per topology and solves it with a single ``run_workloads``;
    ``batched=False`` is the PR-1 serial path (one engine + solve per
    scenario, for A/B timing).  ``transport`` picks the PB baseline
    overlay (``ring`` is the paper's; any registered transport runs).
    """
    engine = _flow_engine(engine)
    if transport == "gleam":                   # baseline must be an overlay
        transport = "ring"
    scales = tuple(scales or SCALES)
    results = {}
    if batched:
        for big in sorted({n * n > 1024 for n in scales}):
            group = [n for n in scales if (n * n > 1024) == big]
            eng = make_engine(engine, _build(big))
            workloads = []
            for n in group:
                workloads.extend(_workloads(big, n, transport))
            recss = eng.run_workloads(workloads)
            for i, n in enumerate(group):
                results[n] = _values(n, recss[2 * i], recss[2 * i + 1])
    else:
        for n in scales:
            results[n] = (gleam_jct(n, engine),
                          ring_long_jct(n, engine, transport))
    for n in scales:
        jg, jb = results[n]
        rows.append((f"fig14/hpl_{n}x{n}/gleam_ms", jg * 1e3,
                     f"engine={engine}"))
        rows.append((f"fig14/hpl_{n}x{n}/{transport}_long_ms", jb * 1e3,
                     f"reduction={100 * (1 - jg / jb):.0f}% "
                     f"(paper 62-73%)"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--engine", default="flow",
                    choices=("packet", "flow", "flow-np"),
                    help="simulation backend (packet falls back to flow)")
    ap.add_argument("--transport", default="ring",
                    choices=[t for t in TRANSPORT_CHOICES if t != "gleam"],
                    help="PB baseline overlay transport (paper: ring)")
    ap.add_argument("--full", action="store_true",
                    help=f"sweep {SCALES_FULL} (16384-host top end) "
                         f"instead of {SCALES}; staging the 16k-host "
                         f"trees is python-routing-bound (expect "
                         f"minutes; solver time stays in seconds)")
    ap.add_argument("--serial", action="store_true",
                    help="PR-1 behavior: fresh engine + solve per "
                         "scenario instead of one batched run_many")
    args = ap.parse_args(argv)
    rows: list = []
    t0 = time.time()
    run(rows, engine=args.engine, transport=args.transport,
        scales=SCALES_FULL if args.full else SCALES,
        batched=not args.serial)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.3f},{d}")
    print(f"# fig14 sweep done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
