"""Fig. 14 — HPL JCT over multicast scales N*N on a 16384-server 3-layer
fat-tree (200Gbps, 1:1 oversubscription), Gleam vs ring(PB)+long(RS).

Paper claims: Gleam reduces JCT 62% (8*8) .. 73% (128*128); Gleam's JCT
stays ~flat with scale while ring/long grow (their parallel-unicast count
expands linearly).

Fluid model (core/flowsim.py): N simultaneous PB groups (one per row) +
N RS groups (one per column), members row-/column-major on the fat-tree.
Ring JCT uses the pipelined-chunk schedule on steady-state hop rates;
`long` spreads then exchanges (volume-optimal when uniform).
"""
from __future__ import annotations

from repro.core.fattree import GBPS, fat_tree
from repro.core.flowsim import FlowSim

VOLUME = 8 << 20                   # bytes per PB/RS message
CHUNKS = 8
SCALES = (8, 16, 32, 64, 128)


def _hosts(topo):
    return topo.hosts


def build(n):
    """Fat-tree with >= n*n hosts (paper: 16384 hosts, 64-port, 200G)."""
    need = n * n
    # hosts = pods * leaves * hosts_per_leaf; keep radix realistic
    if need <= 1024:
        topo = fat_tree(n_pods=8, leaves_per_pod=8, hosts_per_leaf=16,
                        aggs_per_pod=8, bw=200 * GBPS)
    else:
        topo = fat_tree(n_pods=32, leaves_per_pod=16, hosts_per_leaf=32,
                        aggs_per_pod=16, bw=200 * GBPS)
    assert len(topo.hosts) >= need, (len(topo.hosts), need)
    return topo


def gleam_jct(n) -> float:
    topo = build(n)
    sim = FlowSim(topo)
    hosts = _hosts(topo)
    for row in range(n):                       # N PB groups (rows)
        members = hosts[row * n:(row + 1) * n]
        sim.add(sim.multicast_tree_links(members[0], members, key=row),
                VOLUME)
    for col in range(n):                       # N RS groups (columns)
        members = [hosts[row * n + col] for row in range(n)]
        sim.add(sim.multicast_tree_links(members[0], members, key=n + col),
                VOLUME)
    return sim.run()


def ring_long_jct(n) -> float:
    """PB via pipelined increasing-ring + RS via `long` exchange, both as
    concurrent unicast meshes; serial hop structure applied analytically
    on the fluid steady-state rate."""
    topo = build(n)
    sim = FlowSim(topo)
    hosts = _hosts(topo)
    ring_flows = []
    for row in range(n):
        members = hosts[row * n:(row + 1) * n]
        for i in range(n - 1):                 # ring hop i -> i+1
            f = sim.add(sim.unicast_links(members[i], members[i + 1],
                                          key=row),
                        VOLUME / CHUNKS, tag="ring")
            ring_flows.append(f)
    for col in range(n):                       # long: neighbor exchange
        members = [hosts[row * n + col] for row in range(n)]
        for i in range(n - 1):
            sim.add(sim.unicast_links(members[i], members[i + 1],
                                      key=n + col),
                    VOLUME * (n - 1) / n, tag="long")
    sim.run()
    # steady-state chunk time on the slowest ring hop:
    chunk_t = max(f.done_t for f in ring_flows)
    ring_jct = (n - 1 + CHUNKS - 1) * chunk_t
    long_jct = max(f.done_t for f in sim.flows if f.tag == "long")
    return max(ring_jct, long_jct)


def run(rows):
    for n in SCALES:
        jg = gleam_jct(n)
        jb = ring_long_jct(n)
        rows.append((f"fig14/hpl_{n}x{n}/gleam_ms", jg * 1e3, ""))
        rows.append((f"fig14/hpl_{n}x{n}/ring_long_ms", jb * 1e3,
                     f"reduction={100 * (1 - jg / jb):.0f}% "
                     f"(paper 62-73%)"))
    return rows
