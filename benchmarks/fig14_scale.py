"""Fig. 14 — HPL JCT over multicast scales N*N on a 16384-server 3-layer
fat-tree (200Gbps, 1:1 oversubscription), Gleam vs ring(PB)+long(RS).

Paper claims: Gleam reduces JCT 62% (8*8) .. 73% (128*128); Gleam's JCT
stays ~flat with scale while ring/long grow (their parallel-unicast count
expands linearly).

Model: N simultaneous PB groups (one per row) + N RS groups (one per
column), members row-/column-major on the fat-tree, all staged on a flow
SimEngine and solved in one max-min fair batch.  Ring JCT uses the
pipelined-chunk schedule on steady-state hop rates; `long` spreads then
exchanges (volume-optimal when uniform).

This figure is inherently beyond packet-level reach (the paper
parallelized ns-3 for it); requesting ``--engine packet`` falls back to
``flow`` with a note.  The vectorized JAX backend runs the 1024-host
sweep in seconds; ``flow-np`` is the numpy fallback.

Standalone:

    PYTHONPATH=src python benchmarks/fig14_scale.py --engine flow
    PYTHONPATH=src python benchmarks/fig14_scale.py --engine flow --full
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):      # `python benchmarks/fig14_scale.py`
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.engine import make_engine
from repro.core.fattree import GBPS, fat_tree

VOLUME = 8 << 20                   # bytes per PB/RS message
CHUNKS = 8
SCALES = (8, 16, 32)               # 1024-host fat-tree
SCALES_FULL = (8, 16, 32, 64, 128)  # adds the 16384-host config


def build(n):
    """Fat-tree with >= n*n hosts (paper: 16384 hosts, 64-port, 200G)."""
    need = n * n
    # hosts = pods * leaves * hosts_per_leaf; keep radix realistic
    if need <= 1024:
        topo = fat_tree(n_pods=8, leaves_per_pod=8, hosts_per_leaf=16,
                        aggs_per_pod=8, bw=200 * GBPS)
    else:
        topo = fat_tree(n_pods=32, leaves_per_pod=16, hosts_per_leaf=32,
                        aggs_per_pod=16, bw=200 * GBPS)
    assert len(topo.hosts) >= need, (len(topo.hosts), need)
    return topo


def _flow_engine(name: str):
    """This figure needs a flow backend; coerce packet -> flow."""
    return "flow" if name == "packet" else name


def gleam_jct(n, engine="flow") -> float:
    topo = build(n)
    eng = make_engine(_flow_engine(engine), topo)
    hosts = topo.hosts
    recs = []
    for row in range(n):                       # N PB groups (rows)
        members = hosts[row * n:(row + 1) * n]
        recs.append(eng.add_bcast(members, VOLUME, key=row))
    for col in range(n):                       # N RS groups (columns)
        members = [hosts[row * n + col] for row in range(n)]
        recs.append(eng.add_bcast(members, VOLUME, key=n + col))
    eng.run()
    return max(r.jct(n - 1) for r in recs)


def ring_long_jct(n, engine="flow") -> float:
    """PB via pipelined increasing-ring + RS via `long` exchange, both as
    concurrent unicast meshes; serial hop structure applied analytically
    on the fluid steady-state rate."""
    topo = build(n)
    eng = make_engine(_flow_engine(engine), topo)
    hosts = topo.hosts
    ring_recs, long_recs = [], []
    for row in range(n):
        members = hosts[row * n:(row + 1) * n]
        for i in range(n - 1):                 # ring hop i -> i+1
            ring_recs.append(eng.add_unicast(
                members[i], members[i + 1], VOLUME // CHUNKS, key=row))
    for col in range(n):                       # long: neighbor exchange
        members = [hosts[row * n + col] for row in range(n)]
        for i in range(n - 1):
            long_recs.append(eng.add_unicast(
                members[i], members[i + 1],
                VOLUME * (n - 1) // n, key=n + col))
    eng.run()
    # steady-state chunk time on the slowest ring hop:
    chunk_t = max(r.jct(1) for r in ring_recs)
    ring_jct = (n - 1 + CHUNKS - 1) * chunk_t
    long_jct = max(r.jct(1) for r in long_recs)
    return max(ring_jct, long_jct)


def run(rows, engine="flow", scales=None):
    """Default scales stop at 32 (1024 hosts, seconds) in BOTH entry
    points; the 16384-host top end is opt-in (CLI --full) because its
    python-side tree staging takes tens of minutes."""
    engine = _flow_engine(engine)
    for n in scales or SCALES:
        jg = gleam_jct(n, engine)
        jb = ring_long_jct(n, engine)
        rows.append((f"fig14/hpl_{n}x{n}/gleam_ms", jg * 1e3,
                     f"engine={engine}"))
        rows.append((f"fig14/hpl_{n}x{n}/ring_long_ms", jb * 1e3,
                     f"reduction={100 * (1 - jg / jb):.0f}% "
                     f"(paper 62-73%)"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--engine", default="flow",
                    choices=("packet", "flow", "flow-np"),
                    help="simulation backend (packet falls back to flow)")
    ap.add_argument("--full", action="store_true",
                    help=f"sweep {SCALES_FULL} (16384-host top end) "
                         f"instead of {SCALES}; staging the 16k-host "
                         f"trees is python-routing-bound (expect tens "
                         f"of minutes; solver time stays in seconds)")
    args = ap.parse_args(argv)
    rows: list = []
    t0 = time.time()
    run(rows, engine=args.engine,
        scales=SCALES_FULL if args.full else SCALES)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.3f},{d}")
    print(f"# fig14 sweep done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
