"""Figs. 12-13 — storage data replication: 3-copy WRITE throughput (IOPS)
and single-IO latency, Gleam vs 3-unicasts vs 1-copy ideal.

Paper claims: 1.167M IOPS (Gleam) vs 0.413M (3-unicasts) vs 1.188M
(1-copy) at 8KB IOs; latency -40% (64KB) and -60% (512KB).
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.gleam import GleamNetwork


def gleam_run(io_bytes, n_ios):
    net = GleamNetwork(fattree.testbed())
    g = net.multicast_group(["h0", "h1", "h2", "h3"])
    g.register()
    t0 = net.sim.now
    recs = [g.write(io_bytes) for _ in range(n_ios)]
    for r in recs:
        g.run_until_delivered(r)
    dt = max(r.t_sender_cqe for r in recs) - t0
    lat = sum(r.io_latency for r in recs) / n_ios
    return n_ios / dt, lat


def unicast_run(io_bytes, n_ios, copies=3):
    net = GleamNetwork(fattree.testbed())
    qps = [net.unicast_qp("h0", f"h{i + 1}")[0] for i in range(copies)]
    sim = net.sim
    t0 = sim.now
    done = {}
    for qp in qps:
        qp.on_complete = (lambda m, now:
                          done.setdefault(m.msg_id, []).append(now))
    for i in range(n_ios):
        for qp in qps:
            qp.submit(io_bytes, sim.now, op="write", msg_id=i)
    sim.kick(sim.hosts["h0"], sim.now)
    sim.run(until=sim.now + 60.0)
    times = {k: max(v) for k, v in done.items() if len(v) == copies}
    assert len(times) == n_ios
    dt = max(times.values()) - t0
    lat = sum(times.values()) / n_ios - t0
    return n_ios / dt, lat


def run(rows):
    n = 300
    g_iops, _ = gleam_run(8 << 10, n)
    u_iops, _ = unicast_run(8 << 10, n)
    o_iops, _ = unicast_run(8 << 10, n, copies=1)
    rows.append(("fig12/iops_8k/gleam_kiops", g_iops / 1e3,
                 f"{100 * g_iops / o_iops:.0f}% of 1-copy "
                 f"(paper 98%)"))
    rows.append(("fig12/iops_8k/3unicast_kiops", u_iops / 1e3,
                 f"gleam_gain={g_iops / u_iops:.2f}x (paper 2.7x)"))
    rows.append(("fig12/iops_8k/1copy_kiops", o_iops / 1e3, "ideal"))
    for kb, paper in ((64, 40), (512, 60)):
        _, gl = gleam_run(kb << 10, 30)
        _, ul = unicast_run(kb << 10, 30)
        rows.append((f"fig13/lat_{kb}k/gleam_us", gl * 1e6, ""))
        rows.append((f"fig13/lat_{kb}k/3unicast_us", ul * 1e6,
                     f"saving={100 * (1 - gl / ul):.0f}% "
                     f"(paper ~{paper}%)"))
    return rows
