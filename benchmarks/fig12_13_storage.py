"""Figs. 12-13 — storage data replication: 3-copy WRITE throughput (IOPS)
and single-IO latency, Gleam vs 3-unicasts vs 1-copy ideal.

Paper claims: 1.167M IOPS (Gleam) vs 0.413M (3-unicasts) vs 1.188M
(1-copy) at 8KB IOs; latency -40% (64KB) and -60% (512KB).

Both schemes are declared as Workload IR: Gleam replication is one
one-to-many WRITE per IO (MR_UPDATE preamble included, §3.3); the
baseline workload submits one unicast WRITE per copy.  IOPS and IO
latency come from the MsgRecords exactly as core/metrics.py defines
them.

The whole figure is one ``run_workloads`` call: every (IO size,
scheme) workload is an independent scenario.  On the flow engine that
is one vmapped solve for all seven workloads (and the 8KB/64KB/512KB
points share a jit bucket); on the packet engine the scenarios run
serially on a quiesced fabric, which matches the per-workload runs
they replace.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.metrics import iops, mean_io_latency
from repro.core.workload import Workload

MEMBERS = ["h0", "h1", "h2", "h3"]


def gleam_workload(io_bytes, n_ios) -> Workload:
    wl = Workload(f"fig12/gleam_{io_bytes >> 10}k")
    for _ in range(n_ios):
        wl.write(MEMBERS, io_bytes)
    return wl


def unicast_workload(io_bytes, n_ios, copies) -> Workload:
    wl = Workload(f"fig12/unicast_{io_bytes >> 10}k_x{copies}")
    for _ in range(n_ios):
        for c in range(copies):
            wl.unicast("h0", f"h{c + 1}", io_bytes)
    return wl


def _gleam_metrics(recs):
    assert all(r.complete for r in recs)
    return iops(recs, recs[0].t_submit), mean_io_latency(recs)


def _unicast_metrics(recs, copies):
    groups = [recs[i:i + copies] for i in range(0, len(recs), copies)]
    t0 = groups[0][0].t_submit
    assert all(r.complete for g in groups for r in g)
    # an IO completes when its LAST copy's CQE lands
    times = [max(r.t_sender_cqe for r in g) for g in groups]
    dt = max(times) - t0
    lat = sum(times) / len(groups) - t0
    return len(groups) / dt, lat


def gleam_run(io_bytes, n_ios, engine="packet"):
    eng = make_engine(engine, fattree.testbed())
    recs = eng.run_workloads([gleam_workload(io_bytes, n_ios)],
                             timeout=120.0)[0]
    return _gleam_metrics(recs)


def unicast_run(io_bytes, n_ios, copies=3, engine="packet"):
    eng = make_engine(engine, fattree.testbed())
    recs = eng.run_workloads([unicast_workload(io_bytes, n_ios, copies)],
                             timeout=120.0)[0]
    return _unicast_metrics(recs, copies)


def run(rows, engine="packet"):
    n = 300
    eng = make_engine(engine, fattree.testbed())
    points = [(8 << 10, n), (64 << 10, 30), (512 << 10, 30)]
    workloads = []
    for io_bytes, n_ios in points:
        workloads.append(gleam_workload(io_bytes, n_ios))
        workloads.append(unicast_workload(io_bytes, n_ios, 3))
    workloads.append(unicast_workload(8 << 10, n, 1))      # 1-copy ideal
    recss = eng.run_workloads(workloads, timeout=120.0)
    gleam = {io: recss[2 * i] for i, (io, _) in enumerate(points)}
    uni = {(io, 3): recss[2 * i + 1] for i, (io, _) in enumerate(points)}
    uni[(8 << 10, 1)] = recss[-1]

    g_iops, _ = _gleam_metrics(gleam[8 << 10])
    u_iops, _ = _unicast_metrics(uni[(8 << 10, 3)], 3)
    o_iops, _ = _unicast_metrics(uni[(8 << 10, 1)], 1)
    rows.append(("fig12/iops_8k/gleam_kiops", g_iops / 1e3,
                 f"{100 * g_iops / o_iops:.0f}% of 1-copy "
                 f"(paper 98%)"))
    rows.append(("fig12/iops_8k/3unicast_kiops", u_iops / 1e3,
                 f"gleam_gain={g_iops / u_iops:.2f}x (paper 2.7x)"))
    rows.append(("fig12/iops_8k/1copy_kiops", o_iops / 1e3, "ideal"))
    # Absolute fig13 latencies are only meaningful on the packet
    # engine: the fluid model completes the whole concurrent batch at
    # once, so per-IO latency ~= batch span (~2x the packet engine's
    # mean).  The SAVING ratio survives; flag the rows.
    note = "" if engine == "packet" else \
        f" [engine={engine}: batch-concurrent latency]"
    for kb, paper in ((64, 40), (512, 60)):
        _, gl = _gleam_metrics(gleam[kb << 10])
        _, ul = _unicast_metrics(uni[(kb << 10, 3)], 3)
        rows.append((f"fig13/lat_{kb}k/gleam_us", gl * 1e6, note.strip()))
        rows.append((f"fig13/lat_{kb}k/3unicast_us", ul * 1e6,
                     f"saving={100 * (1 - gl / ul):.0f}% "
                     f"(paper ~{paper}%)" + note))
    return rows
