"""Figs. 12-13 — storage data replication: 3-copy WRITE throughput (IOPS)
and single-IO latency, Gleam vs 3-unicasts vs 1-copy ideal.

Paper claims: 1.167M IOPS (Gleam) vs 0.413M (3-unicasts) vs 1.188M
(1-copy) at 8KB IOs; latency -40% (64KB) and -60% (512KB).

Both workloads run through the SimEngine layer: Gleam replication is one
one-to-many WRITE per IO (MR_UPDATE preamble included, §3.3); the
baseline submits one unicast WRITE per copy.  IOPS and IO latency are
computed from the MsgRecords exactly as core/metrics.py defines them.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.metrics import iops, mean_io_latency

MEMBERS = ["h0", "h1", "h2", "h3"]


def gleam_run(io_bytes, n_ios, engine="packet"):
    eng = make_engine(engine, fattree.testbed())
    recs = [eng.add_write(MEMBERS, io_bytes) for _ in range(n_ios)]
    eng.run(timeout=120.0)
    assert all(r.complete for r in recs)
    return iops(recs, recs[0].t_submit), mean_io_latency(recs)


def unicast_run(io_bytes, n_ios, copies=3, engine="packet"):
    eng = make_engine(engine, fattree.testbed())
    groups = [[eng.add_unicast("h0", f"h{c + 1}", io_bytes)
               for c in range(copies)] for _ in range(n_ios)]
    eng.run(timeout=120.0)
    t0 = groups[0][0].t_submit
    assert all(r.complete for g in groups for r in g)
    # an IO completes when its LAST copy's CQE lands
    times = [max(r.t_sender_cqe for r in g) for g in groups]
    dt = max(times) - t0
    lat = sum(times) / n_ios - t0
    return n_ios / dt, lat


def run(rows, engine="packet"):
    n = 300
    g_iops, _ = gleam_run(8 << 10, n, engine)
    u_iops, _ = unicast_run(8 << 10, n, engine=engine)
    o_iops, _ = unicast_run(8 << 10, n, copies=1, engine=engine)
    rows.append(("fig12/iops_8k/gleam_kiops", g_iops / 1e3,
                 f"{100 * g_iops / o_iops:.0f}% of 1-copy "
                 f"(paper 98%)"))
    rows.append(("fig12/iops_8k/3unicast_kiops", u_iops / 1e3,
                 f"gleam_gain={g_iops / u_iops:.2f}x (paper 2.7x)"))
    rows.append(("fig12/iops_8k/1copy_kiops", o_iops / 1e3, "ideal"))
    # Absolute fig13 latencies are only meaningful on the packet
    # engine: the fluid model completes the whole concurrent batch at
    # once, so per-IO latency ~= batch span (~2x the packet engine's
    # mean).  The SAVING ratio survives; flag the rows.
    note = "" if engine == "packet" else \
        f" [engine={engine}: batch-concurrent latency]"
    for kb, paper in ((64, 40), (512, 60)):
        _, gl = gleam_run(kb << 10, 30, engine)
        _, ul = unicast_run(kb << 10, 30, engine=engine)
        rows.append((f"fig13/lat_{kb}k/gleam_us", gl * 1e6, note.strip()))
        rows.append((f"fig13/lat_{kb}k/3unicast_us", ul * 1e6,
                     f"saving={100 * (1 - gl / ul):.0f}% "
                     f"(paper ~{paper}%)" + note))
    return rows
