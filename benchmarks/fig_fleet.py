"""Fleet-scale multi-tenant fabric sharing — per-tenant SLO tails and
connection-state cost as tenant count grows (the fleet sweep plane's
headline benchmark; no single paper figure — this is the §6 "switch
table memory" arithmetic and the §5 contention results run TOGETHER).

Each point packs N tenants' multicast groups (overlapping trees by
construction) plus background mesh/incast RC traffic into ONE contended
scenario (``apps/fleet.py``), runs it on the packet engine AND the flow
engine, and reports:

- worst per-tenant p99 JCT (packet, ms) with the packet-vs-flow
  divergence in the derived column (gate: <= 10%,
  ``tools/check_fleet.py``);
- connection-state accounting: peak QPs on any NIC, total MFT group
  entries and bytes across the fabric (the flow side derives these
  analytically; per-host QP counts must match the packet engine's
  measured census exactly — tests/test_fleet.py);
- staging-cache hit rate for the flow sweep (the cached staging plane
  is what makes the 1k-group point in BENCH_flowsim.json feasible);
- one LRU-pressure point: registration churn (many tenants' groups
  registered through capacity-pinned switch tables), reporting the
  evictions/salvages the fabric eats while the newest tenant still
  broadcasts cleanly.

The sweep starts at 4 tenants: below ~8 concurrent groups the fabric
is so sparse that the p99 of a tenant is the max of 2 samples and the
packet-vs-flow gap is dominated by which ECMP tree each engine happens
to pick, not by contention — the regime the fluid model is for begins
when trees actually overlap.
"""
from __future__ import annotations

from repro.apps.fleet import FleetSpec, mft_pressure_report, run_fleet
from repro.core import fattree

TENANTS = (4, 8)
GROUPS_PER_TENANT = 2
GROUP_SIZE = 6
NBYTES = 2 << 20
BG = dict(bg_unicasts=8, bg_incasts=2, bg_fan_in=4, bg_nbytes=1 << 20)
PRESSURE_GROUPS = 48           # registrations churned through the fabric
PRESSURE_CAPACITY = 8          # table slots per switch under pressure


def _fabric():
    return fattree.fat_tree(n_pods=2, leaves_per_pod=4, hosts_per_leaf=4,
                            aggs_per_pod=4, bw=100 * fattree.GBPS)


def _spec(n_tenants: int) -> FleetSpec:
    return FleetSpec(n_tenants=n_tenants,
                     groups_per_tenant=GROUPS_PER_TENANT,
                     group_size=GROUP_SIZE, nbytes=NBYTES, **BG)


def _worst_tenant(report) -> float:
    return max(q["p99"] for ph, q in report["tenants"].items()
               if ph.startswith("tenant-"))


def run(rows, engine="packet", workers=0):
    # both engines always run — the divergence IS the result; --engine
    # only picks which flow solver the packet run is compared against
    flow_engine = engine if engine.startswith("flow") else "flow"
    for n in TENANTS:
        spec = _spec(n)
        rp = run_fleet("packet", _fabric(), spec, seed=1)
        rf = run_fleet(flow_engine, _fabric(), spec)
        p99p, p99f = _worst_tenant(rp), _worst_tenant(rf)
        div = abs(p99p - p99f) / max(p99p, p99f)
        cp, cf = rp["census"], rf["census"]
        rows.append((
            f"figfleet/{n}tenants/packet_worst_p99_ms", p99p * 1e3,
            f"flow={p99f * 1e3:.4f}ms div={100 * div:.1f}% "
            f"nic_qp_peak={cp['nic_qp_peak']} "
            f"mft_groups={cp['mft_groups_total']} "
            f"mft_bytes={cp['mft_bytes_total']} "
            f"flow_census_qp_match="
            f"{cf['qp_per_host'] == cp['qp_per_host']} "
            f"cache_hit_rate={rf['staging']['hit_rate']:.2f} "
            f"({n}x{GROUPS_PER_TENANT} groups of {GROUP_SIZE} + "
            f"bg mesh/incast)"))
    # LRU pressure: registration churn through capacity-pinned tables
    pr = mft_pressure_report(_fabric(), n_groups=PRESSURE_GROUPS,
                             group_size=GROUP_SIZE,
                             capacity=PRESSURE_CAPACITY, seed=1)
    rows.append((
        f"figfleet/churn{PRESSURE_GROUPS}_cap{PRESSURE_CAPACITY}/"
        "mft_evictions", float(pr["evictions"]),
        f"salvages={pr['salvages']} "
        f"occupancy_peak={pr['occupancy_peak']}/{PRESSURE_CAPACITY} "
        f"last_group_ok={pr['last_group_ok']} "
        f"last_group_jct_ms={pr['last_group_jct'] * 1e3:.4f} "
        f"({PRESSURE_GROUPS} registrations of {GROUP_SIZE} through "
        f"{PRESSURE_CAPACITY}-slot tables)"))
    return rows
