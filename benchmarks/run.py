"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (value is us/ms/IOPS as named).

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run fig09 fig14  # a subset
"""
from __future__ import annotations

import importlib
import sys
import time

MODULES = [
    "fig09_mpi_bcast",       # Fig. 9  MPI_Bcast JCT vs message size
    "fig10_11_hpl",          # Figs. 10-11 HPL PB/RS JCT
    "fig12_13_storage",      # Figs. 12-13 replication IOPS + IO latency
    "fig14_scale",           # Fig. 14 large-scale fat-tree JCT (fluid)
    "fig15_16_loss",         # Figs. 15-16 loss tolerance / goodput
    "collective_schedules",  # adapted layer: ICI schedule comparison
]


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    wanted = [m for m in MODULES
              if not argv or any(a in m for a in argv)]
    rows: list = []
    print("name,value,derived")
    for name in wanted:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        before = len(rows)
        try:
            mod.run(rows)
        except Exception as e:  # noqa: BLE001 — report, keep going
            rows.append((f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}"))
        for n, v, d in rows[before:]:
            print(f"{n},{v:.3f},{d}")
        print(f"# {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
