"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (value is us/ms/IOPS as named).

    PYTHONPATH=src python -m benchmarks.run                    # everything
    PYTHONPATH=src python -m benchmarks.run fig09 fig14        # a subset
    PYTHONPATH=src python -m benchmarks.run --engine flow      # fluid model
    PYTHONPATH=src python -m benchmarks.run fig09 --engine flow \
        --transport multiunicast --group 1024                  # at scale

The ``--engine`` flag selects the simulation backend for every module
that supports backend selection (see ``core/engine.py``):

- ``packet``  (default) — the cycle-accurate per-packet reference.
  Highest fidelity: protocol effects (go-back-N recovery, DCQCN, ACK
  clocking, loss) are simulated for real.  Cost grows with
  bytes x hosts; practical up to a few hundred hosts.
- ``flow``    — vectorized max-min fair fluid flows (JAX solver when
  available).  No per-packet protocol effects, but validated against
  the packet engine within 10% on small topologies
  (tests/test_engines.py); runs 1024+-host sweeps in seconds.
- ``flow-np`` — same fluid model, numpy solver (no JAX needed).

``--transport`` picks the baseline strategy the figures compare Gleam
against — any name in the Workload-IR transport registry
(``multiunicast`` | ``ring`` | ``binary-tree``; see
``core/workload.py``).  Because both engines lower every transport,
the Fig. 9-style comparison curves run at Fig. 14 scale:
``--engine flow --transport ring --group 1024``.  Modules that pin a
specific baseline shape (fig12's 3-unicast replication, fig15's
ring-under-loss) ignore the flag.

Modules that fundamentally need packet fidelity (fig15's loss sweeps)
note it in their ``derived`` column and run the packet engine regardless.
Each module's ``run(rows, engine=..., ...)`` appends rows and returns
them; orchestrator flags a module does not declare are not passed.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import sys
import time

from repro.core.engine import ENGINE_CHOICES
from repro.core.workload import TRANSPORT_CHOICES

MODULES = [
    "fig09_mpi_bcast",       # Fig. 9  MPI_Bcast JCT vs message size
    "fig10_11_hpl",          # Figs. 10-11 HPL PB/RS JCT
    "fig12_13_storage",      # Figs. 12-13 replication IOPS + IO latency
    "fig14_scale",           # Fig. 14 large-scale fat-tree JCT (fluid)
    "fig15_16_loss",         # Figs. 15-16 loss tolerance / goodput
    "fig_churn",             # membership churn: JCT + recovery time
    "fig_faults",            # fault injection: recovery latency + JCT
    "fig_matrix",            # churn x loss x faults grid at fig14 scale
    "fig_apps",              # app plane: train-step time + serve QPS/p99
    "fig_fleet",             # fleet plane: multi-tenant SLOs + census
    "collective_schedules",  # adapted layer: ICI schedule comparison
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("filters", nargs="*",
                    help="substring filters over module names")
    ap.add_argument("--engine", choices=ENGINE_CHOICES, default="packet",
                    help="simulation backend (default: packet)")
    ap.add_argument("--transport", default=None,
                    choices=[t for t in TRANSPORT_CHOICES if t != "gleam"],
                    help="baseline transport for the comparison figures "
                         "(default: each figure's paper baseline)")
    ap.add_argument("--group", type=int, default=None,
                    help="group size for figures that sweep it (fig09; "
                         "default: the paper's testbed size)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="independent repetitions per point for figures "
                         "that report mean±std (fig15/16; default 3)")
    ap.add_argument("--workers", type=int, default=None,
                    help="scenario-parallel worker processes for packet-"
                         "engine batches (0 = one per CPU, 1 = serial; "
                         "default 0 where supported)")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)
    wanted = [m for m in MODULES
              if not args.filters or any(a in m for a in args.filters)]
    flags = {"engine": args.engine}
    if args.transport is not None:
        flags["transport"] = args.transport
    if args.group is not None:
        flags["group"] = args.group
    if args.seeds is not None:
        flags["seeds"] = args.seeds
    if args.workers is not None:
        flags["workers"] = args.workers
    rows: list = []
    print("name,value,derived")
    for name in wanted:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        before = len(rows)
        accepted = inspect.signature(mod.run).parameters
        kw = {k: v for k, v in flags.items() if k in accepted}
        try:
            mod.run(rows, **kw)
        except Exception as e:  # noqa: BLE001 — report, keep going
            rows.append((f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}"))
        for n, v, d in rows[before:]:
            print(f"{n},{v:.3f},{d}")
        print(f"# {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
