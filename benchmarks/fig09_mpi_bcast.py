"""Fig. 9 — MPI_Bcast JCT vs message size, Gleam vs an overlay transport.

Paper claims: 1.6x at 64KB, ~2x at 1GB, stably ~50% JCT reduction for
messages >= 128KB (one-to-three multicast on the 100Gbps testbed).

The comparison is declared as Workload IR: per message size, TWO
workloads — a gleam bcast and a baseline bcast over ``transport``
(default ``binary-tree`` — OpenMPI's tuned-collective choice at small
rank counts is the (split-)binary tree, segmented for pipelining) —
kept separate so the two systems never share bandwidth.
The whole sweep is a single ``run_workloads`` call, so on the flow
engine every size solves in one vmapped batch — and because every
transport lowers on every engine, the same declaration sweeps
``--transport multiunicast|ring|binary-tree`` at ``--group 1024`` and
beyond (the regime of Fig. 14) with ``--engine flow``.

Standalone:

    PYTHONPATH=src python benchmarks/fig09_mpi_bcast.py
    PYTHONPATH=src python benchmarks/fig09_mpi_bcast.py \
        --engine flow --transport multiunicast --group 1024
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):      # `python benchmarks/fig09_mpi_bcast.py`
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import TRANSPORT_CHOICES, Workload

# paper sweeps 4KB .. 1GB; we stop at 64MB to keep the event count sane
SIZES = [4 << 10, 64 << 10, 256 << 10, 1 << 20, 8 << 20, 64 << 20]

SEGMENT = 128 << 10     # OpenMPI-style pipeline segment size

# Per-MPI_Bcast software latency added to BOTH systems: verbs post/poll,
# MPI matching, cache effects (§2.3's RX-stack/CPU/TX-stack discussion).
# ~15-20us is typical for a small collective on a 100G RoCE host; this
# floor is what makes the paper's 64KB acceleration 1.6x rather than
# the pure-wire 3x (the wire-time ratio our simulator measures alone).
MPI_SW_LATENCY = 18e-6


def _label(nbytes: int) -> str:
    return (f"{nbytes >> 10}KB" if nbytes < (1 << 20)
            else f"{nbytes >> 20}MB")


def declare(members, transport: str, sizes=SIZES):
    """The Fig. 9 sweep as Workload IR: per message size, TWO workloads
    — the gleam bcast and the baseline bcast — because each system is
    measured as an independent scenario (they never share bandwidth)."""
    workloads = []
    for nbytes in sizes:
        # OpenMPI-style segmented pipelining: chunk count scales with
        # the message until the 64-segment cap
        chunks = max(1, min(nbytes // SEGMENT, 64))
        wg = Workload(f"fig09/{_label(nbytes)}/gleam")
        wg.bcast(members, nbytes, transport="gleam")
        wb = Workload(f"fig09/{_label(nbytes)}/{transport}")
        wb.bcast(members, nbytes, transport=transport, chunks=chunks)
        workloads += [wg, wb]
    return workloads


def run(rows, engine="packet", transport="binary-tree", group=4,
        sizes=None):
    sizes = list(sizes or SIZES)
    members = [f"h{i}" for i in range(group)]
    eng = make_engine(engine, fattree.testbed(n_hosts=group))
    workloads = declare(members, transport, sizes)
    recss = eng.run_workloads(workloads, timeout=120.0)
    for i, nbytes in enumerate(sizes):
        (rg,), (rb,) = recss[2 * i], recss[2 * i + 1]
        jg = rg.jct(group - 1) + MPI_SW_LATENCY
        jb = rb.jct(group - 1) + MPI_SW_LATENCY
        label = _label(nbytes)
        rows.append((f"fig09/bcast_{label}/gleam_us", jg * 1e6,
                     f"engine={eng.name} n={group}"))
        rows.append((f"fig09/bcast_{label}/{transport}_us", jb * 1e6,
                     f"accel={jb / jg:.2f}x (paper vs OpenMPI: "
                     f"1.6x@64KB, 2x@1GB)"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--engine", default="packet",
                    choices=("packet", "flow", "flow-np"))
    ap.add_argument("--transport", default="binary-tree",
                    choices=[t for t in TRANSPORT_CHOICES if t != "gleam"],
                    help="baseline transport to compare Gleam against")
    ap.add_argument("--group", type=int, default=4,
                    help="group size (paper testbed: 4; the flow engine "
                         "sweeps 1024+)")
    args = ap.parse_args(argv)
    rows: list = []
    run(rows, engine=args.engine, transport=args.transport,
        group=args.group)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.3f},{d}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
