"""Fig. 9 — MPI_Bcast JCT vs message size, Gleam vs OpenMPI-style overlay.

Paper claims: 1.6x at 64KB, ~2x at 1GB, stably ~50% JCT reduction for
messages >= 128KB (one-to-three multicast on the 100Gbps testbed).

The OpenMPI baseline is the pipelined-ring overlay (segmented bcast, the
tuned-collective behaviour for large messages); small messages use the
binomial tree, as OpenMPI's decision rules do.
"""
from __future__ import annotations

from benchmarks.common import (BASELINES, baseline_bcast_jct,
                               gleam_bcast_jct)

MEMBERS = ["h0", "h1", "h2", "h3"]
# paper sweeps 4KB .. 1GB; we stop at 64MB to keep the event count sane
SIZES = [4 << 10, 64 << 10, 256 << 10, 1 << 20, 8 << 20, 64 << 20]


SEGMENT = 128 << 10     # OpenMPI-style pipeline segment size

# Per-MPI_Bcast software latency added to BOTH systems: verbs post/poll,
# MPI matching, cache effects (§2.3's RX-stack/CPU/TX-stack discussion).
# ~15-20us is typical for a small collective on a 100G RoCE host; this
# floor is what makes the paper's 64KB acceleration 1.6x rather than
# the pure-wire 3x (the wire-time ratio our simulator measures alone).
MPI_SW_LATENCY = 18e-6


def run(rows, engine="packet"):
    for nbytes in SIZES:
        jg, _, _ = gleam_bcast_jct(MEMBERS, nbytes, engine=engine)
        # OpenMPI tuned bcast at 4 ranks: (split-)binary tree, segmented
        # for pipelining — the root's degree-2 fanout is the steady-state
        # bottleneck the paper's 'stably ~50% less JCT >= 128KB' reflects.
        chunks = max(1, min(nbytes // SEGMENT, 64))
        jo, _, _ = baseline_bcast_jct(BASELINES["bintree"], MEMBERS,
                                      nbytes, chunks=chunks, engine=engine)
        jg += MPI_SW_LATENCY
        jo += MPI_SW_LATENCY
        label = (f"{nbytes >> 10}KB" if nbytes < (1 << 20)
                 else f"{nbytes >> 20}MB")
        rows.append((f"fig09/bcast_{label}/gleam_us", jg * 1e6, ""))
        rows.append((f"fig09/bcast_{label}/openmpi_us", jo * 1e6,
                     f"accel={jo / jg:.2f}x (paper: 1.6x@64KB, "
                     f"2x@1GB)"))
    return rows
