"""Shared helpers for the paper-figure benchmarks.

Every module reproduces one paper artifact and returns a list of CSV rows
``(name, value, derived)``; ``benchmarks.run`` orchestrates and prints.
All simulations go through the backend-pluggable SimEngine layer
(``core/engine.py``): ``engine="packet"`` runs the same packet-level
event loop as the tests, ``engine="flow"`` the vectorized fluid model.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.baselines import (BASELINE_KINDS, BinaryTreeBcast,
                                  MultiUnicastBcast, RingBcast,
                                  flow_baseline_jct)
from repro.core.engine import make_engine
from repro.core.gleam import GleamNetwork

BASELINES = {
    "multiunicast": MultiUnicastBcast,
    "ring": RingBcast,
    "bintree": BinaryTreeBcast,
}
_KIND_OF = {v: k for k, v in BASELINES.items()}


def gleam_bcast_jct(members, nbytes, *, topo=None, engine="packet",
                    timeout=30.0, **net_kw):
    """JCT of one Gleam multicast bcast on the chosen backend.

    Returns ``(jct_seconds, engine, record)`` — callers that need
    backend internals (switch tables, retransmit counters) can reach
    them through ``engine`` on the packet backend.
    """
    eng = make_engine(engine, topo or fattree.testbed(n_hosts=len(members)),
                      **net_kw)
    rec = eng.add_bcast(members, nbytes)
    eng.run(timeout)
    return rec.jct(len(members) - 1), eng, rec


def baseline_bcast_jct(cls_or_kind, members, nbytes, *, topo=None, chunks=8,
                       engine="packet", timeout=30.0, **net_kw):
    """JCT of an overlay baseline bcast on the chosen backend.

    ``cls_or_kind`` is a baseline class (packet path) or one of
    ``BASELINE_KINDS``; returns ``(jct_seconds, engine_or_net, obj)``.
    """
    kind = (_KIND_OF[cls_or_kind] if cls_or_kind in _KIND_OF
            else cls_or_kind)
    assert kind in BASELINE_KINDS, kind
    topo = topo or fattree.testbed(n_hosts=len(members))
    if engine == "packet":
        net = GleamNetwork(topo, **net_kw)
        cls = BASELINES[kind]
        b = cls(net, members, chunks=chunks) if cls is not MultiUnicastBcast \
            else cls(net, members)
        b.start(nbytes)
        return b.run(timeout=timeout), net, b
    eng = make_engine(engine, topo, **net_kw)
    jct = flow_baseline_jct(eng, kind, members, nbytes, chunks=chunks)
    return jct, eng, None
