"""Shared helpers for the paper-figure benchmarks.

Every module reproduces one paper artifact and returns a list of CSV rows
``(name, value, derived)``; ``benchmarks.run`` orchestrates and prints.
All simulations run the same packet-level engine as the tests.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.baselines import (BinaryTreeBcast, MultiUnicastBcast,
                                  RingBcast)
from repro.core.gleam import GleamNetwork


def gleam_bcast_jct(members, nbytes, *, topo=None, timeout=30.0, **net_kw):
    net = GleamNetwork(topo or fattree.testbed(n_hosts=len(members)),
                       **net_kw)
    g = net.multicast_group(members)
    g.register()
    rec = g.bcast(nbytes)
    return g.run_until_delivered(rec, timeout=timeout), net, g


def baseline_bcast_jct(cls, members, nbytes, *, topo=None, chunks=8,
                       timeout=30.0, **net_kw):
    net = GleamNetwork(topo or fattree.testbed(n_hosts=len(members)),
                       **net_kw)
    b = cls(net, members, chunks=chunks) if cls is not MultiUnicastBcast \
        else cls(net, members)
    b.start(nbytes)
    return b.run(timeout=timeout), net, b


BASELINES = {
    "multiunicast": MultiUnicastBcast,
    "ring": RingBcast,
    "bintree": BinaryTreeBcast,
}
