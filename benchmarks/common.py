"""Shared helpers for the paper-figure benchmarks.

Every module reproduces one paper artifact and returns a list of CSV rows
``(name, value, derived)``; ``benchmarks.run`` orchestrates and prints.
All simulations go through the backend-pluggable SimEngine layer
(``core/engine.py``) and stage their operations as Workload-IR
``GroupOp``s (``core/workload.py``): ``engine="packet"`` runs the same
packet-level event loop as the tests, ``engine="flow"`` the vectorized
fluid model — and ``transport=`` picks the strategy carrying the bytes
(``gleam`` vs the §2.3 overlays), on EITHER engine.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.baselines import (BinaryTreeBcast, MultiUnicastBcast,
                                  RingBcast)
from repro.core.engine import make_engine
from repro.core.workload import GroupOp

# legacy name -> transport mapping (pre-IR callers passed classes)
BASELINES = {
    "multiunicast": MultiUnicastBcast,
    "ring": RingBcast,
    "bintree": BinaryTreeBcast,
}
_KIND_OF = {v: k for k, v in BASELINES.items()}


def bcast_jct(members, nbytes, *, transport="gleam", topo=None,
              engine="packet", chunks=8, timeout=30.0, **net_kw):
    """JCT of one bcast over ``transport`` on the chosen backend.

    Returns ``(jct_seconds, engine, record)`` — callers that need
    backend internals (switch tables, retransmit counters) can reach
    them through ``engine`` on the packet backend.
    """
    eng = make_engine(engine, topo or fattree.testbed(n_hosts=len(members)),
                      **net_kw)
    rec = eng.stage(GroupOp("bcast", tuple(members), nbytes,
                            transport=transport, chunks=chunks))
    eng.run(timeout)
    return rec.jct(len(members) - 1), eng, rec


def gleam_bcast_jct(members, nbytes, *, topo=None, engine="packet",
                    timeout=30.0, **net_kw):
    """JCT of one Gleam multicast bcast on the chosen backend."""
    return bcast_jct(members, nbytes, transport="gleam", topo=topo,
                     engine=engine, timeout=timeout, **net_kw)


def baseline_bcast_jct(cls_or_kind, members, nbytes, *, topo=None, chunks=8,
                       engine="packet", timeout=30.0, **net_kw):
    """JCT of an overlay baseline bcast on the chosen backend.

    ``cls_or_kind`` is a baseline class (legacy) or one of
    ``BASELINE_KINDS`` / transport names; both engines now lower the
    transport through ``stage()``, so the same call works at packet
    and fluid fidelity.  Returns ``(jct_seconds, engine, record)``.
    """
    kind = (_KIND_OF[cls_or_kind] if cls_or_kind in _KIND_OF
            else cls_or_kind)
    return bcast_jct(members, nbytes, transport=kind, topo=topo,
                     engine=engine, chunks=chunks, timeout=timeout, **net_kw)
