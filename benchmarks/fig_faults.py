"""Fault injection — recovery latency and JCT vs fault rate, group size
and scheme, on BOTH engines (the headline for the ISSUE-7 fault plane;
the paper's failure evaluation stops at a single silent receiver crash,
Appendix B).

Scenario: one 1MB bcast per point on a 2-pod fat-tree with two agg
planes (every leaf keeps a surviving uplink under any single fault),
with timed faults riding the op (Workload-IR ``FaultEvent``s):

- the **fault-rate axis** injects ``link_flap``s at interval ``1/rate``
  on the member leaves' plane-0 uplinks — at low rates the flap lands
  after the message completes (invisible to JCT, as it should be), at
  high rates the stream takes real RTO stalls and the tree is repaired
  mid-flight;
- the **recovery axis** runs one scenario per fault class
  (link_down / switch_fail / host_gone_dark / master_crash) with the
  fault 3us into the stream.  Recovery is reported as the JCT penalty
  over the same point without the fault: RTO-bounded for fabric
  faults, ``link_detect``-bounded for a dark host (switch-originated
  teardown confirm, no master round trip), ``fail_detect``-bounded for
  a master crash (member-driven re-election).

Two correlated multi-fault rows ride each group size (``storm_cases``):
a plane-wide link storm and a whole-rack blast — several
``FaultEvent``s in ONE scenario, stressing concurrent repair instead
of the one-fault-at-a-time recovery axis.

Every point runs on the packet engine (real repair envelopes, bounded
retry, re-election) AND the flow engine (piecewise stall/dark
segments); the derived column carries the packet-vs-flow divergence —
the acceptance gate is <= 15% (tools/check_faults.py).  The overlay
row (``ring-dark``) exercises the relay-schedule repair path in
baselines.py: a mid-ring relay goes dark and its children are spliced
onto the dead relay's parent.

Each point runs on a FRESH engine (no shared ``run_many`` fabric):
Algorithm 4 balances tree edges across the agg planes by accumulated
port utilization, so a point's tree — and therefore whether a given
fault even touches it — would otherwise depend on its batch position.
On a fresh fabric both engines deterministically root the tree on
plane 0, which is where the fault targets aim.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import FaultEvent, GroupOp

NBYTES = 1 << 20
SIZES = (4, 8)
FAULT_RATES = (0.0, 2e3, 1e4, 5e4)      # fault events / second
N_FAULTS = 2                            # flaps along the rate axis
FLAP_DURATION = 20e-6
FAULT_AT = 3e-6                         # recovery-axis fault, 3us in


def build_topo():
    # 2 pods x 2 leaves x 4 hosts, two agg planes: any single link or
    # agg-switch fault leaves every leaf a surviving path
    return fattree.fat_tree(n_pods=2, leaves_per_pod=2, hosts_per_leaf=4,
                            aggs_per_pod=2)


def members_for(group: int):
    """Members interleaved across leaves so faults hit real tree edges:
    h0.0.0, h0.1.0, h1.0.0, h1.1.0, then the .1 hosts, ..."""
    hosts = [f"h{p}.{l}.{h}" for h in range(4)
             for p in range(2) for l in range(2)]
    return hosts[:group]


def flap_events(members, rate: float):
    """``link_flap``s at interval ``1/rate`` cycling over non-source
    member leaves' plane-0 uplinks (the fresh-fabric tree's plane;
    never both uplinks of one leaf at once — the plan must keep every
    member routable)."""
    if rate <= 0:
        return ()
    leaves = []
    for m in members[1:]:                       # skip the source's leaf
        leaf = f"L{m[1]}.{m[3]}"
        if leaf not in leaves:
            leaves.append(leaf)
    return tuple(
        FaultEvent("link_flap", (i + 1) / rate,
                   node=leaves[i % len(leaves)],
                   peer=f"A{leaves[i % len(leaves)][1]}.0",
                   duration=FLAP_DURATION)
        for i in range(N_FAULTS))


def recovery_cases(members):
    """(label, faults) per fault class, targeting the last member's
    plane-0 branch of the tree."""
    last = members[-1]
    leaf = f"L{last[1]}.{last[3]}"
    agg = f"A{last[1]}.0"
    return [
        ("link_down", (FaultEvent("link_down", FAULT_AT, node=leaf,
                                  peer=agg),)),
        ("switch_fail", (FaultEvent("switch_fail", FAULT_AT, node=agg),)),
        ("host_dark", (FaultEvent("host_gone_dark", FAULT_AT,
                                  node=last),)),
        ("master_crash", (FaultEvent("master_crash", FAULT_AT),)),
    ]


def storm_cases(members):
    """Correlated multi-``FaultEvent`` scenarios (blast radius > 1).

    - ``storm``: a correlated link storm — plane 0 drops across EVERY
      member rack within a microsecond, so the repair fan-out to
      plane 1 runs for all branches concurrently instead of one at a
      time (the fault plan is validated cumulatively: plane 1 keeps
      every member routable throughout);
    - ``rack-blast``: the last member's whole rack dies in one blast —
      every non-source member on that leaf goes dark back-to-back
      while the leaf's plane-0 uplink drops, exercising teardown
      cascades racing a link repair on the same branch.
    """
    leaves = []
    for m in members[1:]:                       # skip the source's leaf
        leaf = f"L{m[1]}.{m[3]}"
        if leaf not in leaves:
            leaves.append(leaf)
    storm = tuple(FaultEvent("link_down", FAULT_AT + i * 1e-7, node=lf,
                             peer=f"A{lf[1]}.0")
                  for i, lf in enumerate(leaves))
    last = members[-1]
    rack_leaf = f"L{last[1]}.{last[3]}"
    rack = [m for m in members[1:] if f"L{m[1]}.{m[3]}" == rack_leaf]
    # the servers die first, then the ToR uplink drops — by the time
    # the link fault lands no live receiver sits behind it, so neither
    # engine should charge a repair stall to the survivors
    blast = tuple(FaultEvent("host_gone_dark", FAULT_AT + i * 1e-7,
                             node=m)
                  for i, m in enumerate(rack))
    blast += (FaultEvent("link_down", FAULT_AT + len(rack) * 1e-7,
                         node=rack_leaf, peer=f"A{last[1]}.0"),)
    return [("storm", storm), ("rack-blast", blast)]


def _points(group):
    members = members_for(group)
    pts = [(f"r{rate:g}", GroupOp("bcast", members, NBYTES,
                                  faults=flap_events(members, rate)))
           for rate in FAULT_RATES]
    pts += [(label, GroupOp("bcast", members, NBYTES, faults=faults))
            for label, faults in recovery_cases(members)]
    pts += [(label, GroupOp("bcast", members, NBYTES, faults=faults))
            for label, faults in storm_cases(members)]
    # overlay relay repair: a mid-ring relay goes dark
    pts.append(("ring-dark", GroupOp(
        "bcast", members, NBYTES, transport="ring",
        faults=(FaultEvent("host_gone_dark", FAULT_AT,
                           node=members[len(members) // 2]),))))
    pts.append(("ring-r0", GroupOp("bcast", members, NBYTES,
                                   transport="ring")))
    return pts


def _sweep(engine_name, group, timeout=60.0):
    """One fresh engine per point (see module docstring); returns
    {label: (jct_seconds, error)}."""
    out = {}
    for label, op in _points(group):
        eng = make_engine(engine_name, build_topo())
        rec = eng.stage(op)
        eng.run(timeout=timeout)
        out[label] = (rec.jct(len(op.surviving_receivers())), rec.error)
    return out


def run(rows, engine="packet", sizes=SIZES):
    # both engines always run — the packet-vs-flow divergence IS the
    # result; --engine only picks which flow solver to compare against
    flow_engine = engine if engine.startswith("flow") else "flow"
    for group in sizes:
        jct_p = _sweep("packet", group)
        jct_f = _sweep(flow_engine, group)
        for rate in FAULT_RATES:
            label = f"r{rate:g}"
            (jp, ep), (jf, _) = jct_p[label], jct_f[label]
            div = abs(jp - jf) / jp if jp > 0 else 0.0
            n_ev = len(flap_events(members_for(group), rate))
            rows.append((f"figfaults/jct_g{group}_{label}/packet_ms",
                         jp * 1e3,
                         f"flaps={n_ev} flow={jf * 1e3:.4f}ms "
                         f"div={100 * div:.1f}%"
                         + (f" error={ep}" if ep else "")))
        # recovery: each fault class's JCT penalty over the clean point
        for label, _ in recovery_cases(members_for(group)):
            rp = jct_p[label][0] - jct_p["r0"][0]
            rf = jct_f[label][0] - jct_f["r0"][0]
            div = abs(jct_p[label][0] - jct_f[label][0]) / jct_p[label][0]
            rows.append((f"figfaults/recovery_g{group}_{label}/packet_us",
                         rp * 1e6,
                         f"flow={rf * 1e6:.2f}us div={100 * div:.1f}%"))
        # correlated storms: several faults riding ONE scenario
        for label, faults in storm_cases(members_for(group)):
            rp = jct_p[label][0] - jct_p["r0"][0]
            rf = jct_f[label][0] - jct_f["r0"][0]
            div = abs(jct_p[label][0] - jct_f[label][0]) / jct_p[label][0]
            rows.append((f"figfaults/recovery_g{group}_{label}/packet_us",
                         rp * 1e6,
                         f"flow={rf * 1e6:.2f}us div={100 * div:.1f}% "
                         f"({len(faults)} correlated faults)"))
        # overlay: dead mid-ring relay, children respliced
        rp = jct_p["ring-dark"][0] - jct_p["ring-r0"][0]
        rf = jct_f["ring-dark"][0] - jct_f["ring-r0"][0]
        div = (abs(jct_p["ring-dark"][0] - jct_f["ring-dark"][0])
               / jct_p["ring-dark"][0])
        rows.append((f"figfaults/recovery_g{group}_ring-dark/packet_us",
                     rp * 1e6,
                     f"flow={rf * 1e6:.2f}us div={100 * div:.1f}% "
                     f"(overlay relay resplice)"))
    return rows
