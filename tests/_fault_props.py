"""Shared drivers for the fault-plane invariants, used by BOTH the
hypothesis property tests (``test_protocol_properties``, CI) and the
deterministic cases in ``test_faults`` (run everywhere — hypothesis is
an optional dependency).

Two ISSUE-7 acceptance properties, as executable drivers:

- **re-election convergence** — any (valid) sequence of master crashes
  ends with exactly one live master, the lowest-rank survivor, the
  stream complete for every surviving receiver, and no switch holding
  an orphaned MFT entry for a dead host;
- **bounded retry** — with a retry cap set, a permanently severed path
  costs at most ``cap`` unproductive RTO replays before the QP parks
  in a TERMINAL error state surfaced on the message record: bounded
  work, explicit attributable failure, never a hang.
"""
from __future__ import annotations

from repro.core import fattree
from repro.core.gleam import DEFAULT_FAIL_DETECT, GleamNetwork

MEMBERS = ["h0", "h1", "h2", "h3"]
NBYTES = 1 << 17

# master crashes must be spaced by at least the re-election delay: a
# second crash before the survivor took over would target a corpse
MIN_CRASH_GAP = DEFAULT_FAIL_DETECT + 1e-4


def run_reelection_case(crash_offsets, nbytes=NBYTES):
    """Crash the current master at each offset (offsets must honor
    ``MIN_CRASH_GAP``); assert the group converges."""
    assert all(b - a >= MIN_CRASH_GAP
               for a, b in zip(crash_offsets, crash_offsets[1:]))
    assert len(crash_offsets) <= len(MEMBERS) - 2   # survivor remains
    net = GleamNetwork(fattree.fig4())
    g = net.multicast_group(MEMBERS, max_retries=7)
    g.register()
    sim = net.sim
    rec = g.bcast(nbytes, now=0.0)
    for at in crash_offsets:
        sim.schedule(at, lambda now: g.master_crash(now=now))
    sim.run(until=max(crash_offsets) + 0.05)

    dead = set(MEMBERS) - set(g.members)
    assert len(dead) == len(crash_offsets)
    # exactly one live master: the lowest-rank survivor holds source +
    # teardown authority, and is actually alive
    assert g.master == g.source == g.members[0]
    assert not sim.hosts[g.master].dark
    assert g.qps[g.master].alive and not g.qps[g.master].error
    assert all(sim.hosts[m].dark for m in dead)
    # the stream completed for every surviving receiver — no wedge
    for m in g.members:
        if m != g.master:
            assert m in rec.t_deliver, f"{m} never delivered"
    assert rec.t_sender_cqe > 0 and not rec.error
    # no orphaned MFT entries: no switch still indexes a dead host,
    # and no entry sits outside the group's live port refs
    live_ips = {g.qps[m].ip for m in g.members}
    for name, sw in sim.switches.items():
        t = sw.tables.get(g.group_ip)
        if t is None:
            continue
        orphans = set(t.member_port) - live_ips
        assert not orphans, f"{name} still indexes dead ips {orphans}"
    # full teardown leaves nothing behind
    g.close()
    for name, sw in sim.switches.items():
        assert sw.tables.get(g.group_ip) is None, f"{name} leaked a table"
    return rec


def run_bounded_retry_case(cap, sever_at, nbytes=NBYTES):
    """Sever every uplink of the source's access leaf at ``sever_at``
    with NO repair; assert bounded work and a terminal, attributable
    error (or a clean completion if the message beat the sever)."""
    net = GleamNetwork(fattree.fig4())
    g = net.multicast_group(MEMBERS, max_retries=cap)
    g.register()
    sim = net.sim
    rec = g.bcast(nbytes, now=0.0)
    leaf = net.topo.ports["h0"][0][0]

    def sever(now):
        for p in sorted(net.topo.ports[leaf]):
            peer = net.topo.ports[leaf][p][0]
            if not peer.startswith("h"):
                sim.link_down(leaf, peer)

    sim.schedule(sever_at, sever)
    sim.run(until=sever_at + 2.0)
    qp = g.qps["h0"]
    if not qp.error:
        # everything (incl. the final ACK sweep) beat the sever
        assert rec.t_sender_cqe > 0 and not rec.error
        return rec
    assert qp.error == "retry_exceeded"
    assert rec.error == "retry_exceeded"
    assert not qp.alive                     # out of service
    # the budget is the budget: cap unproductive replays, then the
    # (cap+1)-th RTO enters error WITHOUT another replay
    assert qp.retries == cap + 1
    # each replay resends at most the outstanding window once
    assert qp.retransmitted <= cap * qp.window
    # terminal: more simulated time changes nothing
    sent, deadline = qp.retransmitted, qp.timer_deadline
    sim.run(until=sim.now + 1.0)
    assert qp.retransmitted == sent
    assert qp.error == "retry_exceeded" and not qp.alive
    assert qp.timer_deadline == deadline
    return rec
