"""Differential calibration harness for the flow-engine loss/DCQCN
model (ISSUE 6).

The fluid engines carry an expected-value correction for go-back-N
retransmission and DCQCN rate reduction (``core/flowsim.py``,
``kernels/maxmin.py:loss_factors``).  This file proves it three ways:

- **differential**: flow-engine JCT within 15% of fixed-seed packet
  ground truth across the full calibration grid (gleam + multiunicast,
  groups 4/8, loss 1e-5..1e-2) — the packet side re-measured LIVE, so
  drift in either engine trips the test (the frozen-json twin gate is
  ``tools/check_fig15.py``);
- **bit-exactness**: with loss off, the flow engines take the exact
  pre-loss-model code path — results identical, both backends;
- **invariants** (deterministic seeded fuzz over the shared drivers in
  ``_loss_props.py``; hypothesis twins live in
  ``test_protocol_properties.py``): JCT monotone non-decreasing in
  loss, correction factors in (0, 1] (rates never negative / above the
  max-min allocation), go-back-N retransmission bounded by the window
  replay across PSN_MOD wrap, and the calibration constants pinned to
  the packet engine's actual DCQCN parameters.
"""
from __future__ import annotations

import os
import random
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:                # benchmarks/ lives at repo root
    sys.path.insert(0, REPO)

from benchmarks.fig15_16_loss import (FID_GROUPS, FID_LOSS_RATES,  # noqa: E402
                                      FID_TRANSPORTS, flow_jct, packet_gt)
from _loss_props import (run_e2e_retrans_case, run_factor_bounds_case,  # noqa: E402
                         run_gbn_replay_case, run_monotone_case)
from repro.core import fattree, flowsim, packet as pk  # noqa: E402
from repro.core.endpoint import QP, RateState  # noqa: E402
from repro.core.engine import make_engine  # noqa: E402
from repro.core.workload import GroupOp  # noqa: E402

TOL = 0.15          # calibration bound (observed worst ~11%)
ZERO_TOL = 0.001    # loss off => the engines' pre-existing agreement

GRID = [(t, g, l) for t in FID_TRANSPORTS for g in FID_GROUPS
        for l in FID_LOSS_RATES]


# ===================================================== differential grid

@pytest.mark.parametrize(
    "transport,group,loss", GRID,
    ids=[f"{t}-g{g}-loss{l:g}" for t, g, l in GRID])
def test_flow_jct_matches_packet_ground_truth(transport, group, loss):
    """Acceptance: flow vs packet JCT <= 15% at every calibration-grid
    point, the packet side a live multi-seed ``run_many`` mean."""
    jf = flow_jct(group, loss, transport)
    jp = packet_gt(group, loss, transport)
    assert jf == pytest.approx(jp, rel=ZERO_TOL if loss == 0.0 else TOL)


@pytest.mark.parametrize("engine", ["flow", "flow-np"])
def test_zero_loss_path_bit_identical(engine):
    """loss_rate=0 with ECN off must take the EXACT pre-loss-model code
    path: records equal to an engine built without loss kwargs at all."""
    members = [f"h{i}" for i in range(6)]
    outs = []
    for kw in ({}, {"loss_rate": 0.0}):
        eng = make_engine(engine, fattree.testbed(n_hosts=8), **kw)
        recs = [eng.stage(GroupOp("bcast", members, 1 << 20)),
                eng.stage(GroupOp("bcast", members, 1 << 18,
                                  transport="multiunicast", chunks=4)),
                eng.stage(GroupOp("unicast", ["h6", "h7"], 1 << 16))]
        eng.run()
        outs.append([(r.t_sender_cqe, sorted(r.t_deliver.items()))
                     for r in recs])
    assert outs[0] == outs[1]


def test_lossy_backends_agree():
    """The JAX solver's kernel path and the numpy twin implement the
    same model: lossy JCTs agree to solver precision."""
    for loss in (1e-4, 1e-2):
        jf = flow_jct(4, loss, "gleam", "flow")
        jn = flow_jct(4, loss, "gleam", "flow-np")
        assert jf == pytest.approx(jn, rel=1e-6)


def test_op_level_loss_overrides_engine_default():
    """GroupOp.loss_rate overrides the engine-wide rate per op (flow),
    and conflicting values on ONE packet fabric are rejected."""
    members = [f"h{i}" for i in range(4)]

    def jct_one(eng_kw, op_kw):
        eng = make_engine("flow", fattree.testbed(n_hosts=4), **eng_kw)
        rec = eng.stage(GroupOp("bcast", members, 1 << 20, **op_kw))
        eng.run()
        return rec.jct(3)

    j_clean = jct_one({}, {})
    j_lossy = jct_one({"loss_rate": 1e-2}, {})
    assert j_lossy > j_clean * 1.5           # loss visibly slows the op
    # op-level value wins over the engine default, in both directions
    assert jct_one({"loss_rate": 1e-2}, {"loss_rate": 0.0}) == j_clean
    assert jct_one({}, {"loss_rate": 1e-2}) == j_lossy
    peng = make_engine("packet", fattree.testbed(n_hosts=4), seed=1)
    peng.stage(GroupOp("bcast", members, 1 << 16, loss_rate=1e-3))
    with pytest.raises(ValueError, match="conflicting"):
        peng.stage(GroupOp("bcast", members, 1 << 16, loss_rate=1e-4))


def test_dcqcn_constants_pinned_to_packet_engine():
    """The fluid DCQCN equilibrium must be derived from the SAME
    parameters the packet engine's RateState/QP actually use — if one
    side is retuned, this fails before the calibration grid drifts."""
    rs = RateState(rate=1.0, peak=1.0)
    qp = QP(1, 1, 2, 3, link_bw=12.5e9)
    assert flowsim.DCQCN_MIN_RATE == rs.min_rate
    assert flowsim.DCQCN_RATE_NUM == pytest.approx(
        2.0 * rs.inc * qp.cnp_interval / rs.period)


def test_kernel_modes_agree():
    """loss_factors: interpret-mode Pallas kernel vs the jnp oracle."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.maxmin import loss_factors
    rng = np.random.default_rng(7)
    n_links, n_flows, hops = 9, 50, 3
    cap = np.append(rng.uniform(1e9, 3e10, n_links), np.inf)
    links = rng.integers(0, n_links, (n_flows, hops)).astype(np.int32)
    links[5:, 2] = n_links                   # sentinel padding column
    rates = rng.uniform(1e8, 2.5e10, n_flows)
    active = (rng.random(n_flows) < 0.8).astype(float)
    q = np.where(rng.random(n_flows) < 0.5,
                 rng.uniform(0.0, 0.3, n_flows), 0.0)
    wsq = rng.uniform(0.0, 1e-5, n_flows)
    wnd = np.full(n_flows, 512.0)
    ecn = (rng.random(n_flows) < 0.5).astype(float)
    args = tuple(jnp.asarray(a) for a in
                 (links, rates, active, cap, q, wsq, wnd, ecn))
    kw = dict(dcqcn_num=flowsim.DCQCN_RATE_NUM,
              dcqcn_min=flowsim.DCQCN_MIN_RATE)
    ref = loss_factors(*args, mode="ref", **kw)
    out = loss_factors(*args, mode="interpret", block_f=16, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6)
    assert np.all(np.asarray(ref) > 0.0)
    assert np.all(np.asarray(ref) <= 1.0)


# ========================================= invariants (seeded fuzz)

def test_jct_monotone_in_loss_seeded_fuzz():
    rng = random.Random(0x10551)
    for _ in range(12):
        run_monotone_case(group=rng.randint(2, 8),
                          transport=rng.choice(("gleam", "multiunicast",
                                                "ring")),
                          l1=rng.uniform(0.0, 2e-2),
                          l2=rng.uniform(0.0, 2e-2),
                          nbytes=rng.randrange(1 << 12, 1 << 20))


def test_loss_factor_bounds_seeded_fuzz():
    for seed in range(120):
        run_factor_bounds_case(seed)


def test_gbn_replay_bound_seeded_fuzz():
    """Bases biased to straddle the PSN_MOD wrap, like the agg-min
    churn fuzz in test_membership."""
    rng = random.Random(0x10552)
    for _ in range(150):
        base = rng.choice([rng.randrange(pk.PSN_MOD),
                           pk.PSN_MOD - rng.randrange(1, 700),
                           rng.randrange(700)])
        plan = [(rng.choice(["ack", "nack", "timeout"]),
                 rng.randrange(701)) for _ in range(rng.randint(1, 50))]
        run_gbn_replay_case(base, rng.randint(1, 600),
                            rng.choice((4, 32, 256)), plan)


def test_e2e_retrans_bound_seeded_fuzz():
    rng = random.Random(0x10553)
    for _ in range(8):
        run_e2e_retrans_case(n_hosts=rng.randint(3, 10),
                             loss=rng.choice((0.0, 1e-4, 1e-3, 1e-2)),
                             seed=rng.randrange(1 << 16),
                             nbytes=rng.randrange(1 << 12, 1 << 17))
