"""Cross-validation of the SimEngine backends (core/engine.py).

The packet engine is the fidelity reference; the flow engines must agree
with it on topologies small enough for both to run.  ISSUE acceptance:
JCT within 10% on a small topology FOR EVERY TRANSPORT (gleam /
multiunicast / ring / binary-tree) — asserted here on the paper's
testbed across message sizes, plus the original gleam checks on a
2-pod fat tree.  The two flow solvers (numpy / JAX) must agree with
each other far tighter.
"""
from __future__ import annotations

import pytest

from repro.core import fattree
from repro.core.engine import (ENGINE_CHOICES, FlowEngine, PacketEngine,
                               SimEngine, make_engine, wire_bytes)
from repro.core.workload import TRANSPORT_CHOICES, GroupOp


def two_pod_fat_tree():
    """8 hosts, 2 pods x 2 leaves x 2 hosts, dual agg planes."""
    return fattree.fat_tree(n_pods=2, leaves_per_pod=2, hosts_per_leaf=2,
                            aggs_per_pod=2, bw=100 * fattree.GBPS)


def bcast_jct(engine_name, topo, members, nbytes):
    eng = make_engine(engine_name, topo)
    rec = eng.add_bcast(members, nbytes)
    eng.run(timeout=60.0)
    jct = rec.jct(len(members) - 1)
    assert jct != float("inf"), f"{engine_name} bcast did not complete"
    return jct


# ============================================================== conformance

def test_all_engines_satisfy_protocol():
    for name in ENGINE_CHOICES:
        eng = make_engine(name, fattree.testbed())
        assert isinstance(eng, SimEngine)


def test_make_engine_rejects_unknown():
    with pytest.raises(ValueError):
        make_engine("ns3", fattree.testbed())


def test_wire_bytes_includes_per_segment_headers():
    from repro.core.packet import HDR, MTU
    assert wire_bytes(1) == 1 + HDR
    assert wire_bytes(MTU) == MTU + HDR
    assert wire_bytes(MTU + 1) == MTU + 1 + 2 * HDR


# ======================================================= packet-vs-flow JCT

@pytest.mark.parametrize("nbytes", [64 << 10, 1 << 20, 8 << 20])
def test_testbed_bcast_jct_agrees_within_10pct(nbytes):
    members = ["h0", "h1", "h2", "h3"]
    jp = bcast_jct("packet", fattree.testbed(), members, nbytes)
    jf = bcast_jct("flow", fattree.testbed(), members, nbytes)
    assert abs(jf - jp) / jp < 0.10, (jp, jf)


@pytest.mark.parametrize("nbytes", [256 << 10, 4 << 20])
def test_two_pod_fat_tree_bcast_jct_agrees_within_10pct(nbytes):
    """All 8 hosts of a 2-pod fat tree: a genuinely multi-hop tree
    (leaf -> agg -> core -> agg -> leaf)."""
    topo = two_pod_fat_tree()
    members = list(topo.hosts)
    jp = bcast_jct("packet", topo, members, nbytes)
    jf = bcast_jct("flow", two_pod_fat_tree(), members, nbytes)
    assert abs(jf - jp) / jp < 0.10, (jp, jf)


# =============================================== transport parity (ISSUE 3)

def transport_bcast_jct(engine_name, transport, nbytes, members=None):
    members = members or ["h0", "h1", "h2", "h3"]
    eng = make_engine(engine_name, fattree.testbed(n_hosts=len(members)))
    rec = eng.stage(GroupOp("bcast", members, nbytes, transport=transport))
    eng.run(timeout=120.0)
    jct = rec.jct(len(members) - 1)
    assert jct != float("inf"), (engine_name, transport)
    return jct


@pytest.mark.parametrize("transport", TRANSPORT_CHOICES)
@pytest.mark.parametrize("nbytes", [256 << 10, 1 << 20])
def test_transport_jct_parity_flow_vs_packet(transport, nbytes):
    """Every transport must agree between the packet lowering (the
    baselines.py relay machinery) and the flow lowering (relay edge
    flows + analytic pipeline) within the 10% acceptance bound."""
    jp = transport_bcast_jct("packet", transport, nbytes)
    jf = transport_bcast_jct("flow", transport, nbytes)
    assert abs(jf - jp) / jp < 0.10, (transport, jp, jf)


@pytest.mark.parametrize("transport", TRANSPORT_CHOICES)
def test_transport_flow_solvers_agree(transport):
    """numpy and JAX lower transports identically (same edge flows,
    same finalizers): JCTs must match to 0.1%."""
    pytest.importorskip("jax")
    j_np = transport_bcast_jct("flow-np", transport, 1 << 20)
    j_jx = transport_bcast_jct("flow", transport, 1 << 20)
    assert abs(j_np - j_jx) / j_np < 1e-3, (transport, j_np, j_jx)


@pytest.mark.parametrize("transport", TRANSPORT_CHOICES)
def test_allreduce_parity_flow_vs_packet(transport):
    """allreduce = fan-in reduce + transport bcast on BOTH engines.
    Bound is looser than bcast (20%): the fluid model solves both
    phases concurrently, so phases sharing a host uplink (e.g. the
    ring overlay's relay egress vs the member's reduce contribution)
    contend in the solve while the packet engine sequences them."""
    members = ["h0", "h1", "h2", "h3"]
    jcts = {}
    for name in ("packet", "flow"):
        eng = make_engine(name, fattree.testbed())
        rec = eng.stage(GroupOp("allreduce", members, 1 << 20,
                                transport=transport))
        eng.run(timeout=120.0)
        jcts[name] = rec.jct(len(members))      # every member delivers
        assert jcts[name] != float("inf"), name
    assert abs(jcts["flow"] - jcts["packet"]) / jcts["packet"] < 0.20, \
        (transport, jcts)


def test_overlay_transport_per_receiver_ordering():
    """Relay pipelines deliver in hop order: on a ring, receiver i+1
    cannot finish before receiver i (both engines)."""
    members = ["h0", "h1", "h2", "h3"]
    for name in ("packet", "flow"):
        eng = make_engine(name, fattree.testbed())
        rec = eng.stage(GroupOp("bcast", members, 1 << 20,
                                transport="ring"))
        eng.run(timeout=120.0)
        times = [rec.t_deliver[m] for m in members[1:]]
        assert times == sorted(times), (name, times)


def test_flow_solvers_agree_tightly():
    """numpy and JAX progressive filling are the same algorithm; on a
    contended fat tree their JCTs must match to 0.1%."""
    pytest.importorskip("jax")
    topo = two_pod_fat_tree()
    members = list(topo.hosts)
    j_np = bcast_jct("flow-np", topo, members, 1 << 20)
    j_jx = bcast_jct("flow", two_pod_fat_tree(), members, 1 << 20)
    assert abs(j_np - j_jx) / j_np < 1e-3, (j_np, j_jx)


# ================================================== multi-flow consistency

def test_concurrent_groups_share_fabric_consistently():
    """Two disjoint-receiver groups from the same sender link must each
    see roughly half the sender bandwidth in BOTH engines."""
    members_a = ["h0", "h1", "h2"]
    members_b = ["h0", "h3", "h4"]
    jcts = {}
    for name in ("packet", "flow"):
        eng = make_engine(name, fattree.testbed(n_hosts=5))
        ra = eng.add_bcast(members_a, 1 << 20)
        rb = eng.add_bcast(members_b, 1 << 20)
        eng.run(timeout=60.0)
        jcts[name] = (ra.jct(2), rb.jct(2))
    for name, (ja, jb) in jcts.items():
        assert ja != float("inf") and jb != float("inf"), name
    # sharing: each group's JCT is ~2x the solo JCT; engines within 15%
    solo = bcast_jct("flow", fattree.testbed(n_hosts=5), members_a, 1 << 20)
    for name, (ja, jb) in jcts.items():
        assert ja > 1.5 * solo, (name, ja, solo)
    assert abs(jcts["flow"][0] - jcts["packet"][0]) \
        / jcts["packet"][0] < 0.15


def test_unicast_and_write_complete_on_both_engines():
    for name in ("packet", "flow"):
        eng = make_engine(name, fattree.testbed())
        ru = eng.add_unicast("h0", "h1", 256 << 10)
        rw = eng.add_write(["h0", "h1", "h2", "h3"], 256 << 10)
        eng.run(timeout=60.0)
        assert ru.jct(1) != float("inf"), name
        assert rw.jct(3) != float("inf"), name
        assert ru.complete and rw.complete, name


def test_flow_engine_epochs_are_sequential():
    """Records of a second staged batch start no earlier than the first
    batch's completion (the engine's clock advances)."""
    eng = FlowEngine(fattree.testbed(), backend="auto")
    r1 = eng.add_bcast(["h0", "h1", "h2", "h3"], 1 << 20)
    eng.run()
    r2 = eng.add_bcast(["h0", "h1", "h2", "h3"], 1 << 20)
    eng.run()
    assert r2.t_submit >= max(r1.t_deliver.values())
    assert r2.jct(3) == pytest.approx(r1.jct(3), rel=1e-6)


def test_packet_engine_source_rotation():
    """Appendix-B source switching through the engine API: rotating the
    source must not re-register and must still deliver."""
    eng = PacketEngine(fattree.testbed())
    members = ["h0", "h1", "h2", "h3"]
    r0 = eng.add_bcast(members, 64 << 10)
    eng.run()
    r1 = eng.add_bcast(members, 64 << 10, source="h2")
    eng.run()
    assert r0.jct(3) != float("inf")
    assert r1.jct(3) != float("inf")
    assert len(eng._groups) == 1            # one registration, rotated
