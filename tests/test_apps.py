"""Application traffic plane (repro.apps): lowering math, the
open-loop generator, and engine integration.

ISSUE-8 satellite checklist:

- collective sizes match the ArchConfig math for >= 3 archs (dense,
  MoE, hybrid-SSM), anchored on ``count_params(model_defs(cfg))`` —
  the analytic mirror must track the real tensor shapes exactly;
- seeded Poisson arrivals are deterministic (and specs round-trip);
- packet ``run_many`` serial == ``workers=N`` bit-identical on app
  workloads;
- packet-vs-flow parity <= 10% on a small phase-split train step.
"""
from __future__ import annotations

import pytest

from repro.apps.collectives_lowering import (BF16, F32, MeshShape,
                                             kv_cache_bytes,
                                             moe_a2a_pair_bytes,
                                             moe_uses_ep, param_count,
                                             pp_boundary_bytes,
                                             tp_allreduce_bytes,
                                             train_step_workload,
                                             weight_bcast_workload)
from repro.apps.metrics import (jct, phase_stats, quantile, run_phased,
                                split_phases, step_time)
from repro.apps.traffic import ArrivalSpec, ServingGenerator
from repro.configs.base import get_config
from repro.core import fattree
from repro.core.engine import make_engine

ARCHS = ("llama3_2_3b",       # dense:      attn + mlp every block
         "mixtral_8x7b",      # MoE:        attn + moe every block
         "jamba_v0_1_52b")    # hybrid-SSM: mamba/attn mix + moe


# ===================================================== lowering math

@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_model_defs(arch):
    """The analytic mirror must equal the real shape table exactly."""
    from repro.models.blocks import count_params
    from repro.models.model import model_defs
    for smoke in (True, False):
        cfg = get_config(arch, smoke=smoke)
        assert param_count(cfg) == count_params(model_defs(cfg))


@pytest.mark.parametrize("arch", ARCHS)
def test_tp_allreduce_bytes_from_pattern(arch):
    """units = mixers + dense FFNs (MoE FFNs only when not in ep
    mode); one (batch, seq, d) bf16 activation per unit, x2 for the
    backward."""
    cfg = get_config(arch, smoke=True)
    seq, batch, tp = 64, 8, 2
    ep = moe_uses_ep(cfg, tp)
    units = 0
    for _, ffn in cfg.pattern:
        units += 1
        if ffn == "mlp" or (ffn == "moe" and not ep):
            units += 1
    expect = units * cfg.n_blocks * batch * seq * cfg.d_model * BF16 * 2
    assert tp_allreduce_bytes(cfg, seq, batch, tp) == expect
    # inference = one pass
    assert tp_allreduce_bytes(cfg, seq, batch, tp, kind="prefill") \
        == expect // 2


def test_moe_a2a_pair_bytes_mixtral():
    """ep mode: per a2a each ordered pair carries tokens/ep * top_k *
    d * 2 / ep bytes; dispatch+combine per MoE sublayer, x2 train."""
    cfg = get_config("mixtral_8x7b", smoke=True)
    seq, batch, ep = 64, 8, 2
    assert moe_uses_ep(cfg, ep)
    n_moe = sum(1 for _, f in cfg.pattern if f == "moe") * cfg.n_blocks
    per = batch * seq * cfg.top_k * cfg.d_model * BF16 // (ep * ep)
    assert moe_a2a_pair_bytes(cfg, seq, batch, ep) == per * n_moe * 2 * 2


def test_kv_cache_bytes_hybrid():
    """Hybrid arch: bf16 K+V per attn sublayer grows with seq; f32 SSD
    state per mamba sublayer does not."""
    cfg = get_config("jamba_v0_1_52b", smoke=True)
    attn = sum(1 for m, _ in cfg.pattern if m == "attn")
    mamba = sum(1 for m, _ in cfg.pattern if m == "mamba")
    assert attn and mamba, "jamba smoke must stay hybrid"
    seq = 128
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_headdim
    expect = (attn * 2 * seq * cfg.n_kv_heads * cfg.hd * BF16
              + mamba * (h * cfg.ssm_headdim * cfg.ssm_state
                         + (cfg.ssm_conv - 1) * d_in) * F32
              ) * cfg.n_blocks
    assert kv_cache_bytes(cfg, seq) == expect
    # the mamba share is seq-free
    delta = kv_cache_bytes(cfg, 2 * seq) - kv_cache_bytes(cfg, seq)
    assert delta == attn * 2 * seq * cfg.n_kv_heads * cfg.hd * BF16 \
        * cfg.n_blocks


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_workload_structure(arch):
    cfg = get_config(arch, smoke=True)
    # jamba's smoke config is a single block -> no pipeline cut there
    pipe = 2 if cfg.n_blocks % 2 == 0 else 1
    mesh = MeshShape(data=2, model=2, pipe=pipe)
    wl = train_step_workload(cfg, mesh, seq=64, batch=8, accum=2)
    by_phase = {}
    for op in wl.ops:
        by_phase.setdefault(op.phase, []).append(op)
    # one TP all-reduce per (pipe, data) group
    assert len(by_phase["tp-allreduce"]) == mesh.pipe * mesh.data
    if pipe > 1:
        # one pp unicast per (cut, data, model)
        pp = by_phase["pp-boundary"]
        assert len(pp) == (mesh.pipe - 1) * mesh.data * mesh.model
        assert pp[0].nbytes == pp_boundary_bytes(cfg, 64, 8 // 2 // 2) \
            * 2 * 2 // mesh.model
    else:
        assert "pp-boundary" not in by_phase
    # one grad sync per (pipe, model) over the data axis, f32 shard
    gs = by_phase["dp-gradsync"]
    assert len(gs) == mesh.pipe * mesh.model
    assert gs[0].nbytes == F32 * param_count(cfg) \
        // (mesh.model * mesh.pipe)
    if moe_uses_ep(cfg, mesh.model):
        # a full fan-mesh: tp*(tp-1) ordered pairs per TP group
        assert len(by_phase["moe-alltoall"]) == \
            mesh.pipe * mesh.data * mesh.model * (mesh.model - 1)
    else:
        assert "moe-alltoall" not in by_phase
    # phase-split partitions the ops exactly
    parts = split_phases(wl)
    assert sorted(id(o) for p in parts for o in p.ops) \
        == sorted(id(o) for o in wl.ops)
    assert all(p.meta == wl.meta for p in parts)


def test_weight_bcast_is_native_shard():
    cfg = get_config("llama3_2_3b", smoke=True)
    wl = weight_bcast_workload(cfg, 4, 2)
    assert len(wl.ops) == 2                     # one bcast per TP rank
    for m, op in enumerate(wl.ops):
        assert op.op == "bcast" and op.phase == "weights"
        assert op.nbytes == BF16 * param_count(cfg) // 2
        assert list(op.members) == [f"h{r * 2 + m}" for r in range(4)]


def test_train_step_workload_validation():
    cfg = get_config("llama3_2_3b", smoke=True)
    with pytest.raises(ValueError, match="single chip"):
        train_step_workload(cfg, MeshShape(), seq=64, batch=8)
    with pytest.raises(ValueError, match="not divisible"):
        train_step_workload(cfg, MeshShape(data=3), seq=64, batch=8)


# ==================================================== arrivals / specs

def test_poisson_arrivals_deterministic():
    a = ArrivalSpec(rate=1e4, n=32, seed=7)
    xs, ys = a.arrivals(), ArrivalSpec(rate=1e4, n=32, seed=7).arrivals()
    assert xs == ys                              # bit-identical replay
    assert xs == sorted(xs) and len(xs) == 32 and xs[0] > 0
    assert ArrivalSpec(rate=1e4, n=32, seed=8).arrivals() != xs
    # mean gap ~ 1/rate (Mersenne Twister is spec'd, so this is exact
    # across platforms; the loose band just guards the formula)
    assert 0.5 / 1e4 < xs[-1] / 32 < 2.0 / 1e4


def test_arrival_spec_roundtrip_and_validation():
    for spec in (ArrivalSpec(rate=5e3, n=16, seed=3),
                 ArrivalSpec(kind="trace", trace=(3e-4, 1e-4, 2e-4))):
        back = ArrivalSpec.from_dict(spec.to_dict())
        assert back == spec
        assert back.arrivals() == spec.arrivals()
    assert ArrivalSpec(kind="trace", trace=(3e-4, 1e-4)).arrivals() \
        == [1e-4, 3e-4]
    with pytest.raises(ValueError, match="unknown arrival kind"):
        ArrivalSpec(kind="uniform")
    with pytest.raises(ValueError, match="non-empty trace"):
        ArrivalSpec(kind="trace")
    with pytest.raises(ValueError, match="unknown ArrivalSpec fields"):
        ArrivalSpec.from_dict({"kind": "poisson", "burst": 4})


def test_quantiles_nearest_rank():
    xs = list(range(1, 101))                     # 1..100
    assert quantile(xs, 0.50) == 50
    assert quantile(xs, 0.99) == 99
    assert quantile(xs, 0.999) == 100
    assert quantile([], 0.5) == 0.0
    assert quantile([42.0], 0.999) == 42.0


# ================================================= engine integration

def _small_train_wl(transport="gleam"):
    cfg = get_config("llama3_2_3b", smoke=True)
    return train_step_workload(cfg, MeshShape(data=2, model=2),
                               seq=64, batch=8, transport=transport)


def test_step_time_sums_phase_maxima():
    wl = _small_train_wl()
    eng = make_engine("flow", fattree.testbed(n_hosts=4))
    ops, recs = run_phased(eng, wl)
    stats = phase_stats(ops, recs)
    assert set(stats) == {"tp-allreduce", "dp-gradsync"}
    assert step_time(ops, recs) == pytest.approx(
        sum(s.latency for s in stats.values()))
    # an overlappable compute floor clips a cheaper phase
    big = {"tp-allreduce": 10.0}
    assert step_time(ops, recs, big) == pytest.approx(
        10.0 + stats["dp-gradsync"].latency)


@pytest.mark.parametrize("transport", ["gleam", "multiunicast"])
def test_train_step_packet_flow_parity(transport):
    """Phase-split step time: the two engines must agree within 10%."""
    wl = _small_train_wl(transport)
    out = {}
    for name in ("packet", "flow"):
        eng = make_engine(name, fattree.testbed(n_hosts=4))
        ops, recs = run_phased(eng, wl, timeout=120.0)
        out[name] = step_time(ops, recs)
    div = abs(out["packet"] - out["flow"]) / out["packet"]
    assert div <= 0.10, f"{transport}: packet={out['packet']:.3e} " \
                        f"flow={out['flow']:.3e} div={div:.1%}"


def test_packet_serial_matches_workers():
    """App batches ride packet run_many: forked workers must be
    bit-identical to the serial fallback."""
    wl = _small_train_wl()
    phases = split_phases(wl)
    runs = []
    for workers in (1, 2):
        eng = make_engine("packet", fattree.testbed(n_hosts=4))
        res = eng.run_workloads(phases, timeout=120.0, workers=workers)
        runs.append([sorted(r.t_deliver.values()) for rs in res
                     for r in rs])
    assert runs[0] == runs[1]


def test_serving_generator_end_to_end():
    cfg = get_config("llama3_2_3b", smoke=True)
    gen = ServingGenerator(cfg, n_replicas=4, tp=2, prompt_len=32,
                           decode_len=8, kv_replicas=2)
    spec = ArrivalSpec(rate=2e4, n=16, seed=0)
    wls = gen.workloads(spec)
    assert sum(len(wl.meta["requests"]) for wl in wls) == 16
    # per request: prefill + decode all-reduce + kv write
    assert sum(len(wl.ops) for wl in wls) == 3 * 16
    kv = [op for wl in wls for op in wl.ops if op.phase == "kv-replicate"]
    assert all(op.op == "write" and len(op.members) == 3 for op in kv)
    eng = make_engine("flow", fattree.testbed(n_hosts=8))
    rep = gen.run(eng, spec)
    assert rep.n_requests == 16
    assert 0 < rep.achieved_qps <= spec.rate * 1.5
    assert rep.quantiles["p50"] <= rep.quantiles["p99"] \
        <= rep.quantiles["p999"] <= rep.quantiles["max"]
    assert len(rep.latencies) == 16 and min(rep.latencies) > 0
    assert set(rep.phase_latency) == {"prefill", "decode",
                                      "kv-replicate"}
    # same spec, same engine family => same report (replayable)
    rep2 = gen.run(make_engine("flow", fattree.testbed(n_hosts=8)), spec)
    assert rep2.latencies == rep.latencies


def test_serving_generator_validation():
    cfg = get_config("llama3_2_3b", smoke=True)
    with pytest.raises(ValueError, match=">= 2 replicas"):
        ServingGenerator(cfg, n_replicas=1, tp=2)
    with pytest.raises(ValueError, match="kv_replicas"):
        ServingGenerator(cfg, n_replicas=2, tp=2, kv_replicas=2)


def test_workload_meta_and_phase_roundtrip():
    """The app plane's IR additions survive the dict round-trip."""
    from repro.core.workload import Workload
    gen = ServingGenerator(get_config("llama3_2_3b", smoke=True),
                           n_replicas=2, tp=2)
    wl = gen.workloads(ArrivalSpec(rate=1e4, n=4, seed=1))[0]
    back = Workload.from_dict(wl.to_dict())
    assert back.ops == wl.ops
    assert [op.phase for op in back.ops] == [op.phase for op in wl.ops]
    assert back.meta == wl.meta
    assert ArrivalSpec.from_dict(back.meta["spec"]).arrivals() \
        == ArrivalSpec(rate=1e4, n=4, seed=1).arrivals()


def test_jct_falls_back_to_sender_cqe():
    from repro.core.metrics import MsgRecord
    r = MsgRecord(msg_id=0, nbytes=1, t_submit=1.0, t_sender_cqe=3.5)
    assert jct(r) == 2.5
