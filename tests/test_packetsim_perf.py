"""Packet-engine hot-path overhaul tests: typed event loop determinism,
serial vs parallel run_many equivalence, the typed event-budget error,
the ready-QP set invariant, and the packet free-list pool."""
from __future__ import annotations

import pytest

from repro.core import fattree
from repro.core import packet as pk
from repro.core.engine import make_engine
from repro.core.packetsim import EventBudgetExceeded, PacketSim
from repro.core.workload import GroupOp

MEMBERS16 = [f"h{i}" for i in range(16)]


def _lossy_engine(n_hosts=16, loss=1e-3, seed=7):
    topo = fattree.testbed(n_hosts=n_hosts, bw=200 * fattree.GBPS)
    return make_engine("packet", topo, loss_rate=loss, seed=seed,
                       group_kw={"window": 512})


def _stage_bcast(recs, members=MEMBERS16, nbytes=1 << 19):
    def scenario(eng):
        recs.append(eng.stage(GroupOp("bcast", members, nbytes,
                                      transport="gleam", chunks=8)))
    return scenario


def _run_batch(workers, n_scenarios=4, seed=7):
    eng = _lossy_engine(seed=seed)
    recs = []
    eng.run_many([_stage_bcast(recs)] * n_scenarios, timeout=60.0,
                 workers=workers)
    jcts = [r.jct(len(MEMBERS16) - 1) for r in recs]
    delivers = [dict(r.t_deliver) for r in recs]
    return jcts, delivers, eng.last_run_stats


# ------------------------------------------------------------ determinism

def test_typed_event_loop_deterministic_across_runs():
    """Two fresh engines, same seed -> bit-identical JCTs and drop/
    retransmit counters (the typed event loop has no hidden state)."""
    results = []
    for _ in range(2):
        eng = _lossy_engine()
        rec = eng.stage(GroupOp("bcast", MEMBERS16, 1 << 20,
                                transport="gleam", chunks=8))
        eng.run(timeout=60.0)
        sim = eng.net.sim
        rtx = sum(q.retransmitted for h in sim.hosts.values()
                  for q in h.qps.values())
        results.append((rec.jct(15), dict(rec.t_deliver), sim.dropped,
                        sim.tx_bytes, rtx))
    assert results[0] == results[1]


def test_run_many_serial_matches_parallel_bit_for_bit():
    """Satellite: same seed -> identical per-record JCTs, per-receiver
    delivery times, and drop counters between the serial run_many and
    the fork-parallel one (lossy fabric, so the RNG stream matters)."""
    js, ds, ss = _run_batch(workers=None)
    jp, dp, sp = _run_batch(workers=2)
    assert js == jp
    assert ds == dp
    assert ss == sp                  # per-scenario counter deltas too
    assert len(js) == 4 and all(j != float("inf") for j in js)


def test_run_many_scenarios_reseed_independently():
    """Scenario i's RNG stream depends on (engine seed, i) only, so the
    same batch run twice on fresh engines is identical end to end."""
    a = _run_batch(workers=None, n_scenarios=3)
    b = _run_batch(workers=None, n_scenarios=3)
    assert a == b


def test_run_many_parallel_worker_failure_surfaces():
    """A thunk that raises while a WORKER drives its scenario degrades
    gracefully: the parent warns which scenario failed, re-runs it
    serially (same per-index reseed), and the deterministic error then
    reproduces with its REAL type and traceback — it must not vanish
    into a dead child process or an opaque EOFError."""
    eng = _lossy_engine()

    def boom():
        raise ValueError("deferred submission explodes in the worker")

    def bad(e):
        e._staged.append(boom)       # staged thunks run at drive time

    recs = []
    scenarios = [_stage_bcast(recs), bad]
    with pytest.warns(RuntimeWarning, match=r"re-running scenarios \[1\]"):
        with pytest.raises(ValueError, match="deferred submission"):
            eng.run_many(scenarios, timeout=30.0, workers=2)
    assert any("deferred submission" in e for e in eng.last_run_errors)


# ------------------------------------------------------- event budget

def test_event_budget_exceeded_is_typed_and_inspectable():
    """Satellite: the budget error carries events/now and leaves the
    engine state intact — the run can even be resumed with a larger
    budget."""
    eng = _lossy_engine(loss=0.0)
    rec = eng.stage(GroupOp("bcast", MEMBERS16, 1 << 20,
                            transport="gleam", chunks=8))
    sim = eng.net.sim
    for thunk in eng._staged:
        thunk()
    eng._staged = []
    with pytest.raises(EventBudgetExceeded) as ei:
        sim.run(max_events=sim.events + 500)
    err = ei.value
    assert isinstance(err, RuntimeError)         # back-compat contract
    assert err.events == sim.events              # state is inspectable
    assert err.now == sim.now
    assert sim._q, "queue keeps its remaining events"
    assert "event budget exceeded" in str(err)
    # resume with a larger budget: the bcast completes normally
    sim.run(max_events=50_000_000)
    assert rec.jct(15) != float("inf")


# ------------------------------------------------------- ready-QP set

def test_ready_set_tracks_pending_predicate():
    """The host ready-set holds exactly the QPs with sender-side work:
    populated by submit, emptied when the cumulative ACK covers
    everything."""
    eng = _lossy_engine(loss=0.0)
    rec = eng.stage(GroupOp("bcast", MEMBERS16, 64 << 10,
                            transport="gleam", chunks=1))
    sim = eng.net.sim
    assert all(not h._ready for h in sim.hosts.values()), \
        "registration leaves no pending sender work"
    for thunk in eng._staged:
        thunk()
    eng._staged = []
    src = sim.hosts["h0"]
    assert src._ready, "submit marks the source QP ready"
    qp = next(iter(src._ready.values()))
    assert qp.sq_psn != qp.snd_nxt or qp.snd_una != qp.sq_psn
    sim.run()
    assert rec.jct(15) != float("inf")
    assert all(not h._ready for h in sim.hosts.values()), \
        "completion (snd_una == sq_psn) empties every ready-set"


# ------------------------------------------------------- packet pool

def test_packet_pool_recycles_and_reinitializes():
    p = pk.data_packet(1, 2, 3, psn=9, nbytes=100, msg_id=5, last=True)
    p.ecn = True
    p.payload = {"x": 1}
    before = pk.pool_size()
    pk.release(p)
    assert pk.pool_size() == before + 1
    assert p.payload is None, "release drops payload references"
    q = pk.ack_packet(7, 8, 42, dst_qpn=3)
    assert q is p, "allocation reuses the freed object"
    assert (q.kind, q.src_ip, q.dst_ip, q.psn, q.dst_qpn) == \
        (pk.ACK, 7, 8, 42, 3)
    assert q.ecn is False and q.payload is None and q.last is False
    assert q.size == pk.ACK_SIZE


def test_sim_run_feeds_the_pool():
    """An end-to-end run recycles terminal packets instead of leaking
    every hop-copy to the GC."""
    eng = _lossy_engine(loss=0.0)
    eng.stage(GroupOp("bcast", MEMBERS16, 256 << 10, transport="gleam"))
    eng.run(timeout=60.0)
    assert pk.pool_size() > 0


# ------------------------------------------------------- fixed-seed runs

def test_single_run_unaffected_by_prior_scenarios():
    """A scenario driven through run_many equals the same workload on a
    fresh engine driven through run_many — PSN offsets and table state
    from earlier scenarios must not leak into timing."""
    recs_a = []
    eng = _lossy_engine(seed=3)
    eng.run_many([_stage_bcast(recs_a)] * 3, timeout=60.0)
    recs_b = []
    eng2 = _lossy_engine(seed=3)
    eng2.run_many([_stage_bcast(recs_b)] * 2, timeout=60.0)
    # scenario i is the same experiment no matter the batch size
    assert recs_a[0].jct(15) == recs_b[0].jct(15)
    assert recs_a[1].jct(15) == recs_b[1].jct(15)
