"""Shared property drivers for the loss/DCQCN model invariants.

Each ``run_*`` function checks one invariant for one concrete input and
raises AssertionError on violation.  They are driven twice: adaptively
by the hypothesis twins in ``test_protocol_properties.py`` (CI), and by
the deterministic seeded fuzz in ``test_loss_model.py`` (always runs,
no hypothesis dependency) — the same split as ``_membership_props.py``.
"""
from __future__ import annotations

import numpy as np

from repro.core import fattree, flowsim, packet as pk
from repro.core.endpoint import QP
from repro.core.engine import make_engine
from repro.core.gleam import GleamNetwork
from repro.core.workload import GroupOp


def run_monotone_case(group, transport, l1, l2, nbytes):
    """More loss never speeds a flow-engine op up — on arbitrary group
    sizes, transports and message sizes."""
    lo, hi = sorted((l1, l2))

    def jct(loss):
        eng = make_engine("flow", fattree.testbed(n_hosts=group),
                          loss_rate=loss)
        rec = eng.stage(GroupOp("bcast", [f"h{i}" for i in range(group)],
                                nbytes, transport=transport, chunks=2))
        eng.run()
        return rec.jct(group - 1)

    assert jct(hi) >= jct(lo) * (1.0 - 1e-9)


def run_factor_bounds_case(seed):
    """Kernel-level: correction factors are always in (0, 1], so the
    effective rate is positive and never above the solved max-min rate
    (hence never above link capacity) — whatever the q/wsq/ECN mix."""
    from repro.kernels.ref import loss_factors_reference
    rng = np.random.default_rng(seed)
    n_links = int(rng.integers(1, 12))
    n_flows = int(rng.integers(1, 24))
    hops = int(rng.integers(1, 5))
    f32 = np.float32        # jax default precision; no x64 ctx needed
    cap = np.append(rng.uniform(1e8, 4e10, n_links), np.inf).astype(f32)
    links = rng.integers(0, n_links + 1, (n_flows, hops)).astype(np.int32)
    rates = rng.uniform(1.0, 4e10, n_flows).astype(f32)
    active = (rng.random(n_flows) < 0.7).astype(f32)
    q = (rng.uniform(0.0, 1.0, n_flows)
         * (rng.random(n_flows) < 0.7)).astype(f32)
    wsq = rng.uniform(0.0, 1e-4, n_flows).astype(f32)
    wnd = rng.uniform(1.0, 1024.0, n_flows).astype(f32)
    ecn = (rng.random(n_flows) < 0.5).astype(f32)
    fac = np.asarray(loss_factors_reference(
        links, rates, active, cap, q, wsq, wnd, ecn,
        dcqcn_num=flowsim.DCQCN_RATE_NUM,
        dcqcn_min=flowsim.DCQCN_MIN_RATE))
    assert np.all(fac > 0.0) and np.all(fac <= 1.0)
    assert np.all(rates * fac <= rates)


def run_gbn_replay_case(base, n_pkts, window, plan):
    """Go-back-N accounting at the QP: however feedback interleaves —
    including PSN streams that wrap through PSN_MOD — the window stays
    closed at ``window`` outstanding and every NACK/timeout rewinds (and
    so replays) at most ``window`` packets.  ``plan`` is a list of
    (kind, psn-offset) feedback events, kind in ack|nack|timeout."""
    qp = QP(1, 1, 2, 3, link_bw=12.5e9, window=window)
    qp.sq_psn = qp.snd_una = qp.snd_nxt = base  # stream starts near wrap
    qp.submit(n_pkts * pk.MTU, 0.0)
    rewinds = 0
    for i, (kind, off) in enumerate(plan):
        now = float(i)
        for _ in range(4):                       # drain a few emissions
            p, _t = qp.next_packet(now)
            if p is None:
                break
            assert qp.outstanding() <= window
        sent = pk.psn_sub(qp.snd_nxt, base)
        psn = pk.psn_add(base, min(off, max(sent - 1, 0)))
        before = qp.retransmitted
        if kind == "ack":
            qp.on_ack(psn, now)
        elif kind == "nack":
            qp.on_nack(psn, now)
        else:
            qp.timer_deadline = now
            qp.on_timeout(now)
        replay = qp.retransmitted - before
        assert 0 <= replay <= window
        rewinds += replay > 0
        assert qp.outstanding() <= window
    assert qp.retransmitted <= rewinds * window


def run_e2e_retrans_case(n_hosts, loss, seed, nbytes):
    """End to end on random group topologies: the sender never replays
    without a drop, and total retransmission stays within the go-back-N
    budget (every drop triggers at most one window replay, plus at most
    one trailing timeout replay for a tail-drop)."""
    net = GleamNetwork(fattree.testbed(n_hosts=n_hosts),
                       loss_rate=loss, seed=seed)
    g = net.multicast_group([f"h{i}" for i in range(n_hosts)])
    g.register()
    rec = g.bcast(nbytes)
    assert g.run_until_delivered(rec, timeout=30.0) < float("inf")
    src = g.qps[g.source]
    if net.sim.dropped == 0:
        assert src.retransmitted == 0
    else:
        assert src.retransmitted <= (net.sim.dropped + 1) * src.window
