"""Property-based tests (hypothesis) for Gleam's reliability invariants.

The two §3.4 principles, as executable properties over arbitrary feedback
interleavings and loss patterns:

  (i)  an aggregated ACK for PSN p is emitted only when EVERY downstream
       port has acknowledged p (aggregate == min over ports);
  (ii) a NACK with expected PSN e is forwarded only when every port has
       acknowledged every PSN < e, and the minimum outstanding loss is
       never masked (Fig. 7).

Plus end-to-end: under any random loss pattern the multicast still
delivers every byte to every receiver (go-back-N + aggregation compose).
"""
from __future__ import annotations

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import fattree, packet as pk
from repro.core.gleam import GleamNetwork
from repro.core.switch import GleamSwitch

FAST = dict(deadline=None,
            suppress_health_check=[HealthCheck.too_slow,
                                   HealthCheck.data_too_large])


def fresh_switch(n_receivers: int):
    topo = fattree.testbed(n_hosts=n_receivers + 1)
    hosts = fattree.host_ip_map(topo)
    sw = GleamSwitch("SW0", topo, hosts)
    t = sw.tables.create(group_ip=4242)
    for port in range(n_receivers + 1):
        t.add_connected(port, dest_ip=port + 1, dest_qpn=16 + port)
    t.ack_out_port = 0              # port 0 faces the source
    return sw, t


feedback_event = st.tuples(
    st.integers(min_value=1, max_value=4),      # receiver port
    st.sampled_from(["ack", "nack"]),
    st.integers(min_value=0, max_value=63),     # psn
)


@settings(max_examples=200, **FAST)
@given(st.lists(feedback_event, min_size=1, max_size=120))
def test_aggregated_ack_is_min_over_ports(events):
    sw, t = fresh_switch(4)
    acked = {p: -1 for p in range(1, 5)}        # per-port cumulative
    for port, kind, psn in events:
        if kind == "ack":
            pkt = pk.ack_packet(src_ip=port + 1, dst_ip=4242, psn=psn)
        else:
            pkt = pk.nack_packet(src_ip=port + 1, dst_ip=4242, epsn=psn)
        out = sw.on_packet(pkt, port, 0.0)
        if kind == "ack":
            acked[port] = max(acked[port], psn)
        else:
            acked[port] = max(acked[port], psn - 1)
        floor = min(acked.values())
        for _, p in out:
            if p.kind == pk.ACK:
                # (i): never ack beyond the slowest receiver
                assert p.psn <= floor, (
                    f"aggregated ACK {p.psn} > min acked {floor}")


@settings(max_examples=200, **FAST)
@given(st.lists(feedback_event, min_size=1, max_size=120))
def test_nack_never_masks_earlier_loss(events):
    """(ii): any NACK forwarded upstream must carry the MINIMUM expected
    PSN outstanding at that moment — forwarding a higher one would mask
    the earlier loss (Fig. 7)."""
    sw, t = fresh_switch(4)
    acked = {p: -1 for p in range(1, 5)}
    for port, kind, psn in events:
        if kind == "ack":
            pkt = pk.ack_packet(src_ip=port + 1, dst_ip=4242, psn=psn)
            out = sw.on_packet(pkt, port, 0.0)
            acked[port] = max(acked[port], psn)
        else:
            pkt = pk.nack_packet(src_ip=port + 1, dst_ip=4242, epsn=psn)
            out = sw.on_packet(pkt, port, 0.0)
            acked[port] = max(acked[port], psn - 1)
        floor = min(acked.values())
        for _, p in out:
            if p.kind == pk.NACK:
                assert p.psn == floor + 1, (
                    f"NACK {p.psn} != min outstanding {floor + 1}")


@settings(max_examples=150, **FAST)
@given(st.lists(st.integers(min_value=0, max_value=63),
                min_size=1, max_size=100),
       st.integers(min_value=2, max_value=4))
def test_ack_stream_monotonic(psns, n_recv):
    """The sender-facing aggregated ACK stream is strictly increasing —
    the 'unicast-like feedback stream' RC logic requires."""
    sw, t = fresh_switch(n_recv)
    seen = []
    for i, psn in enumerate(psns):
        port = (i % n_recv) + 1
        out = sw.on_packet(pk.ack_packet(port + 1, 4242, psn), port, 0.0)
        seen += [p.psn for _, p in out if p.kind == pk.ACK]
    assert seen == sorted(set(seen)), f"non-monotonic ACK stream {seen}"


@settings(max_examples=12, **FAST)
@given(loss=st.floats(min_value=0.0, max_value=5e-3),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       nbytes=st.integers(min_value=1, max_value=1 << 19))
def test_end_to_end_reliable_delivery_under_loss(loss, seed, nbytes):
    """Whatever the loss pattern, every receiver eventually gets every
    byte and the sender gets exactly one CQE (hardware reliability)."""
    net = GleamNetwork(fattree.testbed(), loss_rate=loss, seed=seed)
    g = net.multicast_group(["h0", "h1", "h2", "h3"])
    g.register()
    rec = g.bcast(nbytes)
    jct = g.run_until_delivered(rec, timeout=30.0)
    assert jct < float("inf"), "multicast did not complete"
    for h in ("h1", "h2", "h3"):
        assert g.qps[h].delivered_bytes >= nbytes
    assert rec.t_sender_cqe >= max(rec.t_deliver.values()) - 1e-9


@settings(max_examples=30, **FAST)
@given(st.integers(min_value=2, max_value=16))
def test_registration_any_group_size(n):
    topo = fattree.testbed(n_hosts=max(n, 2))
    net = GleamNetwork(topo)
    g = net.multicast_group([f"h{i}" for i in range(n)])
    g.register()
    assert g.registered


churn_event = st.one_of(
    st.tuples(st.just("ack"), st.integers(min_value=1, max_value=6),
              st.integers(min_value=0, max_value=300)),
    st.tuples(st.just("add"), st.integers(min_value=1, max_value=6),
              st.just(0)),
    st.tuples(st.just("remove"), st.integers(min_value=1, max_value=6),
              st.just(0)),
)


@settings(max_examples=200, **FAST)
@given(base=st.integers(min_value=0, max_value=pk.PSN_MOD - 1),
       events=st.lists(churn_event, min_size=1, max_size=80))
def test_agg_min_tracks_bruteforce_under_churn_across_wrap(base, events):
    """The cached aggregate minimum (``GroupTable.agg_min``) must equal
    the brute-force windowed ``psn_min`` fold over the live ports at
    every step — including mid-stream port installs (seeded from
    ``last_ack_psn``), removals of the port OWNING the minimum, and PSN
    streams that wrap through PSN_MOD (``base`` near the top).  The
    emitted aggregated-ACK stream must advance in wrapped order.
    (Driver shared with the deterministic fuzz in test_membership.)"""
    from _membership_props import run_churn_case
    run_churn_case(base, events)


# ------------- loss/DCQCN model invariants (drivers in _loss_props.py;
# deterministic seeded-fuzz twins in test_loss_model.py)

@settings(max_examples=20, **FAST)
@given(group=st.integers(min_value=2, max_value=8),
       transport=st.sampled_from(("gleam", "multiunicast", "ring")),
       l1=st.floats(min_value=0.0, max_value=2e-2),
       l2=st.floats(min_value=0.0, max_value=2e-2),
       nbytes=st.integers(min_value=1 << 12, max_value=1 << 20))
def test_flow_jct_monotone_nondecreasing_in_loss(group, transport, l1,
                                                 l2, nbytes):
    from _loss_props import run_monotone_case
    run_monotone_case(group, transport, l1, l2, nbytes)


@settings(max_examples=60, **FAST)
@given(seed=st.integers(min_value=0, max_value=2 ** 32 - 1))
def test_loss_factors_bounded_never_exceed_allocation(seed):
    from _loss_props import run_factor_bounds_case
    run_factor_bounds_case(seed)


@settings(max_examples=120, **FAST)
@given(base=st.integers(min_value=0, max_value=pk.PSN_MOD - 1),
       n_pkts=st.integers(min_value=1, max_value=600),
       window=st.sampled_from((4, 32, 256)),
       plan=st.lists(st.tuples(
           st.sampled_from(["ack", "nack", "timeout"]),
           st.integers(min_value=0, max_value=700)),
           min_size=1, max_size=60))
def test_gbn_replay_bounded_by_window_across_wrap(base, n_pkts, window,
                                                  plan):
    from _loss_props import run_gbn_replay_case
    run_gbn_replay_case(base, n_pkts, window, plan)


@settings(max_examples=10, **FAST)
@given(n_hosts=st.integers(min_value=3, max_value=10),
       loss=st.floats(min_value=0.0, max_value=1e-2),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       nbytes=st.integers(min_value=1 << 12, max_value=1 << 17))
def test_e2e_retransmission_bounded_by_drops(n_hosts, loss, seed,
                                             nbytes):
    from _loss_props import run_e2e_retrans_case
    run_e2e_retrans_case(n_hosts, loss, seed, nbytes)


@settings(max_examples=60, **FAST)
@given(a=st.integers(min_value=0, max_value=pk.PSN_MOD - 1),
       d=st.integers(min_value=0, max_value=(1 << 22) - 1))
def test_psn_wrapped_total_order(a, d):
    """psn_geq is a correct order inside one comparison window, across
    wraparound (both 2^23 and the P4 2^22 windows)."""
    for w in (pk.PSN_WINDOW, pk.PSN_WINDOW_P4):
        b = pk.psn_add(a, d % w)
        assert pk.psn_geq(b, a, w)
        if d % w:
            assert pk.psn_gt(b, a, w)
            assert not pk.psn_geq(a, b, w)
        assert pk.psn_min(a, b, w) == a
        assert pk.psn_max(a, b, w) == b


# --------------- fault-plane invariants (drivers in _fault_props.py;
# deterministic twins in test_faults.py)

@settings(max_examples=25, **FAST)
@given(first=st.floats(min_value=1e-6, max_value=2e-3),
       gap=st.one_of(st.none(),
                     st.floats(min_value=0.0, max_value=1e-3)))
def test_reelection_converges_for_any_crash_schedule(first, gap):
    """Any valid master-crash sequence (1-2 crashes on 4 members,
    spaced past the re-election window) ends with exactly one live
    master — the lowest-rank survivor — the stream complete for every
    surviving receiver, dead hosts dark, and no switch left holding an
    MFT entry for a dead host (the dead-source sever cascade unwinds
    the branches the re-rooted tree bypassed)."""
    from _fault_props import MIN_CRASH_GAP, run_reelection_case
    offsets = [first]
    if gap is not None:
        offsets.append(first + MIN_CRASH_GAP + gap)
    run_reelection_case(offsets, nbytes=1 << 16)


@settings(max_examples=25, **FAST)
@given(cap=st.integers(min_value=0, max_value=8),
       sever_at=st.floats(min_value=1e-6, max_value=5e-5))
def test_bounded_retry_is_terminal_for_any_budget(cap, sever_at):
    """For ANY retry budget and sever instant: a permanently severed
    path costs at most ``cap`` unproductive RTO replays (each bounded
    by the outstanding window) before the QP parks in a terminal
    ``retry_exceeded`` error surfaced on the message record — or the
    message had already beaten the sever and completes cleanly.  Never
    a hang, never unbounded retransmission."""
    from _fault_props import run_bounded_retry_case
    run_bounded_retry_case(cap, sever_at, nbytes=1 << 16)


# --------- dynamic-segment solver invariants (drivers in
# _segment_props.py; deterministic twins in test_segments.py)

@settings(max_examples=40, **FAST)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_flows=st.integers(min_value=1, max_value=32),
       n_links=st.integers(min_value=2, max_value=40))
def test_vectorized_maxmin_bit_identity(seed, n_flows, n_links):
    """CSR-vectorized ``static_maxmin`` reproduces the original
    per-flow-loop progressive filling bit for bit on arbitrary
    duplicate-free problems."""
    from _segment_props import run_solver_identity_case
    run_solver_identity_case(seed, n_flows=n_flows, n_links=n_links)


@settings(max_examples=8, **FAST)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_ops=st.integers(min_value=1, max_value=4),
       scenarios=st.booleans())
def test_batched_segments_match_per_segment_oracle(seed, n_ops,
                                                   scenarios):
    """For ANY random membership-event timeline, the batched
    dynamic-segment solver reproduces the legacy per-segment
    ``static_maxmin`` closures bit for bit on the numpy backend
    (zero-event ops included — n_ops=1 in isolated scenarios also
    exercises the lone-op mincap short-circuit)."""
    from _segment_props import run_engine_timeline_case
    run_engine_timeline_case(seed, n_ops=n_ops, engine="flow-np",
                             scenarios=scenarios)


@settings(max_examples=8, **FAST)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_problems=st.integers(min_value=1, max_value=10),
       with_loss=st.booleans())
def test_device_segment_rates_match_numpy_oracle(seed, n_problems,
                                                 with_loss):
    """The device (JAX) batched segment solver matches the numpy
    ``segment_rates_many`` oracle to <= 1e-6 relative, with and
    without per-segment loss/DCQCN factors."""
    pytest.importorskip("jax")
    from _segment_props import run_segment_rates_parity_case
    run_segment_rates_parity_case(seed, n_problems=n_problems,
                                  with_loss=with_loss)
