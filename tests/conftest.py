"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the single real
CPU device; multi-device behaviour is tested via subprocesses (see
tests/distributed_driver.py) so device count stays per-process."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def mesh1():
    from repro.launch.mesh import single_device_mesh
    return single_device_mesh()


def run_devices(py_src: str, n_devices: int = 8, timeout: int = 900):
    """Run a python snippet in a subprocess with n host devices.

    The snippet should raise / assert on failure.  Returns stdout.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", py_src], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n--- stdout\n"
            f"{proc.stdout[-4000:]}\n--- stderr\n{proc.stderr[-4000:]}")
    return proc.stdout
