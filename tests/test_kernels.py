"""Pallas kernel correctness: interpret=True vs pure-jnp oracles.

Per instructions: sweep shapes/dtypes for each kernel and assert_allclose
against the ref.py oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ============================================================ flash attn

ATTN_CASES = [
    # (B, Sq, Skv, H, KVH, D, causal, window)
    (1, 128, 128, 4, 4, 64, True, 0),          # MHA causal
    (2, 256, 256, 8, 2, 64, True, 0),          # GQA causal
    (1, 128, 128, 4, 2, 32, False, 0),         # bidirectional (encoder)
    (2, 256, 256, 4, 4, 64, True, 128),        # sliding window == block
    (1, 384, 384, 4, 2, 64, True, 96),         # window not block-aligned
    (1, 192, 192, 2, 1, 16, True, 0),          # ragged seq (pad path)
    (1, 100, 100, 2, 2, 64, True, 0),          # non-multiple-of-block
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    b, sq, skv, h, kvh, d, causal, window = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, sq, h, d), dtype)
    k = rand(k2, (b, skv, kvh, d), dtype)
    v = rand(k3, (b, skv, kvh, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_block_shape_sweep():
    """Block shape must not change the result (VMEM tiling invariance)."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (1, 256, 4, 64), jnp.float32)
    k = rand(k2, (1, 256, 2, 64), jnp.float32)
    v = rand(k3, (1, 256, 2, 64), jnp.float32)
    want = ref.mha_reference(q, k, v, causal=True, window=0)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256), (128, 128)]:
        got = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ============================================================ flash decode

DECODE_CASES = [
    # (B, S, H, KVH, D, kv_lens)
    (1, 512, 4, 4, 64, [512]),
    (2, 1024, 8, 2, 64, [1000, 37]),           # ragged fills
    (2, 512, 4, 1, 32, [1, 512]),              # single-token prefix
    (1, 768, 2, 2, 128, [600]),                # 1.5 blocks valid
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_oracle(case, dtype):
    b, s, h, kvh, d, kv_lens = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, h, d), dtype)
    k = rand(k2, (b, s, kvh, d), dtype)
    v = rand(k3, (b, s, kvh, d), dtype)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    got, m, l = ops.flash_decode(q, k, v, kv_len, block_k=512,
                                 interpret=True)
    want = ref.decode_reference(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))
    # statistics invariants: l > 0, m finite, acc = out * l recombines
    assert bool((np.asarray(l) > 0).all())
    assert bool(np.isfinite(np.asarray(m)).all())


def test_flash_decode_split_merge_equals_full():
    """Split the KV across two 'shards', run the kernel per shard, merge
    the (m, l, acc) partials with the Gleam combine — must equal the
    single-shard result.  This is the kernel-level proof that the decode
    path composes with core/collectives.softmax_combine."""
    b, s, h, kvh, d = 2, 1024, 4, 2, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (b, h, d), jnp.float32)
    k = rand(k2, (b, s, kvh, d), jnp.float32)
    v = rand(k3, (b, s, kvh, d), jnp.float32)
    kv_len = jnp.asarray([s, s], jnp.int32)
    full, _, _ = ops.flash_decode(q, k, v, kv_len, interpret=True)
    half = s // 2
    o1, m1, l1 = ops.flash_decode(q, k[:, :half], v[:, :half],
                                  jnp.asarray([half, half], jnp.int32),
                                  interpret=True)
    o2, m2, l2 = ops.flash_decode(q, k[:, half:], v[:, half:],
                                  jnp.asarray([half, half], jnp.int32),
                                  interpret=True)
    # associative merge (acc = out * l)
    m = jnp.maximum(m1, m2)
    s1, s2 = jnp.exp(m1 - m), jnp.exp(m2 - m)
    l = l1 * s1 + l2 * s2
    acc = (o1 * l1[..., None]) * s1[..., None] \
        + (o2 * l2[..., None]) * s2[..., None]
    merged = acc / l[..., None]
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


# ============================================================ ssd scan

SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (1, 256, 2, 64, 64, 128),
    (2, 128, 4, 32, 64, 64),
    (1, 384, 2, 64, 128, 128),
    (1, 100, 2, 16, 32, 64),                    # ragged (pad path)
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_oracle(case, dtype):
    b, s, h, p, n, chunk = case
    keys = jax.random.split(KEY, 5)
    x = rand(keys[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(keys[1], (b, s, h), jnp.float32))
    a = -jnp.abs(rand(keys[2], (b, s, h), jnp.float32)) * 0.1
    B_ = rand(keys[3], (b, s, n), dtype)
    C_ = rand(keys[4], (b, s, n), dtype)
    y, S = ops.ssd_scan(x, dt, a, B_, C_, chunk=chunk, interpret=True)
    y_ref, S_ref = ref.ssd_reference(x, dt, a, B_, C_)
    t = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **t)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunk_invariance():
    """Chunk size is a tiling choice — results must not depend on it."""
    b, s, h, p, n = 1, 256, 2, 32, 64
    keys = jax.random.split(KEY, 5)
    x = rand(keys[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(keys[1], (b, s, h), jnp.float32))
    a = -jnp.abs(rand(keys[2], (b, s, h), jnp.float32)) * 0.1
    B_ = rand(keys[3], (b, s, n), jnp.float32)
    C_ = rand(keys[4], (b, s, n), jnp.float32)
    y64, S64 = ops.ssd_scan(x, dt, a, B_, C_, chunk=64, interpret=True)
    y128, S128 = ops.ssd_scan(x, dt, a, B_, C_, chunk=128, interpret=True)
    y256, S256 = ops.ssd_scan(x, dt, a, B_, C_, chunk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y128),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y128), np.asarray(y256),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S64), np.asarray(S256),
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_model_chunked_impl():
    """The pure-jnp ssd_chunked in models/ssm.py (used by the model) and
    the Pallas kernel agree — kernel can be swapped in transparently."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 1, 256, 2, 32, 64
    keys = jax.random.split(KEY, 5)
    x = rand(keys[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(keys[1], (b, s, h), jnp.float32))
    a = -jnp.abs(rand(keys[2], (b, s, h), jnp.float32)) * 0.1
    B_ = rand(keys[3], (b, s, n), jnp.float32)
    C_ = rand(keys[4], (b, s, n), jnp.float32)
    y_model, S_model = ssd_chunked(x, dt * 0 + dt, a, B_, C_, 64)
    # model's ssd_chunked takes x scaled by dt inside; signature (x, dt, a)
    y_kern, S_kern = ops.ssd_scan(x, dt, a, B_, C_, chunk=64,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_kern), np.asarray(S_model),
                               rtol=1e-3, atol=1e-3)
