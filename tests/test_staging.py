"""Fleet-scale sweep plane: staging cache + vectorized path derivation.

The staging cache (core/staging.py) memoizes derived artifacts —
unicast paths, multicast tree edges, per-receiver latencies, per-op
flow layouts — on the topology, keyed by its (structural revision,
down-set) fingerprint.  The contract under test:

- fixed-seed results are BIT-identical with the cache enabled or
  disabled, on both flow backends, for every transport — including a
  sweep whose fault op forces a mid-sweep invalidation;
- `Topology.paths_many` (batched CSR frontier sweep) returns exactly
  what the scalar `path_links` walk returns, downed links included;
- fingerprint semantics: `connect` invalidates, a transient
  down/clear round trip does NOT (fault staging relies on this), a
  persistent down DOES;
- the `candidate_ports` memo stays under its byte budget no matter how
  many destinations churn through it;
- the packet engine's `staging_cache=False` mode disables the routing
  memos without changing results.
"""
from __future__ import annotations

import pytest

from repro.core import fattree
from repro.core.engine import FlowEngine, PacketEngine, make_engine
from repro.core.faults import FaultEvent
from repro.core.staging import StagingCache
from repro.core.workload import GroupOp, MemberEvent, Workload

def small_fat_tree():
    return fattree.fat_tree(n_pods=2, leaves_per_pod=2, hosts_per_leaf=4,
                            aggs_per_pod=2, bw=100 * fattree.GBPS)


def leaf_of(topo, host):
    """The switch a host hangs off (hosts have exactly one port)."""
    return topo.ports[host][0][0]


def sweep_workloads(hosts):
    """A representative static sweep: every transport + unicast mesh."""
    wls = []
    for transport in ("gleam", "ring", "binary-tree", "multiunicast"):
        wl = Workload(f"sweep/{transport}")
        wl.bcast(hosts[:6], 1 << 20, transport=transport, key=3)
        wl.bcast(hosts[2:9], 256 << 10, transport=transport)
        wls.append(wl)
    mesh = Workload("sweep/mesh")
    for i in range(4):
        mesh.unicast(hosts[i], hosts[(i + 3) % 8], 512 << 10, key=i)
    mesh.allreduce(hosts[:5], 1 << 20)
    wls.append(mesh)
    return wls


def record_tuples(recss):
    return [[(r.msg_id, r.t_submit, r.t_sender_cqe,
              tuple(sorted(r.t_deliver.items())), r.error)
             for r in recs] for recs in recss]


# ===================================================== vectorized routing

def test_paths_many_matches_scalar_walk():
    topo = small_fat_tree()
    reqs = [(src, dst, key)
            for src in topo.hosts[:4]
            for dst in topo.hosts[4:10]
            for key in (0, 1, 7)]
    batched = topo.paths_many(reqs)
    for (src, dst, key), hops in zip(reqs, batched):
        assert hops == tuple(topo.path_links(src, dst, key))


def test_paths_many_respects_downed_links():
    topo = small_fat_tree()
    # take down one leaf->agg uplink; paths must detour identically
    leaf = leaf_of(topo, topo.hosts[0])
    switches = set(topo.switches)
    agg = next(peer for _, (peer, _) in sorted(topo.ports[leaf].items())
               if peer in switches)
    topo.set_link_down(leaf, agg, True)
    reqs = [(topo.hosts[0], dst, k) for dst in topo.hosts[8:16]
            for k in (0, 1)]
    batched = topo.paths_many(reqs)
    for (src, dst, key), hops in zip(reqs, batched):
        assert hops == tuple(topo.path_links(src, dst, key))


def test_paths_many_raises_on_unreachable():
    topo = small_fat_tree()
    with pytest.raises(KeyError):
        topo.paths_many([(topo.hosts[0], "nonexistent-host", 0)])
    # an isolated destination (its only link downed) is unreachable
    iso = topo.hosts[-1]
    topo.set_link_down(iso, leaf_of(topo, iso), True)
    with pytest.raises(ValueError):
        topo.paths_many([(topo.hosts[0], iso, 0)])


# ==================================================== cache-off = cache-on

@pytest.mark.parametrize("backend", ["flow", "flow-np"])
def test_flow_bit_identity_cache_on_vs_off(backend):
    t_on, t_off = small_fat_tree(), small_fat_tree()
    wls = sweep_workloads(t_on.hosts)
    on = make_engine(backend, t_on, staging_cache=True)
    off = make_engine(backend, t_off, staging_cache=False)
    r_on = record_tuples(on.run_workloads(wls))
    r_off = record_tuples(off.run_workloads(wls))
    assert r_on == r_off
    stats = on.staging_stats()
    assert stats["misses"] > 0
    # second pass over the SAME topology must hit and stay identical
    on2 = make_engine(backend, t_on, staging_cache=True)
    assert record_tuples(on2.run_workloads(wls)) == r_on
    assert on2.staging_stats()["hit_rate"] > 0.5


@pytest.mark.parametrize("backend", ["flow", "flow-np"])
def test_flow_bit_identity_with_fault_invalidation_mid_sweep(backend):
    """A sweep mixing static ops, a fault op, and a persistent topology
    change between runs: cache-on must equal cache-off throughout."""
    t_on, t_off = small_fat_tree(), small_fat_tree()
    hosts = t_on.hosts

    def wls():
        wl1 = Workload("pre")
        wl1.bcast(hosts[:6], 1 << 20, key=1)
        leaf = leaf_of(t_on, hosts[1])
        switches = set(t_on.switches)
        agg = next(peer for _, (peer, _) in
                   sorted(t_on.ports[leaf].items()) if peer in switches)
        wl2 = Workload("faulty")
        wl2.bcast(hosts[:6], 1 << 20, key=1, faults=(
            FaultEvent("link_down", 2e-5, node=leaf, peer=agg),))
        wl3 = Workload("dynamic")
        wl3.bcast(hosts[:5], 1 << 20, events=(
            MemberEvent("join", hosts[6], 1e-5),))
        return [wl1, wl2, wl3]

    on = make_engine(backend, t_on, staging_cache=True)
    off = make_engine(backend, t_off, staging_cache=False)
    assert record_tuples(on.run_workloads(wls())) == \
        record_tuples(off.run_workloads(wls()))

    # persistent fabric change: shared cache must invalidate, results
    # must still agree
    for topo in (t_on, t_off):
        topo.set_link_down(topo.hosts[2], leaf_of(topo, topo.hosts[2]),
                           True)
    inv0 = StagingCache.of(t_on).invalidations
    on2 = make_engine(backend, t_on, staging_cache=True)
    off2 = make_engine(backend, t_off, staging_cache=False)
    wl = Workload("post")
    wl.bcast(hosts[:2] + hosts[3:6], 1 << 20, key=1)
    assert record_tuples(on2.run_workloads([wl])) == \
        record_tuples(off2.run_workloads([wl]))
    assert StagingCache.of(t_on).invalidations > inv0


def test_packet_engine_route_cache_off_bit_identity():
    t_on, t_off = small_fat_tree(), small_fat_tree()
    wl = Workload("pkt")
    wl.bcast(t_on.hosts[:5], 256 << 10, key=2)
    wl.unicast(t_on.hosts[5], t_on.hosts[1], 64 << 10)
    on = PacketEngine(t_on, seed=7, staging_cache=True)
    off = PacketEngine(t_off, seed=7, staging_cache=False)
    wl2 = Workload("pkt")
    wl2.bcast(t_off.hosts[:5], 256 << 10, key=2)
    wl2.unicast(t_off.hosts[5], t_off.hosts[1], 64 << 10)
    assert record_tuples(on.run_workloads([wl])) == \
        record_tuples(off.run_workloads([wl2]))
    assert t_on.route_cache and not t_off.route_cache


# ======================================================= fingerprint rules

def test_fingerprint_transient_fault_round_trip_preserves_cache():
    topo = small_fat_tree()
    eng = FlowEngine(topo)
    wl = Workload("w")
    wl.bcast(topo.hosts[:6], 1 << 20)
    eng.run_workloads([wl])
    cache = StagingCache.of(topo)
    n_paths, inv0 = len(cache.paths), cache.invalidations
    assert n_paths > 0
    fp = topo.fingerprint()
    topo.set_link_down(topo.hosts[0], leaf_of(topo, topo.hosts[0]), True)
    assert topo.fingerprint() != fp
    topo.clear_down()
    assert topo.fingerprint() == fp          # state-based, not a counter
    eng2 = FlowEngine(topo)
    eng2.run_workloads([wl])
    assert cache.invalidations == inv0       # artifacts survived
    assert len(cache.paths) == n_paths


def test_fingerprint_connect_invalidates():
    topo = small_fat_tree()
    cache = StagingCache.of(topo)
    cache.paths[("x", "y", 0)] = (1, 2)
    topo.add_host("h-extra")
    topo.connect("h-extra", topo.switches[0], bw=100 * fattree.GBPS,
                 delay=1e-6)
    assert cache.sync().paths == {}
    assert cache.invalidations == 1


# ==================================================== candidate_ports memo

def test_candidate_ports_memo_stays_under_byte_budget():
    """Regression: many-destination churn (every host pairs with every
    other) keeps the memo at its byte-budget cap, evicting LRU —
    unbounded growth was the pre-budget failure mode."""
    topo = fattree.fat_tree(n_pods=4, leaves_per_pod=4, hosts_per_leaf=4,
                            aggs_per_pod=4, bw=100 * fattree.GBPS)
    # shrink the budget to its 1024-entry floor so the sweep overflows
    topo.CAND_CACHE_BYTES = 1
    cap = topo._cand_cache_cap()
    assert cap == 1024
    demand = set()
    for src in topo.hosts:
        for dst in topo.hosts[::3]:
            if src != dst:
                topo.path_links(src, dst, 0)
                demand.add((src, dst))
                assert len(topo._cand) <= cap
    # the sweep genuinely overflowed the cap (else the test is vacuous)
    assert len(demand) > cap
    assert len(topo._cand) == cap
    # routing answers are unaffected by eviction
    default_cap = fattree.Topology.CAND_CACHE_BYTES // \
        fattree.Topology._CAND_ENTRY_BYTES
    assert default_cap >= cap
    assert topo.path_links(topo.hosts[0], topo.hosts[-1], 0)


# ============================================================== telemetry

def test_staging_stats_shape():
    topo = small_fat_tree()
    eng = FlowEngine(topo)
    wl = Workload("w")
    wl.bcast(topo.hosts[:4], 1 << 20)
    eng.run_workloads([wl])
    stats = eng.staging_stats()
    for k in ("hits", "misses", "hit_rate", "invalidations", "paths",
              "trees", "lat", "ops"):
        assert k in stats
    assert 0.0 <= stats["hit_rate"] <= 1.0
