"""Shared drivers for the dynamic-segment solver invariants (ISSUE 10),
used by BOTH the hypothesis property tests
(``test_protocol_properties``) and the deterministic fixed-seed cases
in ``test_segments`` (run everywhere — hypothesis is optional).

Three acceptance properties, as executable drivers:

- **vectorized filling bit-identity** — the CSR/np.add.at
  ``static_maxmin`` reproduces the original per-flow-loop
  implementation bit for bit on arbitrary problems;
- **batched == per-segment oracle** — the batched segment solver
  (numpy and device paths) matches the legacy per-segment
  ``static_maxmin`` closures: bit-identical on the numpy backend,
  <= 1e-6 relative on the JAX backend (float64, same tol, same round
  cap — only reduction-order rounding differs);
- **zero-event bit-identity** — workloads with no events/faults never
  touch the segment machinery: batched and legacy modes produce
  bit-identical records on both flow backends.
"""
from __future__ import annotations

import numpy as np

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.flowsim import (FlowSim, LossParams, static_maxmin,
                                static_maxmin_loops)
from repro.core.workload import GroupOp, MemberEvent

NBYTES = 1 << 18
SEG_TOL = 1e-6              # jax-vs-oracle acceptance bound


def random_problem(rng, n_links: int, n_flows: int):
    """(cap, link_sets): random capacities and duplicate-free sets."""
    cap = rng.uniform(1e8, 4e9, n_links)
    hi = min(7, n_links + 1)
    sets = [tuple(int(x) for x in
                  rng.choice(n_links, size=int(rng.integers(1, hi)),
                             replace=False))
            for _ in range(n_flows)]
    return cap, sets


def run_solver_identity_case(seed: int, n_flows: int = 12,
                             n_links: int = 24) -> None:
    """Vectorized ``static_maxmin`` == loop oracle, bit for bit."""
    rng = np.random.default_rng(seed)
    cap, sets = random_problem(rng, n_links, n_flows)
    vec = static_maxmin(cap, sets)
    ref = static_maxmin_loops(cap, sets)
    assert vec.shape == ref.shape
    assert (vec == ref).all(), (vec, ref)


def random_dynamic_ops(rng, n_ops: int, pool: int = 12):
    """Random bcast ops with valid join/leave/fail timelines."""
    hosts = [f"h{i}" for i in range(pool)]
    ops = []
    for _ in range(n_ops):
        size = int(rng.integers(3, 7))
        members = [hosts[i] for i in
                   rng.choice(pool, size=size, replace=False)]
        spare = [h for h in hosts if h not in members]
        present = set(members)
        events = []
        t = 0.0
        for _ in range(int(rng.integers(0, 4))):
            t += float(rng.uniform(5e-6, 4e-5))
            if spare and rng.random() < 0.5:
                m = spare.pop(int(rng.integers(len(spare))))
                events.append(MemberEvent("join", m, t))
                present.add(m)
            else:
                cands = sorted(m for m in present if m != members[0])
                if not cands:
                    continue
                m = cands[int(rng.integers(len(cands)))]
                kind = "leave" if rng.random() < 0.5 else "fail"
                events.append(MemberEvent(kind, m, t))
                present.remove(m)
        ops.append(GroupOp("bcast", members, NBYTES,
                           events=tuple(events)))
    return ops


def _records(engine: str, mode: str, ops, loss_rate: float = 0.0,
             scenarios: bool = False):
    """Run ops on one engine/segment-solver mode; full record rows."""
    kw = {"loss_rate": loss_rate} if loss_rate else {}
    eng = make_engine(engine, fattree.testbed(n_hosts=14),
                      segment_solver=mode, **kw)
    if scenarios:                       # one op per isolated scenario
        recs = []

        def scenario(op):
            return lambda e: recs.append(e.stage(op))

        eng.run_many([scenario(op) for op in ops], timeout=60.0)
    else:                               # all ops contend in one fabric
        recs = [eng.stage(op) for op in ops]
        eng.run()
    return [(r.t_sender_cqe, sorted(r.t_deliver.items())) for r in recs]


def run_engine_timeline_case(seed: int, n_ops: int = 3,
                             engine: str = "flow-np",
                             scenarios: bool = False) -> None:
    """Batched vs legacy on a random event timeline: bit-identical on
    the numpy backend (same solver, same problems), <= 1e-6 on JAX."""
    rng = np.random.default_rng(seed)
    ops = random_dynamic_ops(rng, n_ops)
    got = _records(engine, "batched", ops, scenarios=scenarios)
    want = _records(engine, "legacy", ops, scenarios=scenarios)
    if engine == "flow-np":
        assert got == want, (got, want)
        return
    for (gc, gd), (wc, wd) in zip(got, want):
        assert abs(gc - wc) <= SEG_TOL * wc, (gc, wc)
        for (m, gt), (_, wt) in zip(gd, wd):
            assert abs(gt - wt) <= SEG_TOL * wt, (m, gt, wt)


def random_loss_params(rng) -> LossParams:
    """Plausible pre-folded loss-model inputs (see LossParams)."""
    return LossParams(q=float(rng.uniform(0.0, 0.05)),
                      wsq=float(rng.uniform(0.0, 1e-4)),
                      wnd=float(rng.choice([64.0, 256.0, 512.0])),
                      tail=0.0, ecn=bool(rng.random() < 0.5))


def run_segment_rates_parity_case(seed: int, n_problems: int = 6,
                                  with_loss: bool = True) -> None:
    """JAX ``segment_rates_many`` vs the numpy oracle, <= 1e-6."""
    from repro.core.flowsim_jax import JaxFlowSim
    topo = fattree.testbed(n_hosts=12)
    np_sim = FlowSim(topo)
    jx_sim = JaxFlowSim(topo)
    rng = np.random.default_rng(seed)
    n_links = len(np_sim.cap)
    problems = []
    for _ in range(n_problems):
        _, sets = random_problem(rng, n_links,
                                 int(rng.integers(2, 9)))
        lp = random_loss_params(rng) \
            if with_loss and rng.random() < 0.7 else None
        problems.append((tuple(sets), lp))
    want = np_sim.segment_rates_many(problems)
    got = jx_sim.segment_rates_many(problems)
    for g, w in zip(got, want):
        assert abs(g - w) <= SEG_TOL * w, (g, w)
