"""Multi-tenant fleet plane: workload builder, SLO metrics, census.

Contracts under test (apps/fleet.py):

- the seeded workload builder is deterministic per spec and actually
  makes tenants' trees overlap;
- per-tenant quantiles are monotone (p50 <= p99 <= p999 <= latency)
  and partition the op set;
- the flow engines' ANALYTIC connection census agrees exactly with the
  packet engine's MEASURED per-host QP counts (same reuse rules), and
  on aggregate MFT group occupancy;
- packet and flow engines agree on per-tenant SLOs within the fleet
  gate's 10% envelope at bandwidth-dominated sizes;
- a fleet sweep exercises the staging cache (hit rate > 0 and growing
  on a second pass over the same fabric).
"""
from __future__ import annotations

import pytest

from repro.apps.fleet import (FleetSpec, connection_census, fleet_workload,
                              mft_pressure_report, run_fleet,
                              tenant_quantiles)
from repro.core import fattree


def fabric():
    return fattree.fat_tree(n_pods=2, leaves_per_pod=2, hosts_per_leaf=4,
                            aggs_per_pod=2, bw=100 * fattree.GBPS)


SPEC = FleetSpec(n_tenants=3, groups_per_tenant=2, group_size=5,
                 nbytes=4 << 20, bg_unicasts=6, bg_incasts=1,
                 bg_fan_in=3, bg_nbytes=2 << 20, seed=0)


def test_fleet_workload_deterministic_and_overlapping():
    hosts = fabric().hosts
    wl1, wl2 = fleet_workload(hosts, SPEC), fleet_workload(hosts, SPEC)
    assert [(o.op, o.members, o.nbytes, o.phase) for o in wl1.ops] == \
        [(o.op, o.members, o.nbytes, o.phase) for o in wl2.ops]
    other = fleet_workload(hosts, FleetSpec(**{
        **{f.name: getattr(SPEC, f.name)
           for f in SPEC.__dataclass_fields__.values()}, "seed": 1}))
    assert [o.members for o in other.ops] != [o.members for o in wl1.ops]
    # tenants' member sets overlap (fabric sharing is the scenario)
    groups = [set(o.members) for o in wl1.ops if o.op == "bcast"]
    assert any(a & b for i, a in enumerate(groups)
               for b in groups[i + 1:])
    n_mcast = SPEC.n_tenants * SPEC.groups_per_tenant
    n_uni = SPEC.bg_unicasts + SPEC.bg_incasts * SPEC.bg_fan_in
    assert len(wl1.ops) == n_mcast + n_uni


def test_fleet_workload_rejects_tiny_fabric():
    with pytest.raises(ValueError):
        fleet_workload(["a", "b", "c"], SPEC)
    with pytest.raises(ValueError):
        FleetSpec(group_size=1)


def test_tenant_quantiles_monotone_and_partitioning():
    report = run_fleet("flow", fabric(), SPEC)
    tenants = report["tenants"]
    phases = {SPEC.tenant_phase(t) for t in range(SPEC.n_tenants)}
    assert phases | {"bg-mesh", "bg-incast"} == set(tenants)
    for q in tenants.values():
        assert 0.0 < q["p50"] <= q["p99"] <= q["p999"] <= q["latency"]
    assert sum(q["n_ops"] for q in tenants.values()) == \
        len(fleet_workload(fabric().hosts, SPEC).ops)
    assert report["errors"] == 0


def test_census_flow_analytic_matches_packet_measured():
    """The analytic census mirrors the packet engine's connection reuse
    rules — per-host QP counts must agree EXACTLY, as must aggregate
    MFT group occupancy (per-switch splits may differ: envelope-flooded
    installs vs geometric trees)."""
    rf = run_fleet("flow", fabric(), SPEC)
    rp = run_fleet("packet", fabric(), SPEC, seed=1)
    cf, cp = rf["census"], rp["census"]
    assert not cf["measured"] and cp["measured"]
    assert cf["qp_per_host"] == cp["qp_per_host"]
    assert cf["qp_total"] == cp["qp_total"] > 0
    assert cf["nic_qp_peak"] == cp["nic_qp_peak"]
    assert cf["mft_groups_total"] == cp["mft_groups_total"] > 0
    assert cp["mft_evictions"] == 0          # fabric not under pressure
    assert cf["mft_bytes_total"] > 0 and cp["mft_bytes_total"] > 0


def test_census_reuse_rules():
    """Duplicate member sets / unicast pairs must not double-count."""
    topo = fabric()
    hosts = topo.hosts
    from repro.core.workload import Workload
    wl = Workload("dup")
    wl.bcast(hosts[:5], 1 << 20, key=0)
    wl.bcast(hosts[:5], 1 << 20, key=0)      # same group, reused
    wl.unicast(hosts[5], hosts[6], 1 << 20)
    wl.unicast(hosts[5], hosts[6], 1 << 20)  # same channel, reused
    from repro.core.engine import make_engine
    eng = make_engine("flow", topo)
    eng.run_workloads([wl])
    census = connection_census(eng, wl)
    assert census["qp_per_host"][hosts[0]] == 1
    assert census["qp_per_host"][hosts[5]] == 1
    assert census["qp_per_host"][hosts[6]] == 1
    assert census["qp_total"] == 7           # 5 group members + RC pair
    # and the packet engine agrees on the same reuse
    peng = make_engine("packet", fabric(), seed=1)
    wl2 = Workload("dup")
    wl2.bcast(hosts[:5], 1 << 20, key=0)
    wl2.bcast(hosts[:5], 1 << 20, key=0)
    wl2.unicast(hosts[5], hosts[6], 1 << 20)
    wl2.unicast(hosts[5], hosts[6], 1 << 20)
    peng.run_workloads([wl2])
    assert connection_census(peng)["qp_per_host"] == \
        census["qp_per_host"]


def test_packet_vs_flow_slo_parity():
    rf = run_fleet("flow", fabric(), SPEC)
    rp = run_fleet("packet", fabric(), SPEC, seed=1)
    for phase, qf in rf["tenants"].items():
        a, b = qf["latency"], rp["tenants"][phase]["latency"]
        assert abs(a - b) / max(a, b) <= 0.10, (phase, a, b)


def test_fleet_staging_cache_hits():
    topo = fabric()
    r1 = run_fleet("flow", topo, SPEC)
    assert r1["staging"]["hits"] > 0
    r2 = run_fleet("flow", topo, SPEC)       # same fabric: warm
    assert r2["staging"]["hit_rate"] > r1["staging"]["hit_rate"]
    assert r2["tenants"] == r1["tenants"]    # and bit-identical


def test_mft_pressure_registration_churn():
    """LRU pressure: churning more registrations through the fabric
    than the tables can hold evicts, stays within capacity everywhere,
    and the NEWEST group still broadcasts end to end."""
    pr = mft_pressure_report(fabric(), n_groups=24, group_size=5,
                             capacity=4, seed=1)
    assert pr["evictions"] > 0
    assert 0 < pr["occupancy_peak"] <= 4
    for s in pr["switches"].values():
        assert s["occupancy"] <= s["capacity"] == 4
    assert pr["last_group_ok"]
    assert pr["last_group_jct"] > 0


def test_flow_backends_agree():
    r_jax = run_fleet("flow", fabric(), SPEC)
    r_np = run_fleet("flow-np", fabric(), SPEC)
    for phase, q in r_jax["tenants"].items():
        for k in ("p50", "p99", "latency"):
            assert q[k] == pytest.approx(r_np["tenants"][phase][k],
                                         rel=1e-6)
    assert r_jax["census"] == r_np["census"]
