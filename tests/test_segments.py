"""Batched dynamic-segment solver regressions (PR 10).

Deterministic fixed-seed halves of the invariants driven by
``_segment_props`` (the hypothesis wrappers live in
``test_protocol_properties``), plus the dynamic-op registry
token regression.
"""
import math

import numpy as np
import pytest

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.flowsim import static_maxmin, static_maxmin_loops
from repro.core.flowsim_jax import HAS_JAX
from repro.core.workload import GroupOp, MemberEvent

needs_jax = pytest.mark.skipif(not HAS_JAX, reason="jax unavailable")


# ------------------------------------------------- vectorized filling

def test_static_maxmin_bit_identity():
    from _segment_props import run_solver_identity_case
    for seed in range(8):
        run_solver_identity_case(seed)
    run_solver_identity_case(99, n_flows=1, n_links=3)
    run_solver_identity_case(100, n_flows=40, n_links=8)


def test_static_maxmin_edge_cases():
    assert static_maxmin(np.array([1e9]), []).shape == (0,)
    # single flow, and everyone contending for one shared link
    cap = np.array([1e9, 2e9])
    for sets in ([(0, 1)], [(0,), (0,), (0, 1)]):
        vec = static_maxmin(cap, sets)
        ref = static_maxmin_loops(cap, sets)
        assert (vec == ref).all()


# -------------------------------------------- dynamic-op registry keys

def test_dynamic_registry_tokens_never_reused():
    """Allocate/free dynamic ops in a loop: the old ``id(hidden)`` keys
    could collide once records were garbage-collected; monotonic tokens
    must never repeat, registries must drain after every run, and the
    workload must stay deterministic across iterations."""
    eng = make_engine("flow-np", fattree.testbed(n_hosts=8))
    events = (MemberEvent("leave", "h3", 2e-5),)
    seen, jcts = set(), []
    for _ in range(6):
        t0 = eng.now
        rec = eng.stage(GroupOp("bcast", ["h0", "h1", "h2", "h3"],
                                1 << 18, events=events))
        toks = set(eng._dyn_links)
        assert toks and not (toks & seen)
        seen |= toks
        eng.run()
        assert not eng._dyn_links and not eng._dyn_meta \
            and not eng._seg_fair
        jcts.append(rec.t_sender_cqe - t0)
    assert eng._dyn_seq == 6
    # absolute-time offsets cost a last-place ulp per iteration, no more
    assert all(math.isclose(j, jcts[0], rel_tol=1e-9) for j in jcts)


# ------------------------------------------------ batched == oracle

def test_batched_matches_legacy_numpy():
    from _segment_props import run_engine_timeline_case
    for seed in range(3):
        run_engine_timeline_case(seed, n_ops=3, engine="flow-np")


def test_lone_dynamic_op_scenarios_numpy():
    from _segment_props import run_engine_timeline_case
    run_engine_timeline_case(3, n_ops=2, engine="flow-np",
                             scenarios=True)


@needs_jax
def test_batched_matches_legacy_jax():
    from _segment_props import run_engine_timeline_case
    run_engine_timeline_case(0, n_ops=3, engine="flow")


@needs_jax
def test_segment_rates_many_parity():
    from _segment_props import run_segment_rates_parity_case
    for seed in range(4):
        run_segment_rates_parity_case(seed)
    run_segment_rates_parity_case(7, with_loss=False)


# ------------------------------------------------ zero-event identity

def _static_records(engine, mode):
    eng = make_engine(engine, fattree.testbed(n_hosts=10),
                      segment_solver=mode)
    ops = [GroupOp("bcast", [f"h{i}" for i in range(5)], 1 << 18),
           GroupOp("bcast", ["h5", "h6", "h7"], 1 << 16)]
    recs = [eng.stage(op) for op in ops]
    eng.run()
    return [(r.t_sender_cqe, sorted(r.t_deliver.items()))
            for r in recs]


def test_zero_event_bit_identity_numpy():
    assert _static_records("flow-np", "batched") == \
        _static_records("flow-np", "legacy")


@needs_jax
def test_zero_event_bit_identity_jax():
    assert _static_records("flow", "batched") == \
        _static_records("flow", "legacy")


# ------------------------------------------------ memoized warm starts

def test_segment_memo_stable_across_runs():
    """Identical workloads re-run on one engine hit the segment-rate
    memo (warm start) and must reproduce the first run exactly."""
    eng = make_engine("flow-np", fattree.testbed(n_hosts=8))

    def go():
        t0 = eng.now
        recs = [eng.stage(GroupOp("bcast", ["h0", "h1", "h2", "h3"],
                                  1 << 18,
                                  events=(MemberEvent("join", "h5",
                                                      1.5e-5),))),
                eng.stage(GroupOp("bcast", ["h4", "h6", "h7"],
                                  1 << 18))]
        eng.run()
        return [r.t_sender_cqe - t0 for r in recs]

    first = go()
    memo = eng._sim.cache.sync().misc.get("segrates")
    assert memo                      # batched solves were memoized
    assert go() == first
