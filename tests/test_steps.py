"""Semantics of the performance machinery: every §Perf optimization must
be a pure re-schedule — same math, different layout/loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch import steps
from repro.launch.mesh import single_device_mesh
from repro.models import model as mdl
from repro.models.blocks import init_params, param_structs
from repro.models.model import model_defs
from repro.optim import adamw

ARCH = "granite_3_2b"


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH, smoke=True).replace(n_layers=2,
                                               compute_dtype="float32")
    mesh = single_device_mesh()
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                               jnp.int32),
        "loss_mask": jnp.ones((4, 32), jnp.float32),
    }
    return cfg, mesh, params, batch


def naive_loss(params, batch, cfg, mesh):
    """Reference: full-logits cross-entropy."""
    x, aux = mdl.forward_hidden(params, batch, cfg, mesh)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["targets"][..., None],
                               -1)[..., 0]
    nll = (logz - gold) * batch["loss_mask"]
    return nll.sum() / batch["loss_mask"].sum()


class TestChunkedXent:
    def test_matches_full_logits_loss(self, setup):
        cfg, mesh, params, batch = setup
        with mesh:
            (total, metrics) = mdl.loss_fn(params, batch, cfg, mesh)
            want = naive_loss(params, batch, cfg, mesh)
        np.testing.assert_allclose(float(metrics["loss"]), float(want),
                                   rtol=1e-5)

    def test_chunk_size_invariant(self, setup):
        cfg, mesh, params, batch = setup
        vals = []
        for chunk in (8, 16, 32):
            c = cfg.replace(xent_chunk=chunk)
            with mesh:
                _, m = mdl.loss_fn(params, batch, c, mesh)
            vals.append(float(m["loss"]))
        np.testing.assert_allclose(vals[0], vals[1], rtol=1e-6)
        np.testing.assert_allclose(vals[0], vals[2], rtol=1e-6)

    def test_gradients_match(self, setup):
        cfg, mesh, params, batch = setup
        with mesh:
            g1 = jax.grad(lambda p: mdl.loss_fn(p, batch, cfg, mesh)[1]
                          ["loss"])(params)
            g2 = jax.grad(lambda p: naive_loss(p, batch, cfg, mesh))(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)


class TestGradAccumulation:
    def test_accum_equals_full_batch(self, setup):
        cfg, mesh, params, batch = setup
        opt = adamw.init(params)
        s1 = steps.make_train_step(cfg, mesh, accum_steps=1)
        s4 = steps.make_train_step(cfg, mesh, accum_steps=4)
        with mesh:
            p1, o1, m1 = jax.jit(s1)(params, opt, batch)
            p4, o4, m4 = jax.jit(s4)(params, adamw.init(params), batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)


class TestPrefillStep:
    def test_last_token_logits_match_forward(self, setup):
        cfg, mesh, params, batch = setup
        prefill = steps.make_prefill_step(cfg, mesh)
        with mesh:
            got = prefill(params, {"tokens": batch["tokens"]})
            full, _ = mdl.forward(params, {"tokens": batch["tokens"]},
                                  cfg, mesh)
        np.testing.assert_allclose(np.asarray(got[:, 0]),
                                   np.asarray(full[:, -1]), rtol=1e-4,
                                   atol=1e-4)


class TestLoweringSpecs:
    @pytest.mark.parametrize("shape", list(steps.SHAPE_TABLE))
    def test_smoke_cells_lower_on_tiny_mesh(self, shape):
        """The dry-run machinery itself (specs, shardings, donation) is
        exercised on a 1x1 mesh with smoke configs — no 512-device env
        needed to validate the plumbing."""
        cfg = get_config("mixtral_8x7b", smoke=True)
        mesh = single_device_mesh()
        ok, _ = steps.shape_runnable(cfg, shape)
        if not ok:
            pytest.skip("shape not runnable for this arch")
        # shrink the shape table entry to smoke size
        orig = steps.SHAPE_TABLE[shape]
        small = dict(orig, seq=64, batch=4)
        steps.SHAPE_TABLE[shape] = small
        try:
            lowered, spec = steps.lower_cell(cfg, shape, mesh)
            assert lowered is not None
            assert spec.n_params > 0
        finally:
            steps.SHAPE_TABLE[shape] = orig


class TestShardingPlanner:
    def test_divisibility_fallback(self):
        from repro.parallel.sharding import ShardingPlan
        import numpy as onp
        from jax.sharding import Mesh
        devs = onp.asarray(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, ("data", "model"))
        plan = ShardingPlan(mesh)
        # heads=24 on model=1: trivially placed; logical resolution only
        spec = plan.spec(("embed", "heads", None), (64, 24, 16))
        assert spec is not None

    def test_inference_rules_drop_fsdp(self):
        from repro.parallel.sharding import (DEFAULT_RULES,
                                             INFERENCE_RULES)
        assert DEFAULT_RULES["embed"][0] == ("pod", "data")
        assert INFERENCE_RULES["embed"] == ((),)


class TestRooflineParsing:
    def test_collective_bytes_parser(self):
        from repro.launch.roofline import collective_bytes
        hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[4,4]{1,0} all-reduce-start(%y), to_apply=%add
  %ar.2 = f32[4,4]{1,0} all-reduce-done(%ar.1)
  %cp = (f32[8]{0}, f32[8]{0}) collective-permute(%z, %w)
  %dot = f32[2,2]{1,0} dot(%a, %b)
"""
        out = collective_bytes(hlo)
        assert out["bytes"]["all-gather"] == 16 * 128 * 2
        assert out["bytes"]["all-reduce"] == 4 * 4 * 4   # start only
        assert out["bytes"]["collective-permute"] == 2 * 8 * 4
        assert out["counts"]["all-gather"] == 1

    def test_model_flops_moe_uses_active_params(self):
        from repro.launch.roofline import model_flops
        cfg = get_config("mixtral_8x7b")
        dense_equiv = get_config("granite_3_2b")
        info = dict(seq=128, batch=4, kind="train")
        f_moe = model_flops(cfg, info, int(47e9), 16)
        # active ~ 13/47 of total for mixtral top-2-of-8
        assert f_moe < 6 * 47e9 * 512 / 16
        assert f_moe > 6 * 47e9 * 512 / 16 * 0.2
