"""Workload IR (core/workload.py) + its engine integration.

Covers the ISSUE-3 satellite checklist: IR round-trip through dicts,
registry errors that NAME the valid choices (transports and engines),
the deprecated ``add_*`` shims (warn but keep working), ``run_workloads``
scenario semantics, and the packet engine's between-scenario quiesce.
"""
from __future__ import annotations

import pytest

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import (GroupOp, TRANSPORT_CHOICES, Transport,
                                 Workload, get_transport, register_transport,
                                 relay_plan, transport_names)


# ================================================================ the IR

def test_groupop_roundtrip():
    op = GroupOp("bcast", ("h0", "h1", "h2"), 1 << 20, transport="ring",
                 source="h1", key=3, chunks=4, phase="weights")
    back = GroupOp.from_dict(op.to_dict())
    assert back == op and back.phase == "weights"


def test_workload_roundtrip():
    wl = Workload("fig09/1MB")
    wl.bcast(["h0", "h1", "h2", "h3"], 1 << 20)
    wl.unicast("h0", "h1", 4 << 10, key=7)
    wl.write(["h0", "h1"], 8 << 10, same_mr=True, transport="gleam")
    wl.allreduce(["h0", "h1", "h2"], 64 << 10, transport="binary-tree")
    back = Workload.from_dict(wl.to_dict())
    assert back.name == wl.name and back.ops == wl.ops


def test_workload_meta_roundtrip():
    """App-plane generator specs ride in ``meta`` (ISSUE-8): the tag
    survives the dict round-trip, and metaless dumps stay stable (no
    ``meta`` key) so old fixtures keep parsing."""
    wl = Workload("serve/w0",
                  meta={"kind": "serve", "window": 0,
                        "spec": {"kind": "poisson", "rate": 1e4,
                                 "n": 16, "seed": 3, "trace": []}})
    wl.allreduce(["h0", "h1"], 4 << 10, phase="prefill")
    d = wl.to_dict()
    assert d["meta"]["spec"]["seed"] == 3
    back = Workload.from_dict(d)
    assert back.meta == wl.meta
    assert back.ops[0].phase == "prefill"
    plain = Workload("x")
    plain.bcast(["h0", "h1"], 1024)
    assert "meta" not in plain.to_dict()
    assert Workload.from_dict(plain.to_dict()).meta == {}


def test_groupop_validation():
    members = ("h0", "h1")
    with pytest.raises(ValueError, match="unknown op"):
        GroupOp("scatter", members, 1024)
    with pytest.raises(ValueError, match="nbytes"):
        GroupOp("bcast", members, 0)
    with pytest.raises(ValueError, match="exactly"):
        GroupOp("unicast", ("h0", "h1", "h2"), 1024)
    with pytest.raises(ValueError, match=">= 2 members"):
        GroupOp("bcast", ("h0",), 1024)
    with pytest.raises(ValueError, match="not in members"):
        GroupOp("bcast", members, 1024, source="h9")
    with pytest.raises(ValueError, match="chunks"):
        GroupOp("bcast", members, 1024, chunks=0)


def test_groupop_normalizes_transport_aliases():
    op = GroupOp("bcast", ("h0", "h1"), 1024, transport="bintree")
    assert op.transport == "binary-tree"


def test_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown GroupOp fields"):
        GroupOp.from_dict({"op": "bcast", "members": ["h0", "h1"],
                           "nbytes": 1024, "fanout": 2})
    with pytest.raises(ValueError, match="unknown Workload fields"):
        Workload.from_dict({"name": "x", "ops": [], "extra": 1})


def test_ordered_members_rotates_source_first():
    op = GroupOp("bcast", ("h0", "h1", "h2", "h3"), 1024, source="h2")
    assert op.ordered_members() == ["h2", "h0", "h1", "h3"]


# =============================================================== registry

def test_unknown_transport_raises_valueerror_listing_names():
    with pytest.raises(ValueError) as ei:
        get_transport("carrier-pigeon")
    for name in TRANSPORT_CHOICES:
        assert name in str(ei.value)
    with pytest.raises(ValueError):
        GroupOp("bcast", ("h0", "h1"), 1024, transport="carrier-pigeon")


def test_unknown_engine_raises_valueerror_listing_names():
    with pytest.raises(ValueError) as ei:
        make_engine("ns3", fattree.testbed())
    for name in ("packet", "flow", "flow-np"):
        assert name in str(ei.value)


def test_builtin_transports_registered():
    assert set(TRANSPORT_CHOICES) <= set(transport_names())
    assert get_transport("gleam").native
    assert not get_transport("ring").native


def test_register_custom_transport_and_relay_plan():
    """Any edge-providing strategy slots in: a chain transport's hops
    fall out of the edge list (relay_plan walks parent pointers)."""
    register_transport(Transport(
        "test-chain",
        relay_edges=lambda m: [(m[i], m[i + 1])
                               for i in range(len(m) - 1)],
        chunked=True))
    try:
        plan = relay_plan(get_transport("test-chain"),
                          ["a", "b", "c", "d"])
        assert plan == [("a", "b", 1), ("b", "c", 2), ("c", "d", 3)]
        # flow engine lowers it like any built-in
        eng = make_engine("flow", fattree.testbed())
        rec = eng.stage(GroupOp("bcast", ("h0", "h1", "h2", "h3"),
                                256 << 10, transport="test-chain"))
        eng.run()
        assert rec.jct(3) != float("inf")
    finally:
        from repro.core import workload as wl
        wl._TRANSPORTS.pop("test-chain", None)


def test_relay_plan_deep_ring_no_recursion_limit():
    members = [f"h{i}" for i in range(3000)]
    plan = relay_plan(get_transport("ring"), members)
    assert plan[-1][2] == 2999


# ===================================================== deprecation shims

@pytest.mark.parametrize("engine", ["packet", "flow"])
def test_add_bcast_shim_warns_and_matches_stage(engine):
    members = ["h0", "h1", "h2", "h3"]
    legacy = make_engine(engine, fattree.testbed())
    with pytest.deprecated_call():
        r_old = legacy.add_bcast(members, 1 << 20)
    legacy.run(timeout=60.0)
    new = make_engine(engine, fattree.testbed())
    r_new = new.stage(GroupOp("bcast", members, 1 << 20))
    new.run(timeout=60.0)
    assert r_old.jct(3) == pytest.approx(r_new.jct(3), rel=1e-9)


def test_add_write_and_unicast_shims_warn():
    eng = make_engine("flow", fattree.testbed())
    with pytest.deprecated_call():
        eng.add_write(["h0", "h1", "h2"], 64 << 10)
    with pytest.deprecated_call():
        eng.add_unicast("h0", "h1", 64 << 10)
    eng.run()


# ======================================================== run_workloads

@pytest.mark.parametrize("engine", ["packet", "flow"])
def test_run_workloads_returns_per_op_records(engine):
    members = ["h0", "h1", "h2", "h3"]
    wl_a = Workload("a")
    wl_a.bcast(members, 256 << 10)
    wl_a.unicast("h0", "h1", 64 << 10)
    wl_b = Workload("b")
    wl_b.bcast(members, 256 << 10, transport="multiunicast")
    eng = make_engine(engine, fattree.testbed())
    recss = eng.run_workloads([wl_a, wl_b], timeout=60.0)
    assert [len(r) for r in recss] == [2, 1]
    assert recss[0][0].jct(3) != float("inf")
    assert recss[0][1].jct(1) != float("inf")
    assert recss[1][0].jct(3) != float("inf")


def test_run_workloads_scenarios_are_independent():
    """Two identical workloads batched together must each match the
    solo run — scenarios never share bandwidth (flow engine)."""
    members = ["h0", "h1", "h2", "h3"]
    wl = Workload("solo")
    wl.bcast(members, 1 << 20)
    solo = make_engine("flow", fattree.testbed())
    ref = solo.run_workloads([wl])[0][0]
    eng = make_engine("flow", fattree.testbed())
    recss = eng.run_workloads([Workload("x", list(wl.ops)),
                               Workload("y", list(wl.ops))])
    for recs in recss:
        assert recs[0].jct(3) == pytest.approx(ref.jct(3), rel=1e-6)


def test_packet_run_many_quiesces_between_scenarios():
    """Satellite: the serial fallback must reset sim time and drain
    residual events so scenarios are independent experiments — the
    same heavy scenario twice must measure the same JCT, with the
    second starting on a fresh clock and an empty event queue."""
    members = ["h0", "h1", "h2", "h3"]
    wl = Workload("w")
    wl.bcast(members, 1 << 20)
    eng = make_engine("packet", fattree.testbed())
    recss = eng.run_workloads([Workload("a", list(wl.ops)),
                               Workload("b", list(wl.ops))])
    ja, jb = recss[0][0].jct(3), recss[1][0].jct(3)
    assert ja != float("inf") and jb != float("inf")
    assert jb == pytest.approx(ja, rel=1e-6)       # independent experiments
    assert recss[1][0].t_submit == 0.0             # clock was reset
    assert not eng.net.sim._q                      # events were drained


def test_packet_quiesce_resets_congestion_state():
    """DCQCN rate cuts from scenario A must not leak into scenario B."""
    members = ["h0", "h1", "h2", "h3"]
    eng = make_engine("packet", fattree.testbed())
    wl = Workload("w")
    wl.bcast(members, 4 << 20)
    eng.run_workloads([wl, wl])
    for host in eng.net.sim.hosts.values():
        for qp in host.qps.values():
            assert qp.rate.rate == qp.rate.peak


# ============================================================= allreduce

@pytest.mark.parametrize("engine", ["packet", "flow"])
def test_allreduce_root_delivers_at_reduce_completion(engine):
    """allreduce covers every member (root included): root's delivery
    is the reduce completion, receivers follow after the bcast."""
    members = ["h0", "h1", "h2", "h3"]
    eng = make_engine(engine, fattree.testbed())
    rec = eng.stage(GroupOp("allreduce", members, 256 << 10))
    eng.run(timeout=60.0)
    assert set(rec.t_deliver) == set(members)
    assert rec.t_deliver["h0"] <= min(rec.t_deliver[m]
                                      for m in members[1:])
    assert rec.complete
