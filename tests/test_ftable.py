"""Unit tests for the extended multicast forwarding table (Fig. 5):
entry install / lookup / aggregation queries, the §3.3 memory
arithmetic, and the capacity-bounded LRU eviction path of
``ForwardingTables``.
"""
from __future__ import annotations

import pytest

from repro.core.ftable import (CONNECTED, ENTRY_BYTES, FORWARDED,
                               ForwardingTables, GroupTable)
from repro.core.packet import PSN_MOD, PSN_WINDOW_P4


# ============================================================== GroupTable

class TestInstallLookup:
    def test_connected_entry_carries_l3_l4_and_mr_state(self):
        t = GroupTable(group_ip=7)
        t.add_connected(3, dest_ip=42, dest_qpn=17, va=0x1000, rkey=0x9)
        e = t.entries[3]
        assert (e.type, e.port) == (CONNECTED, 3)
        assert (e.dest_ip, e.dest_qpn, e.va, e.rkey) == (42, 17, 0x1000, 0x9)
        # fresh entries have acked nothing: cumulative "up to -1"
        assert e.ack_psn == PSN_MOD - 1

    def test_forwarded_never_downgrades_connected(self):
        t = GroupTable(group_ip=7)
        t.add_connected(1, dest_ip=5, dest_qpn=20)
        t.add_forwarded(1)                      # Alg. 4 reuse: keep as-is
        assert t.entries[1].type == CONNECTED

    def test_min_ack_returns_slowest_port(self):
        t = GroupTable(group_ip=7)
        t.add_connected(0, 1, 16)
        t.add_connected(1, 2, 17)
        t.add_connected(2, 3, 18)
        t.entries[0].ack_psn = 10
        t.entries[1].ack_psn = 4                # the straggler
        t.entries[2].ack_psn = 30
        mn, mp = t.min_ack()
        assert (mn, mp) == (4, 1)

    def test_table_bytes_matches_fig5_arithmetic(self):
        t = GroupTable(group_ip=7)
        t.add_connected(0, 1, 16)
        t.add_forwarded(1)
        expected = (16                              # group-level state
                    + ENTRY_BYTES[CONNECTED] + 4    # + per-port cc counter
                    + ENTRY_BYTES[FORWARDED] + 4)
        assert t.table_bytes() == expected


# ======================================================== ForwardingTables

class TestStore:
    def test_create_get_roundtrip_and_p4_window(self):
        ft = ForwardingTables(p4_mode=True)
        t = ft.create(100)
        assert ft.get(100) is t
        assert t.psn_window == PSN_WINDOW_P4
        assert ft.get(101) is None

    def test_remove_uninstalls(self):
        ft = ForwardingTables()
        ft.create(100)
        assert ft.remove(100) is not None
        assert ft.get(100) is None
        assert ft.remove(100) is None           # idempotent
        assert ft.total_bytes() == 0

    def test_lru_eviction_at_capacity(self):
        ft = ForwardingTables(capacity=2)
        ft.create(1)
        ft.create(2)
        ft.get(1)                               # 1 is now most recent
        ft.create(3)                            # evicts 2, the LRU
        assert ft.get(2) is None
        assert ft.get(1) is not None and ft.get(3) is not None
        assert ft.evictions == 1

    def test_recreate_existing_group_does_not_evict(self):
        ft = ForwardingTables(capacity=2)
        ft.create(1)
        ft.create(2)
        ft.create(2)                            # re-registration, same id
        assert ft.evictions == 0
        assert ft.get(1) is not None

    def test_unbounded_by_default(self):
        ft = ForwardingTables()
        for g in range(64):
            ft.create(g)
        assert ft.evictions == 0
        assert len(ft.tables) == 64


# =========================================== eviction through a real switch

def test_switch_table_capacity_evicts_oldest_group():
    """A capacity-1 switch keeps only the most recent registration; the
    evicted group's data falls back to unicast forwarding (no table)."""
    from repro.core import fattree
    from repro.core.gleam import GleamNetwork

    net = GleamNetwork(fattree.testbed())
    sw = net.sim.switches["SW0"]
    sw.tables.capacity = 1
    g1 = net.multicast_group(["h0", "h1", "h2"])
    g1.register()
    g2 = net.multicast_group(["h0", "h2", "h3"])
    g2.register()
    assert sw.tables.get(g1.group_ip) is None
    assert sw.tables.get(g2.group_ip) is not None
    assert sw.tables.evictions == 1
    # the evicted group released its registration load: remaining
    # port_util equals exactly what g2's live table accounts for
    live = sw.tables.get(g2.group_ip)
    assert sum(sw.port_util.values()) == sum(live.port_refs.values())
    sw.tables.remove(g2.group_ip)
    assert sum(sw.port_util.values()) == 0
