"""Unit tests for the extended multicast forwarding table (Fig. 5):
entry install / lookup / aggregation queries, the §3.3 memory
arithmetic, and the capacity-bounded LRU eviction path of
``ForwardingTables``.
"""
from __future__ import annotations

import pytest

from repro.core.ftable import (CONNECTED, ENTRY_BYTES, FORWARDED,
                               ForwardingTables, GroupTable)
from repro.core.packet import PSN_MOD, PSN_WINDOW_P4


# ============================================================== GroupTable

class TestInstallLookup:
    def test_connected_entry_carries_l3_l4_and_mr_state(self):
        t = GroupTable(group_ip=7)
        t.add_connected(3, dest_ip=42, dest_qpn=17, va=0x1000, rkey=0x9)
        e = t.entries[3]
        assert (e.type, e.port) == (CONNECTED, 3)
        assert (e.dest_ip, e.dest_qpn, e.va, e.rkey) == (42, 17, 0x1000, 0x9)
        # fresh entries have acked nothing: cumulative "up to -1"
        assert e.ack_psn == PSN_MOD - 1

    def test_forwarded_never_downgrades_connected(self):
        t = GroupTable(group_ip=7)
        t.add_connected(1, dest_ip=5, dest_qpn=20)
        t.add_forwarded(1)                      # Alg. 4 reuse: keep as-is
        assert t.entries[1].type == CONNECTED

    def test_min_ack_returns_slowest_port(self):
        t = GroupTable(group_ip=7)
        t.add_connected(0, 1, 16)
        t.add_connected(1, 2, 17)
        t.add_connected(2, 3, 18)
        t.entries[0].ack_psn = 10
        t.entries[1].ack_psn = 4                # the straggler
        t.entries[2].ack_psn = 30
        mn, mp = t.min_ack()
        assert (mn, mp) == (4, 1)

    def test_table_bytes_matches_fig5_arithmetic(self):
        t = GroupTable(group_ip=7)
        t.add_connected(0, 1, 16)
        t.add_forwarded(1)
        expected = (16                              # group-level state
                    + ENTRY_BYTES[CONNECTED] + 4    # + per-port cc counter
                    + ENTRY_BYTES[FORWARDED] + 4)
        assert t.table_bytes() == expected


# ======================================================== ForwardingTables

class TestStore:
    def test_create_get_roundtrip_and_p4_window(self):
        ft = ForwardingTables(p4_mode=True)
        t = ft.create(100)
        assert ft.get(100) is t
        assert t.psn_window == PSN_WINDOW_P4
        assert ft.get(101) is None

    def test_remove_uninstalls(self):
        ft = ForwardingTables()
        ft.create(100)
        assert ft.remove(100) is not None
        assert ft.get(100) is None
        assert ft.remove(100) is None           # idempotent
        assert ft.total_bytes() == 0

    def test_lru_eviction_at_capacity(self):
        ft = ForwardingTables(capacity=2)
        ft.create(1)
        ft.create(2)
        ft.get(1)                               # 1 is now most recent
        ft.create(3)                            # evicts 2, the LRU
        assert ft.get(2) is None
        assert ft.get(1) is not None and ft.get(3) is not None
        assert ft.evictions == 1

    def test_recreate_existing_group_does_not_evict(self):
        ft = ForwardingTables(capacity=2)
        ft.create(1)
        ft.create(2)
        ft.create(2)                            # re-registration, same id
        assert ft.evictions == 0
        assert ft.get(1) is not None

    def test_unbounded_by_default(self):
        ft = ForwardingTables()
        for g in range(64):
            ft.create(g)
        assert ft.evictions == 0
        assert len(ft.tables) == 64


# ============================================= churn: incremental teardown

class TestChurnMemory:
    def test_remove_port_shrinks_table_bytes(self):
        t = GroupTable(group_ip=7)
        t.add_connected(0, 1, 16)
        t.add_forwarded(1)
        full = t.table_bytes()
        assert t.remove_port(1) is not None
        assert t.table_bytes() == full - (ENTRY_BYTES[FORWARDED] + 4)
        t.remove_port(0)
        assert t.table_bytes() == full - (ENTRY_BYTES[FORWARDED] + 4) \
            - (ENTRY_BYTES[CONNECTED] + 4)
        assert t.remove_port(0) is None             # idempotent

    def test_remove_port_drops_per_port_state_and_caches(self):
        t = GroupTable(group_ip=7)
        for p in range(3):
            t.add_connected(p, p + 1, 16 + p)
        t.ack_out_port = 0
        t.cnp_count[2] = 5.0
        t.agg_min = (0, 2)
        t.agg_entries_cache = list(t.entries.values())
        t.remove_port(2)
        assert 2 not in t.cnp_count
        assert t.agg_min is None and t.agg_entries_cache is None

    def test_retarget_swaps_receiver_in_place(self):
        t = GroupTable(group_ip=7)
        t.add_connected(3, dest_ip=42, dest_qpn=17, va=0x1000, rkey=0x9)
        t.last_ack_psn = 99
        e = t.retarget(3, dest_ip=77, dest_qpn=23, va=0x2000, rkey=0xA)
        assert (e.dest_ip, e.dest_qpn, e.va, e.rkey) == (77, 23, 0x2000, 0xA)
        assert e.ack_psn == 99          # newcomer starts at the aggregate
        t.add_forwarded(5)
        with pytest.raises(ValueError, match="not a connected"):
            t.retarget(5, 1, 2)

    def test_1k_groups_claim_survives_a_churn_cycle(self):
        """§3.3: 1K maximal groups (all 32 ports connected) fit in
        0.92 MB — and still do after every group churns half its ports
        out and back in; full teardown returns to zero."""
        ft = ForwardingTables()
        for g in range(1000):
            t = ft.create(g)
            for p in range(32):
                t.add_connected(p, dest_ip=100 + p, dest_qpn=16 + p)
        peak = ft.total_bytes()
        assert peak <= 0.92e6
        # churn: every group loses its even ports...
        for g in range(1000):
            t = ft.get(g)
            for p in range(0, 32, 2):
                t.remove_port(p)
        halved = ft.total_bytes()
        assert halved == peak - 1000 * 16 * (ENTRY_BYTES[CONNECTED] + 4)
        # ...and regains them: back to the claimed footprint, not above
        for g in range(1000):
            t = ft.get(g)
            for p in range(0, 32, 2):
                t.add_connected(p, dest_ip=100 + p, dest_qpn=16 + p)
        assert ft.total_bytes() == peak <= 0.92e6
        # deregistration releases everything
        for g in range(1000):
            ft.remove(g)
        assert ft.total_bytes() == 0


# =========================================== eviction through a real switch

def test_switch_table_capacity_evicts_oldest_group():
    """A capacity-1 switch keeps only the most recent registration; the
    evicted group's data falls back to unicast forwarding (no table)."""
    from repro.core import fattree
    from repro.core.gleam import GleamNetwork

    net = GleamNetwork(fattree.testbed())
    sw = net.sim.switches["SW0"]
    sw.tables.capacity = 1
    g1 = net.multicast_group(["h0", "h1", "h2"])
    g1.register()
    g2 = net.multicast_group(["h0", "h2", "h3"])
    g2.register()
    assert sw.tables.get(g1.group_ip) is None
    assert sw.tables.get(g2.group_ip) is not None
    assert sw.tables.evictions == 1
    # the evicted group released its registration load: remaining
    # port_util equals exactly what g2's live table accounts for
    live = sw.tables.get(g2.group_ip)
    assert sum(sw.port_util.values()) == sum(live.port_refs.values())
    sw.tables.remove(g2.group_ip)
    assert sum(sw.port_util.values()) == 0


# ===================================== mid-stream eviction salvage (faults)

class TestMidStreamEvictionSalvage:
    """LRU-evicting a group whose broadcast is STILL RUNNING must not
    wedge the stream on re-install: the store salvages the evicted
    table's cumulative-ACK high water mark and seeds the fresh table
    (and therefore every fresh entry) at the stream position instead of
    the "acked up to -1" default."""

    def test_salvage_reseeds_last_ack_psn(self):
        ft = ForwardingTables(capacity=1)
        t = ft.create(1)
        t.ack_out_port = 0              # mid-stream marker (data flowed)
        t.last_ack_psn = 1234
        ft.create(2)                    # evicts group 1 mid-stream
        assert ft.evictions == 1 and ft.salvages == 0
        t1b = ft.create(1)              # re-install (repair re-flood)
        assert ft.salvages == 1
        assert t1b.last_ack_psn == 1234
        t1b.add_connected(3, dest_ip=9, dest_qpn=17)
        t1b.add_forwarded(4)
        assert t1b.entries[3].ack_psn == 1234
        assert t1b.entries[4].ack_psn == 1234

    def test_idle_eviction_is_not_salvaged(self):
        ft = ForwardingTables(capacity=1)
        t = ft.create(1)
        t.last_ack_psn = 777            # no ack_out_port: stream over /
        ft.create(2)                    # never started — nothing to save
        t1b = ft.create(1)
        assert ft.salvages == 0
        assert t1b.last_ack_psn == PSN_MOD - 1

    def test_explicit_remove_forgets_the_mark(self):
        ft = ForwardingTables(capacity=1)
        t = ft.create(1)
        t.ack_out_port = 0
        t.last_ack_psn = 555
        ft.create(2)                    # evict mid-stream: mark saved
        ft.remove(1)                    # deregistration: stream is over
        t1b = ft.create(1)
        assert ft.salvages == 0
        assert t1b.last_ack_psn == PSN_MOD - 1

    def test_eviction_during_live_bcast_recovers_end_to_end(self):
        """Regression: capacity pressure evicts the active group's
        table mid-broadcast; the master's repair re-flood re-creates it
        with the salvaged PSN seed and the stream completes — the
        aggregate minimum never goes backwards, the sender never
        wedges."""
        from repro.core import fattree
        from repro.core.gleam import GleamNetwork

        net = GleamNetwork(fattree.testbed(n_hosts=6))
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        sim = net.sim
        sw = sim.switches["SW0"]
        rec = g.bcast(1 << 17, now=0.0)

        def squeeze(now):
            t = sw.tables.get(g.group_ip)
            assert t is not None and t.ack_out_port is not None
            sw.tables.capacity = 1
            sw.tables.create(9999)      # LRU pressure evicts the live
                                        # group and saves its PSN mark
            assert sw.tables.get(g.group_ip) is None
            g.reinstall(now=now)        # Alg. 4 repair re-flood

        sim.schedule(3e-6, squeeze)
        sim.run(until=0.1)
        # two evictions: the live group under pressure, then the dummy
        # when the repair re-flood re-installs at capacity
        assert sw.tables.evictions == 2
        assert sw.tables.salvages == 1
        assert rec.t_sender_cqe > 0 and not rec.error
        for m in ("h1", "h2", "h3"):
            assert m in rec.t_deliver, f"{m} never delivered"
        t = sw.tables.get(g.group_ip)
        assert t is not None and t.last_ack_psn != PSN_MOD - 1
