"""Fault-injection plane (ISSUE 7): the ``FaultEvent`` IR and its
validation, per-class recovery on the live fabric (link/switch/host/
master), packet-vs-flow recovery parity, the dead-source sever cascade,
bounded-retry endpoint semantics, and fault scenarios under the
parallel ``run_many`` path.

The deterministic halves of the two headline properties live here (the
hypothesis twins are in ``test_protocol_properties`` and share the
drivers in ``_fault_props``): re-election converges to exactly one
live master with no orphaned MFT entries, and a severed path costs at
most ``max_retries`` replays before a terminal, attributable error.
"""
from __future__ import annotations

import pytest

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.faults import (DEFAULT_FAULT_RETRIES, FAULT_CHOICES,
                               FaultEvent, fault_downs,
                               validate_fault_plan)
from repro.core.gleam import GleamNetwork
from repro.core.workload import GroupOp

from _fault_props import run_bounded_retry_case, run_reelection_case

MEMBERS = ["h0", "h1", "h2", "h3"]
NBYTES = 1 << 17
AT = 3e-6               # mid-stream fault injection point
PARITY_TOL = 0.15       # packet-vs-flow recovery divergence gate


# ========================================================= FaultEvent IR

class TestFaultEventIR:
    def test_valid_events_per_kind(self):
        FaultEvent("link_down", AT, node="L4", peer="S3")
        FaultEvent("link_flap", AT, node="L4", peer="S3", duration=1e-5)
        FaultEvent("switch_fail", AT, node="S3")
        FaultEvent("host_gone_dark", AT, node="h3")
        FaultEvent("master_crash", AT)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent("meteor_strike", AT, node="S3")

    def test_negative_time_rejected(self):
        with pytest.raises(ValueError, match=">= 0"):
            FaultEvent("switch_fail", -1e-6, node="S3")

    def test_link_faults_need_both_endpoints(self):
        with pytest.raises(ValueError, match="both link endpoints"):
            FaultEvent("link_down", AT, node="L4")
        with pytest.raises(ValueError, match="node == peer"):
            FaultEvent("link_down", AT, node="L4", peer="L4")

    def test_node_faults_take_no_peer(self):
        with pytest.raises(ValueError, match="no peer"):
            FaultEvent("switch_fail", AT, node="S3", peer="S4")
        with pytest.raises(ValueError, match="needs a target"):
            FaultEvent("host_gone_dark", AT)

    def test_master_crash_takes_no_target(self):
        with pytest.raises(ValueError, match="no node/peer"):
            FaultEvent("master_crash", AT, node="h0")

    def test_flap_duration_rules(self):
        with pytest.raises(ValueError, match="duration > 0"):
            FaultEvent("link_flap", AT, node="L4", peer="S3")
        with pytest.raises(ValueError, match="no duration"):
            FaultEvent("link_down", AT, node="L4", peer="S3",
                       duration=1e-5)

    def test_dict_roundtrip(self):
        for f in (FaultEvent("link_flap", AT, node="L4", peer="S3",
                             duration=1e-5),
                  FaultEvent("master_crash", AT)):
            assert FaultEvent.from_dict(f.to_dict()) == f

    def test_from_dict_rejects_unknown_fields(self):
        with pytest.raises(ValueError, match="unknown FaultEvent fields"):
            FaultEvent.from_dict({"kind": "master_crash", "at": AT,
                                  "severity": 9})

    def test_fault_downs_spans(self):
        topo = fattree.fig4()
        spans = fault_downs(
            (FaultEvent("link_flap", 2e-6, node="L4", peer="S3",
                        duration=1e-5),
             FaultEvent("switch_fail", 1e-6, node="S3"),
             FaultEvent("master_crash", 0.0)), topo)
        # time-ordered, host/master faults carry no fabric links
        assert [s[0] for s in spans] == [1e-6, 2e-6]
        assert spans[0][1] == float("inf")
        assert ("S3", "L4") in spans[0][2] or ("S3", "L3") in spans[0][2]
        assert spans[1] == (2e-6, 2e-6 + 1e-5, [("L4", "S3")])


# ==================================================== plan validation

class TestFaultPlanValidation:
    def test_fabric_faults_require_native_transport(self):
        for faults in ((FaultEvent("link_down", AT, node="L4",
                                   peer="S3"),),
                       (FaultEvent("switch_fail", AT, node="S3"),),
                       (FaultEvent("master_crash", AT),)):
            with pytest.raises(ValueError, match="native"):
                GroupOp("bcast", MEMBERS, NBYTES, transport="ring",
                        faults=faults).fault_roles()

    def test_host_gone_dark_allowed_on_overlay(self):
        op = GroupOp("bcast", MEMBERS, NBYTES, transport="ring",
                     faults=(FaultEvent("host_gone_dark", AT,
                                        node="h2"),))
        assert op.surviving_receivers() == ["h1", "h3"]

    def test_dark_source_must_be_master_crash(self):
        with pytest.raises(ValueError, match="use master_crash"):
            GroupOp("bcast", MEMBERS, NBYTES,
                    faults=(FaultEvent("host_gone_dark", AT,
                                       node="h0"),)).fault_roles()

    def test_master_crash_needs_a_survivor(self):
        with pytest.raises(ValueError, match="no survivor"):
            GroupOp("bcast", ["h0", "h1"], NBYTES,
                    faults=(FaultEvent("master_crash", 1e-6),
                            FaultEvent("master_crash", 2e-3),)
                    ).fault_roles()

    def test_surviving_receivers_excuse_dark_and_sources(self):
        op = GroupOp("bcast", MEMBERS, NBYTES,
                     faults=(FaultEvent("master_crash", AT),
                             FaultEvent("host_gone_dark", 2e-3,
                                        node="h2"),))
        # h0 died, h1 re-elected (source role), h2 went dark
        assert op.surviving_receivers() == ["h3"]

    def test_disconnecting_plan_rejected_at_staging(self):
        topo = fattree.fig4()
        op = GroupOp("bcast", MEMBERS, NBYTES,
                     faults=(FaultEvent("link_down", AT, node="L4",
                                        peer="S3"),
                             FaultEvent("link_down", AT, node="L4",
                                        peer="S4"),))
        with pytest.raises(ValueError, match="disconnects"):
            validate_fault_plan(topo, op)
        with pytest.raises(ValueError, match="disconnects"):
            make_engine("packet", fattree.fig4()).stage(op)
        # the single-uplink variant leaves a surviving path: accepted
        validate_fault_plan(topo, GroupOp(
            "bcast", MEMBERS, NBYTES,
            faults=(FaultEvent("link_down", AT, node="L4",
                               peer="S3"),)))

    def test_validator_restores_topology(self):
        topo = fattree.fig4()
        validate_fault_plan(topo, GroupOp(
            "bcast", MEMBERS, NBYTES,
            faults=(FaultEvent("switch_fail", AT, node="S3"),)))
        assert not topo._down


# ============================================= per-class engine recovery

def _fault_cases():
    return [
        ("link_down", (FaultEvent("link_down", AT, node="L4",
                                  peer="S3"),)),
        ("link_flap", (FaultEvent("link_flap", AT, node="L4", peer="S3",
                                  duration=2e-5),)),
        ("switch_fail", (FaultEvent("switch_fail", AT, node="S3"),)),
        ("host_gone_dark", (FaultEvent("host_gone_dark", AT,
                                       node="h3"),)),
        ("master_crash", (FaultEvent("master_crash", AT),)),
    ]


def _run_once(engine_name, faults=(), transport="gleam"):
    eng = make_engine(engine_name, fattree.fig4(),
                      **({"seed": 7} if engine_name == "packet" else {}))
    op = GroupOp("bcast", MEMBERS, NBYTES, transport=transport,
                 faults=faults)
    rec = eng.stage(op)
    eng.run(timeout=60.0)
    assert not rec.error
    for m in op.surviving_receivers():
        assert m in rec.t_deliver, f"{m} never delivered"
    return rec.io_latency       # sender CQE: sees every recovery class


@pytest.mark.parametrize("label,faults", _fault_cases())
def test_every_fault_class_recovers_with_engine_parity(label, faults):
    """Each fault class completes on BOTH engines — no hangs, every
    surviving receiver delivered — and the measured recovery latency
    (sender-CQE penalty over the clean run) agrees within the gate."""
    base_p = _run_once("packet")
    base_f = _run_once("flow")
    jct_p = _run_once("packet", faults)
    jct_f = _run_once("flow", faults)
    assert jct_p > base_p       # the fault cost something
    div = abs(jct_p - jct_f) / jct_p
    assert div <= PARITY_TOL, (
        f"{label}: packet {jct_p * 1e6:.2f}us vs flow {jct_f * 1e6:.2f}us "
        f"({100 * div:.1f}% > {100 * PARITY_TOL:.0f}%)")


def test_overlay_relay_dark_resplices():
    """A dead mid-ring relay: children are respliced onto the dead
    relay's parent; survivors still complete on both engines."""
    jp = _run_once("packet",
                   (FaultEvent("host_gone_dark", AT, node="h2"),),
                   transport="ring")
    jf = _run_once("flow",
                   (FaultEvent("host_gone_dark", AT, node="h2"),),
                   transport="ring")
    assert abs(jp - jf) / jp <= PARITY_TOL

    # flap heals the fabric afterwards: the packet sim restores the link
    eng = make_engine("packet", fattree.fig4(), seed=7)
    rec = eng.stage(GroupOp(
        "bcast", MEMBERS, NBYTES,
        faults=(FaultEvent("link_flap", AT, node="L4", peer="S3",
                           duration=2e-5),)))
    eng.run(timeout=60.0)
    assert not rec.error
    assert not eng.net.topo._down       # the flap healed


@pytest.mark.parametrize("transport", ["ring", "binary-tree",
                                       "multiunicast"])
def test_overlay_graceful_leave_resplices(transport):
    """ISSUE-8 satellite regression: a graceful mid-stream ``leave`` on
    an overlay relay transport must resplice the relay schedule through
    the ``repair_dead_relay`` path — before this fix it raised at
    construction.  Unlike a dark, a leaver's host stays up and the
    splice is immediate (no fail_detect), so the leaver must NOT be
    counted (or keep relaying) even though residual chunks still reach
    its NIC, and survivors must all deliver on BOTH engines.

    The parity gate is looser than PARITY_TOL: the detect-free splice
    races the live stream head-on, where the fluid model's lack of
    in-flight chunk state costs the most (measured ~18% on ring)."""
    from repro.core.workload import MemberEvent
    events = (MemberEvent("leave", "h2", AT),)
    jcts = {}
    for engine_name in ("packet", "flow"):
        eng = make_engine(engine_name, fattree.fig4(),
                          **({"seed": 7} if engine_name == "packet"
                             else {}))
        op = GroupOp("bcast", MEMBERS, NBYTES, transport=transport,
                     events=events)
        assert op.surviving_receivers() == ["h1", "h3"]
        rec = eng.stage(op)
        eng.run(timeout=60.0)
        assert not rec.error
        assert "h2" not in rec.t_deliver, "leaver was still counted"
        for m in ("h1", "h3"):
            assert m in rec.t_deliver, f"{m} never delivered"
        jcts[engine_name] = rec.io_latency
    div = abs(jcts["packet"] - jcts["flow"]) / jcts["packet"]
    assert div <= 0.25, (
        f"{transport}: packet {jcts['packet'] * 1e6:.2f}us vs flow "
        f"{jcts['flow'] * 1e6:.2f}us ({100 * div:.1f}% > 25%)")


# ============================================ re-election + sever cascade

class TestMasterCrashRecovery:
    def test_single_crash_converges(self):
        rec = run_reelection_case([AT])
        assert rec.t_sender_cqe > 0

    def test_double_crash_mid_stream_converges(self):
        # 4MB keeps the stream alive across BOTH fail_detect windows
        rec = run_reelection_case([AT, 1.2e-3], nbytes=1 << 22)
        assert rec.t_sender_cqe > 0

    def test_crash_after_completion_still_reelects(self):
        run_reelection_case([5e-4], nbytes=1 << 14)

    def test_sever_cascade_unwinds_dead_masters_branch(self):
        """The dead master's access leaf is OFF the re-rooted tree, so
        no repair envelope ever visits it: the dead-source sever
        cascade must have unwound its table (and every switch the new
        tree bypassed) instead of leaking it until group teardown."""
        net = GleamNetwork(fattree.fig4())
        g = net.multicast_group(MEMBERS)
        g.register()
        rec = g.bcast(NBYTES, now=0.0)
        net.sim.schedule(AT, lambda now: g.master_crash(now=now))
        net.sim.run(until=0.05)
        assert rec.t_sender_cqe > 0
        # h0's leaf (L1) fed the old tree from the dead source
        assert net.sim.switches["L1"].tables.get(g.group_ip) is None
        live_ips = {g.qps[m].ip for m in g.members}
        for name, sw in net.sim.switches.items():
            t = sw.tables.get(g.group_ip)
            if t is not None:
                assert not set(t.member_port) - live_ips, name

    def test_resume_from_dead_senders_una(self):
        """The survivor resumes at the dead sender's cumulative-ACK
        point: receivers re-ACK the overlap instead of NACKing below
        the new base, and the sender CQE lands ~fail_detect later."""
        net = GleamNetwork(fattree.fig4())
        g = net.multicast_group(MEMBERS)
        g.register()
        rec = g.bcast(NBYTES, now=0.0)
        net.sim.schedule(AT, lambda now: g.master_crash(now=now))
        net.sim.run(until=0.05)
        assert g.master == "h1"
        assert rec.t_sender_cqe == pytest.approx(
            g.fail_detect + AT, rel=0.25)


# ====================================================== bounded retry

class TestBoundedRetry:
    def test_retry_budget_is_terminal_and_attributable(self):
        rec = run_bounded_retry_case(2, AT)
        assert rec.error == "retry_exceeded"

    def test_zero_budget_errors_on_first_unproductive_rto(self):
        rec = run_bounded_retry_case(0, AT)
        assert rec.error == "retry_exceeded"

    def test_sever_after_completion_is_clean(self):
        rec = run_bounded_retry_case(3, 1.0)
        assert not rec.error

    def test_fault_ops_default_to_bounded_retries(self):
        eng = make_engine("packet", fattree.fig4(), seed=7)
        rec = eng.stage(GroupOp(
            "bcast", MEMBERS, NBYTES,
            faults=(FaultEvent("link_down", AT, node="L4",
                               peer="S3"),)))
        eng.run(timeout=60.0)
        assert not rec.error
        g = eng.net.groups_by_ip[next(iter(eng.net.groups_by_ip))]
        assert g.qps["h0"].max_retries == DEFAULT_FAULT_RETRIES

    def test_no_fault_ops_keep_unbounded_legacy_semantics(self):
        eng = make_engine("packet", fattree.fig4(), seed=7)
        eng.stage(GroupOp("bcast", MEMBERS, NBYTES))
        eng.run(timeout=60.0)
        g = eng.net.groups_by_ip[next(iter(eng.net.groups_by_ip))]
        assert g.qps["h0"].max_retries is None


# ================================================== run_many + faults

def test_fault_scenarios_serial_equals_workers():
    """Fault scenarios survive the fork/replay parallel path: same
    records serial and with workers=2 (fresh-engine reseed per
    scenario makes the comparison exact)."""
    def _batch(workers):
        eng = make_engine("packet", fattree.fig4(), seed=7)
        recs = []

        def clean(e):
            recs.append(e.stage(GroupOp("bcast", MEMBERS, NBYTES)))

        def crash(e):
            recs.append(e.stage(GroupOp(
                "bcast", MEMBERS, NBYTES,
                faults=(FaultEvent("master_crash", AT),))))

        def dark(e):
            recs.append(e.stage(GroupOp(
                "bcast", MEMBERS, NBYTES,
                faults=(FaultEvent("host_gone_dark", AT,
                                   node="h3"),))))

        eng.run_many([clean, crash, dark], timeout=60.0,
                     workers=workers)
        return [(sorted(r.t_deliver.items()), r.t_sender_cqe, r.error)
                for r in recs]

    assert _batch(None) == _batch(2)


def test_zero_fault_op_is_bit_identical_to_faultless_op():
    """``faults=()`` takes the exact legacy code path: same records as
    an op built without the field at all (the PR-6 bit-identity
    invariant, unit-sized)."""
    def _run(op):
        eng = make_engine("packet", fattree.testbed(n_hosts=6), seed=7)
        rec = eng.stage(op)
        eng.run(timeout=60.0)
        return sorted(rec.t_deliver.items()), rec.t_sender_cqe

    assert _run(GroupOp("bcast", MEMBERS, NBYTES)) == \
        _run(GroupOp("bcast", MEMBERS, NBYTES, faults=()))


def test_fault_choices_cover_engine_lowerings():
    assert set(FAULT_CHOICES) == {"link_down", "link_flap", "switch_fail",
                                  "host_gone_dark", "master_crash"}
