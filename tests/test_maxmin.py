"""The fused max-min solver stack (kernels/maxmin.py + flowsim_jax.py).

- property-style randomized agreement: the Pallas kernel (interpret
  mode, so it runs on any backend) against the numpy ``FlowSim``
  progressive filling, on randomized topologies and flow sets, to 0.1%;
- shape bucketing: two sweep points in the same (F, H) bucket must hit
  the jit cache (no recompile);
- float64 auto-promotion once volumes exceed the float32 safe-integer
  range, pinned against a float64 numpy reference;
- ``run_many`` batched scenarios == serial runs on fresh engines;
- solvers never clobber the staged ``Flow.volume``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.flowsim import FlowSim

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.core import flowsim_jax                     # noqa: E402
from repro.core.flowsim_jax import JaxFlowSim, _bucket, _solver  # noqa: E402
from repro.kernels import maxmin                       # noqa: E402
from repro.kernels.ref import maxmin_round_reference   # noqa: E402


def _jit_cache_size() -> int:
    """Compiled-shape count of the solver flavor ``run()`` dispatches."""
    return _solver(False, maxmin._resolve_mode())._cache_size()


def small_fat_tree():
    """8 hosts, heterogeneous tiers — interesting max-min contention."""
    return fattree.fat_tree(n_pods=2, leaves_per_pod=2, hosts_per_leaf=2,
                            aggs_per_pod=2, bw=100 * fattree.GBPS)


def random_flows(rng, sim, n_lo=3, n_hi=12):
    """Random mix of unicast paths and multicast trees with volumes."""
    hosts = list(sim.topo.hosts)
    out = []
    for _ in range(int(rng.integers(n_lo, n_hi + 1))):
        key = int(rng.integers(0, 4))
        if rng.random() < 0.5:
            src, dst = (str(h) for h in
                        rng.choice(hosts, 2, replace=False))
            links = sim.unicast_links(src, dst, key)
        else:
            k = int(rng.integers(2, min(6, len(hosts)) + 1))
            members = [str(h) for h in rng.choice(hosts, k, replace=False)]
            links = sim.multicast_tree_links(members[0], members, key)
        out.append((links, float(rng.uniform(1e5, 5e6))))
    return out


def pack_links(flows, n_links):
    """(F, H) sentinel-padded link-id matrix like the solver builds."""
    h = max(len(links) for links, _ in flows)
    fl = np.full((len(flows), h), n_links, np.int32)
    for i, (links, _) in enumerate(flows):
        fl[i, :len(links)] = links
    return fl


# =============================================== kernel vs numpy filling

@pytest.mark.parametrize("seed", range(5))
def test_pallas_kernel_matches_numpy_filling(seed):
    """ISSUE acceptance: interpret-mode kernel rates agree with the
    numpy FlowSim progressive filling within 0.1% on random cases."""
    rng = np.random.default_rng(seed)
    topo = small_fat_tree() if seed % 2 else fattree.fig4()
    ref_sim = FlowSim(topo)
    flows = random_flows(rng, ref_sim)
    staged = [ref_sim.add(links, vol) for links, vol in flows]
    ref_sim._allocate(staged)
    want = np.asarray([f.rate for f in staged])

    fl = pack_links(flows, len(ref_sim.cap))
    cap = np.append(ref_sim.cap, np.inf).astype(np.float32)
    active = np.ones(len(flows), bool)
    got = np.asarray(maxmin.maxmin_rates(
        jnp.asarray(fl), jnp.asarray(cap), jnp.asarray(active),
        mode="interpret", block_f=8))
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_kernel_round_matches_reference_exactly():
    """One fused round == the jnp oracle, including freeze/cap state."""
    rng = np.random.default_rng(7)
    F, H, L = 23, 4, 17
    links = rng.integers(0, L, (F, H)).astype(np.int32)
    for i in range(F):                     # ragged link lists
        links[i, int(rng.integers(1, H + 1)):] = L
    cap = np.append(rng.uniform(1.0, 10.0, L), np.inf).astype(np.float32)
    frozen = (rng.random(F) < 0.3).astype(np.float32)
    rates = np.zeros(F, np.float32)
    want = maxmin_round_reference(jnp.asarray(links), jnp.asarray(frozen),
                                  jnp.asarray(rates), jnp.asarray(cap))
    got = maxmin.maxmin_round_pallas(
        jnp.asarray(links), jnp.asarray(frozen), jnp.asarray(rates),
        jnp.asarray(cap), block_f=8, interpret=True)
    for g, w, name in zip(got, want, ("rates", "frozen", "cap_rem")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, err_msg=name)


@pytest.mark.parametrize("seed", range(3))
def test_jax_sim_completion_times_match_numpy(seed):
    """Full event loop (epochs + warm start) vs numpy FlowSim, 0.1%."""
    rng = np.random.default_rng(100 + seed)
    topo = small_fat_tree()
    sim_np, sim_jx = FlowSim(topo), JaxFlowSim(topo)
    flows = random_flows(rng, sim_np)
    fn = [sim_np.add(links, vol) for links, vol in flows]
    fj = [sim_jx.add(links, vol) for links, vol in flows]
    sim_np.run()
    sim_jx.run()
    done_np = np.asarray([f.done_t for f in fn])
    done_jx = np.asarray([f.done_t for f in fj])
    np.testing.assert_allclose(done_jx, done_np, rtol=1e-3)


# ======================================================= shape bucketing

def test_bucket_is_pow2_with_floor():
    assert _bucket(1, 16) == 16
    assert _bucket(16, 16) == 16
    assert _bucket(17, 16) == 32
    assert _bucket(1984, 16) == 2048
    assert _bucket(3, 8) == 8


def test_same_bucket_hits_jit_cache():
    """Two sweep points in one (F, H) bucket must NOT recompile."""
    topo = fattree.testbed(n_hosts=8)

    def solve(n_flows):
        sim = JaxFlowSim(topo)
        for i in range(n_flows):
            sim.add(sim.unicast_links("h0", f"h{1 + i % 7}", key=i),
                    1e6 + i)
        sim.run()

    solve(17)                               # F bucket 32
    before = _jit_cache_size()
    solve(25)                               # same bucket -> cache hit
    assert _jit_cache_size() == before
    solve(40)                               # F bucket 64 -> one compile
    assert _jit_cache_size() == before + 1


def test_unbucketed_mode_recompiles_per_shape():
    """The PR-1 behavior is still reachable (bench A/B) and differs."""
    topo = fattree.testbed(n_hosts=8)

    def solve(n_flows):
        sim = JaxFlowSim(topo)
        sim.bucketing = False
        for i in range(n_flows):
            sim.add(sim.unicast_links("h0", f"h{1 + i % 7}"), 1e6)
        sim.run()

    solve(18)
    before = _jit_cache_size()
    solve(19)                               # exact shapes -> recompile
    assert _jit_cache_size() == before + 1


def test_mode_override_not_stale_after_compile(monkeypatch):
    """REPRO_MAXMIN set AFTER a bucket compiled must still take effect
    (the kernel mode is part of the jit cache key, not baked into a
    stale executable)."""
    topo = fattree.testbed()
    sim = JaxFlowSim(topo)
    sim.add(sim.unicast_links("h0", "h1"), 1e6)
    sim.run()
    want = sim.flows[0].done_t
    monkeypatch.setenv("REPRO_MAXMIN", "interpret")
    before = _solver(False, "interpret")._cache_size()
    sim2 = JaxFlowSim(topo)
    sim2.add(sim2.unicast_links("h0", "h1"), 1e6)
    sim2.run()
    assert _solver(False, "interpret")._cache_size() == before + 1
    assert sim2.flows[0].done_t == pytest.approx(want, rel=1e-5)


# ==================================================== float64 promotion

def test_small_volumes_solve_in_float32():
    sim = JaxFlowSim(fattree.testbed())
    sim.add(sim.unicast_links("h0", "h1"), 1 << 20)
    sim.run()
    assert sim.solve_dtype == np.float32


def test_large_volumes_auto_promote_to_float64():
    """Multi-GB volumes (fig12/13 regime) pin the f64 path: dtype
    selection + agreement with a float64 numpy reference at 1e-9 —
    beyond float32's ~6e-8 representation error."""
    topo = fattree.testbed()
    sim_jx, sim_np = JaxFlowSim(topo), FlowSim(topo)
    rng = np.random.default_rng(3)
    pairs = [("h0", "h1"), ("h0", "h2"), ("h1", "h3"), ("h2", "h3")]
    fj, fn = [], []
    for i, (a, b) in enumerate(pairs):
        vol = float(2 << 30) * (1.0 + float(rng.uniform(0, 0.5)))
        fj.append(sim_jx.add(sim_jx.unicast_links(a, b), vol))
        fn.append(sim_np.add(sim_np.unicast_links(a, b), vol))
    sim_jx.run()
    sim_np.run()
    assert sim_jx.solve_dtype == np.float64
    np.testing.assert_allclose([f.done_t for f in fj],
                               [f.done_t for f in fn], rtol=1e-9)


def test_f32_boundary_is_safe_integer_range():
    sim = JaxFlowSim(fattree.testbed())
    sim.add(sim.unicast_links("h0", "h1"), flowsim_jax.F32_SAFE_MAX)
    sim.run()
    assert sim.solve_dtype == np.float32
    sim2 = JaxFlowSim(fattree.testbed())
    sim2.add(sim2.unicast_links("h0", "h1"),
             flowsim_jax.F32_SAFE_MAX * 1.01)
    sim2.run()
    assert sim2.solve_dtype == np.float64


# ================================================= run_many / solve_many

def _stage_pair(recs):
    def a(eng):
        recs.append(eng.add_bcast(["h0", "h1", "h2"], 1 << 20))

    def b(eng):
        recs.append(eng.add_bcast(["h0", "h3", "h4"], 2 << 20))
        recs.append(eng.add_unicast("h1", "h2", 1 << 20))
    return [a, b]


@pytest.mark.parametrize("engine", ["flow", "flow-np"])
def test_run_many_matches_serial_fresh_engines(engine):
    recs: list = []
    eng = make_engine(engine, fattree.testbed(n_hosts=5))
    ends = eng.run_many(_stage_pair(recs))
    assert len(ends) == 2
    got = [recs[0].jct(2), recs[1].jct(2), recs[2].jct(1)]

    e1 = make_engine(engine, fattree.testbed(n_hosts=5))
    r1 = e1.add_bcast(["h0", "h1", "h2"], 1 << 20)
    e1.run()
    e2 = make_engine(engine, fattree.testbed(n_hosts=5))
    r2 = e2.add_bcast(["h0", "h3", "h4"], 2 << 20)
    r3 = e2.add_unicast("h1", "h2", 1 << 20)
    e2.run()
    want = [r1.jct(2), r2.jct(2), r3.jct(1)]
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_run_many_scenarios_are_isolated():
    """Identical scenarios staged together must NOT share bandwidth:
    each must match its solo JCT (unlike one run() batch, which halves
    the shared sender link)."""
    members = ["h0", "h1", "h2", "h3"]
    solo_eng = make_engine("flow", fattree.testbed())
    solo = solo_eng.add_bcast(members, 1 << 20)
    solo_eng.run()
    eng = make_engine("flow", fattree.testbed())
    recs = []
    eng.run_many([lambda e: recs.append(e.add_bcast(members, 1 << 20)),
                  lambda e: recs.append(e.add_bcast(members, 1 << 20))])
    for r in recs:
        assert r.jct(3) == pytest.approx(solo.jct(3), rel=1e-6)


def test_run_many_heterogeneous_epochs_split_batches():
    """A unicast-mesh epoch (many flows, short paths) next to a
    multicast epoch (few flows, long link lists) exercises the batch
    planner; results must still match serial runs."""
    topo = small_fat_tree()
    hosts = topo.hosts
    eng = make_engine("flow", topo)
    mesh_recs: list = []
    tree_recs: list = []

    def mesh(e):
        for i, a in enumerate(hosts):
            for b in hosts[i + 1:]:
                mesh_recs.append(e.add_unicast(a, b, 1 << 18, key=i))

    def tree(e):
        tree_recs.append(e.add_bcast(list(hosts), 4 << 20))

    eng.run_many([mesh, tree])
    e1 = make_engine("flow", small_fat_tree())
    ref_recs: list = []
    for i, a in enumerate(hosts):
        for b in hosts[i + 1:]:
            ref_recs.append(e1.add_unicast(a, b, 1 << 18, key=i))
    e1.run()
    e2 = make_engine("flow", small_fat_tree())
    rt = e2.add_bcast(list(hosts), 4 << 20)
    e2.run()
    got = [r.jct(1) for r in mesh_recs] + [tree_recs[0].jct(len(hosts) - 1)]
    want = [r.jct(1) for r in ref_recs] + [rt.jct(len(hosts) - 1)]
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_run_many_rejects_pending_staged_ops():
    eng = make_engine("flow", fattree.testbed())
    eng.add_bcast(["h0", "h1"], 1 << 20)
    with pytest.raises(RuntimeError):
        eng.run_many([lambda e: None])


def test_packet_engine_run_many_serial_fallback():
    """Serial scenarios run as independent experiments: the fabric
    quiesces and the clock resets between them (matching the flow
    engine's isolated-scenario semantics), so each end time measures
    its own scenario, not the accumulated history."""
    eng = make_engine("packet", fattree.testbed())
    recs: list = []
    ends = eng.run_many(
        [lambda e: recs.append(e.add_bcast(["h0", "h1", "h2"], 64 << 10)),
         lambda e: recs.append(e.add_unicast("h0", "h3", 64 << 10))])
    assert len(ends) == 2
    assert recs[0].jct(2) != float("inf")
    assert recs[1].jct(1) != float("inf")
    assert recs[1].t_submit == 0.0          # clock reset between scenarios


# ===================================================== volume integrity

@pytest.mark.parametrize("cls", [FlowSim, JaxFlowSim])
def test_solvers_preserve_staged_volume(cls):
    """ISSUE bugfix: run() must record completion via done_t/remaining
    WITHOUT destroying the staged volume."""
    sim = cls(fattree.testbed())
    f = sim.add(sim.unicast_links("h0", "h1"), 1 << 20)
    sim.run()
    assert f.volume == float(1 << 20)
    assert f.remaining == 0.0
    assert f.done_t > 0.0
