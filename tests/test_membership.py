"""Membership control plane (ISSUE 5): the MulticastGroup state
machine, in-band join/leave/fail/master-switch on the live fabric,
incremental forwarding-table maintenance under churn, and the
Workload-IR ``MemberEvent`` lowering on BOTH engines.

The acceptance gates live here too: dynamic scenarios agree between the
packet and flow engines within 10%, and a no-events GroupOp takes the
exact static code path (same records as before the refactor).
"""
from __future__ import annotations

import pytest

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.gleam import (ACTIVE, CLOSED, GleamNetwork, IDLE,
                              REGISTERING, UPDATING)
from repro.core.workload import GroupOp, MemberEvent, Workload


def fresh_group(n=4, spares=2, **net_kw):
    net = GleamNetwork(fattree.testbed(n_hosts=n + spares), **net_kw)
    g = net.multicast_group([f"h{i}" for i in range(n)])
    return net, g


# ======================================================== state machine

class TestStateMachine:
    def test_lifecycle_states(self):
        net, g = fresh_group()
        assert g.state == IDLE
        g.register(run=False)
        assert g.state == REGISTERING
        net.sim.run(until=1.0)
        assert g.registered and g.state == ACTIVE
        rec = g.join("h4")
        assert g.state == UPDATING
        g._run_until_op(rec)
        assert g.state == ACTIVE and rec.complete
        g.close()
        assert g.state == CLOSED

    def test_ops_require_active_group(self):
        net, g = fresh_group()
        with pytest.raises(RuntimeError, match="active group"):
            g.join("h4")
        g.register()
        g.close()
        with pytest.raises(RuntimeError, match="active group"):
            g.leave("h1")
        with pytest.raises(RuntimeError, match="closed group"):
            g.bcast(1024)

    def test_membership_validation(self):
        net, g = fresh_group()
        g.register()
        with pytest.raises(ValueError, match="already a member"):
            g.join("h1")
        with pytest.raises(ValueError, match="not a member"):
            g.leave("h9")
        with pytest.raises(ValueError, match="current source"):
            g.fail(g.source)

    def test_events_log_records_latency(self):
        net, g = fresh_group()
        g.register()
        g.join("h4", run=True)
        g.leave("h4", run=True)
        assert [r.kind for r in g.events_log] == ["join", "leave"]
        for r in g.events_log:
            assert r.complete and r.latency > 0


# ============================================== control plane on fabric

class TestJoin:
    def test_joiner_receives_subsequent_messages(self):
        net, g = fresh_group()
        g.register()
        g.join("h4", run=True)
        assert "h4" in g.members and g.n_receivers() == 4
        rec = g.bcast(256 << 10)
        g.run_until_delivered(rec)
        assert "h4" in rec.t_deliver

    def test_join_mid_stream_locks_onto_live_psn(self):
        """A member joining mid-message adopts the live stream's PSN
        (no reset, no NACK storm) and delivers the in-flight message's
        tail; the original receivers are unperturbed."""
        net, g = fresh_group()
        g.register()
        warm = g.bcast(64 << 10)            # advance the PSN stream
        g.run_until_delivered(warm)
        rec = g.bcast(1 << 20)
        sim = net.sim
        sim.run(until=sim.now + 20e-6)
        g.join("h4")
        jct = g.run_until_delivered(rec)
        assert jct != float("inf")
        assert "h4" in rec.t_deliver        # tail delivered to the joiner
        assert g.qps["h4"].retransmitted == 0
        # and the stream did not roll back for anyone
        assert g.qps[g.source].retransmitted == 0

    def test_join_installs_table_entry(self):
        net, g = fresh_group(n=3)
        g.register()
        t = net.sim.switches["SW0"].tables.get(g.group_ip)
        before = t.table_bytes()
        g.join("h3", run=True)
        assert t.table_bytes() > before
        assert g.qps["h3"].ip in t.member_port


class TestLeaveAndFail:
    def test_leave_prunes_table_and_releases_port_load(self):
        net, g = fresh_group()
        g.register()
        sw = net.sim.switches["SW0"]
        t = sw.tables.get(g.group_ip)
        before_bytes = t.table_bytes()
        before_util = sum(sw.port_util.values())
        g.leave("h3", run=True)
        assert t.table_bytes() < before_bytes
        assert sum(sw.port_util.values()) == before_util - 1
        assert not g.qps["h3"].alive        # graceful quiesce
        rec = g.bcast(128 << 10)
        g.run_until_delivered(rec)
        assert "h3" not in rec.t_deliver

    def test_leave_of_straggler_unwedges_aggregation(self):
        """Removing the receiver that owns the aggregate minimum must
        emit the catch-up aggregated ACK so the sender completes."""
        net, g = fresh_group()
        g.register()
        rec = g.bcast(1 << 20)
        sim = net.sim
        sim.run(until=sim.now + 10e-6)
        g.qps["h3"].deactivate()            # silent straggler...
        sim.run(until=sim.now + 100e-6)
        assert rec.t_sender_cqe < 0         # ...has wedged the sender
        g.leave("h3")                       # in-band removal
        g.run_until_delivered(rec, timeout=5.0)
        assert rec.t_sender_cqe > 0         # un-wedged and completed

    def test_fail_recovery_is_detection_bound(self):
        net, g = fresh_group()
        g.register()
        rec = g.bcast(4 << 20)
        sim = net.sim
        sim.run(until=sim.now + 20e-6)
        frec = g.fail("h3")
        jct = g.run_until_delivered(rec, timeout=10.0)
        assert jct != float("inf") and rec.t_sender_cqe > 0
        assert frec.complete
        # recovery = detection delay + isolation round trip
        assert frec.latency == pytest.approx(g.fail_detect, rel=0.05)
        assert "h3" not in rec.t_deliver
        # the crashed member's traffic was sunk, not mis-delivered
        assert sim.hosts["h3"].dead_drops > 0

    def test_whole_group_teardown_when_last_member_leaves(self):
        net, g = fresh_group(n=3)
        g.register()
        sw = net.sim.switches["SW0"]
        g.leave("h2", run=True)
        g.leave("h1", run=True)
        # only the source remains -> close releases everything
        g.close()
        assert sw.tables.get(g.group_ip) is None
        assert sum(sw.port_util.values()) == 0


class TestMasterSwitch:
    def test_handover_moves_source_and_master(self):
        net, g = fresh_group()
        g.register()
        rec = g.bcast(128 << 10)
        g.run_until_delivered(rec)
        g.master_switch("h2")
        assert g.master == "h2" and g.source == "h2"
        rec2 = g.bcast(128 << 10)
        g.run_until_delivered(rec2)
        assert set(rec2.t_deliver) == {"h0", "h1", "h3"}

    def test_handover_then_removal_on_multihop_topology(self):
        """Regression: a teardown envelope from a post-handover master
        follows a DIFFERENT path than the install did — switches that
        never indexed the member must relay it along the tree instead
        of dropping it, or leave/fail never complete off the testbed."""
        net = GleamNetwork(fattree.fig4())
        g = net.multicast_group(["h0", "h1", "h2"])
        g.register()
        g.master_switch("h1")
        lrec = g.leave("h2", run=True)
        assert lrec.complete
        rec = g.bcast(256 << 10)
        g.run_until_delivered(rec)
        assert set(rec.t_deliver) == {"h0"}

    def test_handover_then_fail_recovers_on_multihop_topology(self):
        net = GleamNetwork(fattree.fig4())
        g = net.multicast_group(["h0", "h1", "h2"])
        g.register()
        g.master_switch("h1")
        rec = g.bcast(2 << 20)
        sim = net.sim
        sim.run(until=sim.now + 20e-6)
        frec = g.fail("h2")
        jct = g.run_until_delivered(rec, timeout=10.0)
        assert jct != float("inf") and rec.t_sender_cqe > 0
        assert frec.complete

    def test_rejoin_within_detection_window_supersedes_isolation(self):
        """Regression: a member that fails and rejoins before
        ``fail_detect`` elapses must NOT have its fresh install torn
        down by the stale isolation envelope — the rejoin sends the
        teardown itself, immediately ahead of the re-install."""
        net, g = fresh_group()
        g.register()
        sim = net.sim
        sw = sim.switches["SW0"]
        util_before = sum(sw.port_util.values())
        frec = g.fail("h3")
        sim.run(until=sim.now + 100e-6)     # well inside fail_detect
        g.join("h3", run=True)
        assert frec.complete                # rejoin = early detection
        sim.run(until=sim.now + 2 * g.fail_detect)  # stale timer no-ops
        t = sw.tables.get(g.group_ip)
        assert g.qps["h3"].ip in t.member_port
        rec = g.bcast(256 << 10)
        g.run_until_delivered(rec)
        assert "h3" in rec.t_deliver        # the rejoined member is live
        # accounting is exact: the dead port's ref was released once
        assert sum(sw.port_util.values()) == util_before

    def test_new_master_drives_membership_ops(self):
        net, g = fresh_group()
        g.register()
        g.master_switch("h1")
        g.leave("h3", run=True)             # envelopes now from h1
        g.join("h4", run=True)
        assert g.events_log[-1].complete
        rec = g.bcast(128 << 10)
        g.run_until_delivered(rec)
        assert set(rec.t_deliver) == {"h0", "h2", "h4"}


# ================================================ engine-level lowering

MEMBERS8 = [f"h{i}" for i in range(8)]

CASES = {
    "join": ((MemberEvent("join", "h8", 30e-6),), 7),
    "leave": ((MemberEvent("leave", "h7", 30e-6),), 6),
    "fail": ((MemberEvent("fail", "h7", 30e-6),), 6),
    "mix": ((MemberEvent("master-switch", "h1", 10e-6),
             MemberEvent("leave", "h6", 20e-6),
             MemberEvent("join", "h8", 40e-6),
             MemberEvent("fail", "h5", 60e-6)), 5),
}


def _dynamic_jct(engine, events, n_expected):
    eng = make_engine(engine, fattree.testbed(n_hosts=10))
    rec = eng.stage(GroupOp("bcast", MEMBERS8, 1 << 20, events=events))
    eng.run(timeout=60.0)
    jct = rec.jct(n_expected)
    assert jct != float("inf")
    return jct


@pytest.mark.parametrize("case", sorted(CASES))
def test_dynamic_packet_vs_flow_parity(case):
    """Acceptance: dynamic membership scenarios agree between the
    engines within 10% (observed <= ~3%)."""
    events, n = CASES[case]
    jp = _dynamic_jct("packet", events, n)
    jf = _dynamic_jct("flow", events, n)
    assert jf == pytest.approx(jp, rel=0.10)


def test_dynamic_events_run_on_multihop_topology():
    eng = make_engine("packet", fattree.fig4())
    rec = eng.stage(GroupOp("bcast", ["h0", "h1", "h2"], 2 << 20,
                            events=(MemberEvent("join", "h3", 20e-6),
                                    MemberEvent("fail", "h2", 50e-6))))
    eng.run(timeout=60.0)
    assert rec.jct(1) != float("inf")
    assert "h3" in rec.t_deliver and "h2" not in rec.t_deliver


def test_dynamic_run_many_serial_equals_parallel():
    """run_many's quiesce/fork machinery survives dynamic scenarios:
    serial and workers=2 results are bit-identical."""
    evsets = [(), (MemberEvent("leave", "h5", 10e-6),),
              (MemberEvent("fail", "h4", 15e-6),),
              (MemberEvent("join", "h7", 12e-6),)]
    members = [f"h{i}" for i in range(6)]
    out = {}
    for workers in (None, 2):
        recs = []
        eng = make_engine("packet", fattree.testbed(n_hosts=9),
                          loss_rate=1e-4, seed=7)

        def scen(evs):
            def fn(e):
                recs.append(e.stage(GroupOp("bcast", members, 1 << 19,
                                            events=evs)))
            return fn

        eng.run_many([scen(e) for e in evsets], timeout=60.0,
                     workers=workers)
        out[workers] = [(r.msg_id, r.t_submit, r.t_sender_cqe,
                         sorted(r.t_deliver.items())) for r in recs]
    assert out[None] == out[2]


def _contended_dynamic_jcts(engine):
    """Two piecewise-membership ops sharing h1's downlink until their
    30us leave events — the PR-5 'no contention for dynamic segments'
    known-simplification, now modeled."""
    eng = make_engine(engine, fattree.testbed(n_hosts=5),
                      group_kw={"window": 32})
    ra = eng.stage(GroupOp("bcast", ["h0", "h1", "h2"], 1 << 20,
                           events=(MemberEvent("leave", "h2", 30e-6),)))
    rb = eng.stage(GroupOp("bcast", ["h3", "h1", "h4"], 1 << 20,
                           events=(MemberEvent("leave", "h1", 30e-6),)))
    eng.run(timeout=60.0)
    return ra.jct(1), rb.jct(1)


def test_overlapping_dynamic_ops_contend_like_packet():
    """Regression (ISSUE 6): overlapping dynamic ops must share
    bandwidth segment by segment.  Both ops cross h1's downlink until
    the leaves fire, so each runs at half rate first, full rate after —
    packet parity <= 10% (observed ~2%) on BOTH fluid backends.
    window=32 keeps the packet senders ACK-clocked through the shared
    segment; at larger windows go-back-N runahead on the uncontended
    uplinks adds an asymmetry the fluid model cannot express."""
    jp = _contended_dynamic_jcts("packet")
    solo_eng = make_engine("flow", fattree.testbed(n_hosts=5))
    solo_rec = solo_eng.stage(
        GroupOp("bcast", ["h0", "h1", "h2"], 1 << 20,
                events=(MemberEvent("leave", "h2", 30e-6),)))
    solo_eng.run(timeout=60.0)
    solo = solo_rec.jct(1)
    for engine in ("flow", "flow-np"):
        jf = _contended_dynamic_jcts(engine)
        for f, p in zip(jf, jp):
            assert f == pytest.approx(p, rel=0.10)
        # the shared segment really is priced: slower than the same op
        # running alone, far below the old whole-op-at-shared-rate value
        assert jf[0] > solo * 1.05
        assert jf[0] < solo * 2.0 * 0.85


def test_churn_under_loss_packet_engine():
    """Membership churn and loss recovery compose on the packet engine:
    a lossy fabric with master-switch/leave/join/fail mid-message still
    completes, with real drops recovered along the way."""
    events, n = CASES["mix"]
    eng = make_engine("packet", fattree.testbed(n_hosts=10),
                      loss_rate=1e-3, seed=5)
    rec = eng.stage(GroupOp("bcast", MEMBERS8, 1 << 20, events=events))
    eng.run(timeout=60.0)
    assert rec.jct(n) != float("inf")
    assert eng.net.sim.dropped > 0          # loss genuinely exercised


def test_static_groupop_unchanged_by_events_field():
    """No membership events => the exact static code path: records of a
    fixed-seed scenario match a plain (pre-events-field) GroupOp run."""
    members = [f"h{i}" for i in range(6)]
    out = []
    for _ in range(2):
        eng = make_engine("packet", fattree.testbed(n_hosts=6),
                          loss_rate=1e-4, seed=3)
        rec = eng.stage(GroupOp("bcast", members, 1 << 20, events=()))
        eng.run(timeout=60.0)
        out.append((rec.t_submit, rec.t_sender_cqe,
                    sorted(rec.t_deliver.items())))
    assert out[0] == out[1]


def test_workload_roundtrip_with_events():
    wl = Workload("churn")
    wl.bcast(MEMBERS8, 1 << 20,
             events=(MemberEvent("join", "h8", 1e-5),
                     MemberEvent("fail", "h7", 2e-5)))
    back = Workload.from_dict(wl.to_dict())
    assert back.ops == wl.ops
    assert back.ops[0].events[0].kind == "join"


def test_event_validation():
    with pytest.raises(ValueError, match="unknown event kind"):
        MemberEvent("reboot", "h1", 0.0)
    with pytest.raises(ValueError, match=">= 0"):
        MemberEvent("join", "h1", -1.0)
    with pytest.raises(ValueError, match="already a member"):
        GroupOp("bcast", ("h0", "h1"), 1024,
                events=(MemberEvent("join", "h1", 0.0),))
    with pytest.raises(ValueError, match="not a member"):
        GroupOp("bcast", ("h0", "h1"), 1024,
                events=(MemberEvent("leave", "h9", 0.0),))
    with pytest.raises(ValueError, match="current source"):
        GroupOp("bcast", ("h0", "h1"), 1024,
                events=(MemberEvent("fail", "h0", 0.0),))
    # graceful leave is valid on an overlay relay (the engines resplice
    # the relay schedule, ISSUE 8); join/fail/master-switch are not
    GroupOp("bcast", ("h0", "h1", "h2"), 1024, transport="ring",
            events=(MemberEvent("leave", "h2", 0.0),))
    with pytest.raises(ValueError, match="overlay"):
        GroupOp("bcast", ("h0", "h1", "h2"), 1024, transport="ring",
                events=(MemberEvent("join", "h3", 0.0),))
    with pytest.raises(ValueError, match="bcast/write"):
        GroupOp("allreduce", ("h0", "h1", "h2"), 1024,
                events=(MemberEvent("leave", "h2", 0.0),))
    # master-switch re-points the source: failing the old source is OK
    GroupOp("bcast", ("h0", "h1", "h2"), 1024,
            events=(MemberEvent("master-switch", "h1", 1e-6),
                    MemberEvent("fail", "h0", 2e-6)))


def test_surviving_receivers():
    op = GroupOp("bcast", ("h0", "h1", "h2", "h3"), 1024,
                 events=(MemberEvent("leave", "h2", 1e-6),
                         MemberEvent("join", "h4", 2e-6)))
    assert op.surviving_receivers() == ["h1", "h3"]


def test_agg_min_under_churn_seeded_fuzz():
    """Deterministic (no-hypothesis) slice of the agg-min-under-churn
    property: 300 seeded random event sequences, with bases biased to
    straddle the PSN_MOD wrap.  The hypothesis twin in
    test_protocol_properties explores the space adaptively in CI."""
    import random

    from _membership_props import run_churn_case
    from repro.core.packet import PSN_MOD

    rng = random.Random(0x61EA)
    for _ in range(300):
        base = rng.choice([rng.randrange(PSN_MOD),
                           PSN_MOD - rng.randrange(1, 200),
                           rng.randrange(200)])
        events = [(rng.choice(["ack", "ack", "ack", "add", "remove"]),
                   rng.randrange(1, 7), rng.randrange(301))
                  for _ in range(rng.randrange(1, 80))]
        run_churn_case(base, events)
