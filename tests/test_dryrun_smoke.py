"""Dry-run machinery end-to-end on a small device grid (subprocess with
16 host devices, 4x4 mesh) — validates mesh construction, lowering,
compilation, memory/cost analysis, and the probe-based roofline fit
without the full 512-device production run.
"""
from __future__ import annotations

import pytest

from tests.conftest import run_devices

SRC = r"""
import os
assert os.environ["XLA_FLAGS"].endswith("16")
import jax, json
from repro.configs.base import get_config
from repro.launch import steps
from repro.launch.dryrun import probe_terms
from repro.launch.roofline import summarize

mesh = jax.make_mesh((4, 4), ("data", "model"))
cfg = get_config("granite_3_2b", smoke=True)
# shrink shapes so the smoke config compiles fast
steps.SHAPE_TABLE["train_4k"] = dict(seq=256, batch=16, kind="train",
                                     accum=2)
steps.SHAPE_TABLE["decode_32k"] = dict(seq=256, batch=16, kind="decode")

for shape in ("train_4k", "decode_32k"):
    lowered, spec = steps.lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    rl = summarize(compiled, None, cfg, shape, steps.SHAPE_TABLE[shape],
                   "test", 16, spec.n_params)
    probes = probe_terms(cfg, shape, mesh)
    assert probes["flops"] > 0
    assert probes["bytes"] > 0
    print(shape, "OK", rl.bottleneck)
print("DRYRUN_SMOKE_OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_on_16_devices():
    out = run_devices(SRC, n_devices=16, timeout=1200)
    assert "DRYRUN_SMOKE_OK" in out
