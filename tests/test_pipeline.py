"""Pipeline-parallel primitive: GPipe schedule == unpipelined reference
(8-stage mesh in a subprocess)."""
from __future__ import annotations

import pytest

from tests.conftest import run_devices

SRC = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.parallel.pipeline import pipeline, pipeline_stages

S = 8            # stages
L = 16           # layers (2 per stage)
D = 32
N_MICRO = 4
MB = 2

mesh = jax.make_mesh((S,), ("stage",))
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * 0.3
b = jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1
xs = jax.random.normal(jax.random.PRNGKey(2), (N_MICRO, MB, D))

def layer(p, x):
    wi, bi = p
    return jnp.tanh(x @ wi + bi)

def stage_fn(stage_params, x):
    def body(xx, p):
        return layer(p, xx), None
    out, _ = jax.lax.scan(body, x, stage_params)
    return out

# ---- reference: plain sequential layers over each microbatch
def reference(xs):
    def full(x):
        out, _ = jax.lax.scan(lambda xx, p: (layer(p, xx), None), x, (w, b))
        return out
    return jax.vmap(full)(xs)

want = np.asarray(reference(xs))

# ---- pipelined: layers stage-major, sharded over "stage"
staged = pipeline_stages((w, b), S)          # (S, L/S, ...)

def body(stage_params, xs):
    # shard_map keeps the size-1 stage dim on the local block: squeeze
    stage_params = jax.tree.map(lambda p: p[0], stage_params)
    out = pipeline(stage_fn, "stage")(stage_params, xs)
    # results live on the LAST stage; every other stage holds zeros, so a
    # psum over the stage axis is a broadcast (Gleam one-to-many, again)
    return jax.lax.psum(out, "stage")

f = shard_map(body, mesh=mesh,
              in_specs=((P("stage"), P("stage")), P()),
              out_specs=P(), check_vma=False)
got = np.asarray(jax.jit(f)(staged, xs))
np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

# bubble accounting: ticks = n_micro + S - 1
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_gpipe_pipeline_matches_reference():
    out = run_devices(SRC, n_devices=8)
    assert "PIPELINE_OK" in out
