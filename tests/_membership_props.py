"""Shared driver for the agg-min-under-churn invariant, used by BOTH
the hypothesis property test (``test_protocol_properties``, CI) and the
deterministic seeded fuzz in ``test_membership`` (runs everywhere —
hypothesis is an optional dependency)."""
from __future__ import annotations

from repro.core import fattree, packet as pk
from repro.core.switch import GleamSwitch


def run_churn_case(base: int, events) -> None:
    """Replay (kind, port, delta) events against one GroupTable and
    assert, after every step, that the cached ``agg_min`` equals the
    brute-force windowed ``psn_min`` fold over the live ports and that
    the emitted aggregated-ACK stream advances in wrapped order.

    ``base`` positions the PSN stream (choose near PSN_MOD to cross the
    wrap); ``kind`` is ``ack`` (delta above base), ``add`` (install the
    port mid-window, seeded from ``last_ack_psn``), or ``remove``
    (incremental teardown + the switch's Alg. 3 un-wedge)."""
    topo = fattree.testbed(n_hosts=8)
    sw = GleamSwitch("SW0", topo, fattree.host_ip_map(topo))
    t = sw.tables.create(group_ip=4242)
    # mid-stream state just below the wrap point
    t.last_ack_psn = pk.psn_sub(base, 1)
    t.add_connected(0, dest_ip=1, dest_qpn=16)      # source-facing port
    t.ack_out_port = 0
    for port in (1, 2, 3):
        t.add_connected(port, dest_ip=port + 1, dest_qpn=16 + port)
    mirror = {p: t.entries[p].ack_psn for p in (1, 2, 3)}
    last_emitted = None
    for kind, port, delta in events:
        emitted = []
        if kind == "ack":
            if port not in mirror:
                continue
            psn = pk.psn_add(base, delta)
            out = sw.on_packet(pk.ack_packet(port + 1, 4242, psn),
                               port, 0.0)
            mirror[port] = pk.psn_max(mirror[port], psn)
            emitted = [q.psn for _, q in out if q.kind == pk.ACK]
        elif kind == "add":
            if port in mirror:
                continue
            t.add_connected(port, dest_ip=port + 1, dest_qpn=16 + port)
            mirror[port] = t.entries[port].ack_psn
        else:                                       # remove
            if port not in mirror or len(mirror) == 1:
                continue
            t.remove_port(port)
            del mirror[port]
            # the switch un-wedges after a removal (§3.4): re-run Alg. 3
            emitted = [q.psn for _, q in sw._generate(t, 0.0)
                       if q.kind == pk.ACK]
        brute = None
        for v in mirror.values():
            brute = v if brute is None else pk.psn_min(brute, v)
        if t.agg_min is not None:
            assert t.agg_min[0] == brute, \
                f"cached agg_min {t.agg_min[0]} != brute {brute}"
        for psn in emitted:
            assert psn == brute
            if last_emitted is not None:
                assert pk.psn_gt(psn, last_emitted)
            last_emitted = psn
