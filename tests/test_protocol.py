"""Behavioural tests for the faithful Gleam layer (§3, §4, Appendices).

Every test runs the real packet-level simulator — the same code path the
benchmarks use — on the paper's own topologies (Fig. 8 testbed, Fig. 4
three-layer example).
"""
from __future__ import annotations

import pytest

from repro.core import fattree, packet as pk
from repro.core.baselines import (BinaryTreeBcast, MultiUnicastBcast,
                                  RingBcast)
from repro.core.ftable import CONNECTED, FORWARDED, GroupTable
from repro.core.gleam import GleamNetwork, VIRTUAL_QPN


def make_net(topo=None, **kw) -> GleamNetwork:
    return GleamNetwork(topo or fattree.testbed(), **kw)


# ================================================================ control

class TestRegistration:
    def test_registration_completes(self):
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        t = g.register()
        assert g.registered
        assert t > 0

    def test_forwarding_table_types(self):
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        sw = net.sim.switches["SW0"]
        t = sw.tables.get(g.group_ip)
        assert t is not None
        # all four members hang off SW0 -> all entries connected
        assert len(t.entries) == 4
        assert all(e.type == CONNECTED for e in t.entries.values())

    def test_fig4_tree_structure(self):
        """On the Fig. 4 fat-tree the envelope builds a multi-hop tree:
        leaves get connected entries, interior switches forwarded ones."""
        net = make_net(fattree.fig4())
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        # L1 (h0's leaf): sees the other members via its uplinks
        l1 = net.sim.switches["L1"].tables.get(g.group_ip)
        assert l1 is not None
        kinds = {e.type for e in l1.entries.values()}
        assert CONNECTED in kinds      # h0 directly attached
        assert FORWARDED in kinds      # upstream toward the spines
        # h2's leaf has a connected entry for h2
        l3 = net.sim.switches["L3"].tables.get(g.group_ip)
        assert l3 is not None
        assert any(e.type == CONNECTED for e in l3.entries.values())

    def test_envelope_spans_multiple_packets_over_183_nodes(self):
        """Appendix A: one envelope holds at most 183 member records."""
        topo = fattree.testbed(n_hosts=200)
        net = make_net(topo)
        g = net.multicast_group([f"h{i}" for i in range(200)])
        g.register()
        sw = net.sim.switches["SW0"]
        t = sw.tables.get(g.group_ip)
        assert t is not None and len(t.entries) == 200

    def test_memory_footprint_claim(self):
        """§3.3: 1K maximal groups cost <= 0.92MB of switch memory."""
        t = GroupTable(group_ip=1)
        n_ports = 64
        for port in range(n_ports):
            t.add_connected(port, dest_ip=port + 1, dest_qpn=port + 16)
        per_group = t.table_bytes()
        assert 1000 * per_group <= 0.92 * 2 ** 20 * 2, (
            f"per-group {per_group}B x 1K exceeds 2x the paper's claim")


# ================================================================ data plane

class TestOneToMany:
    def test_bcast_delivers_to_all(self):
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.bcast(1 << 20)
        jct = g.run_until_delivered(rec)
        assert len(rec.t_deliver) == 3
        assert jct < float("inf")
        assert rec.t_sender_cqe > 0          # hardware-reliability CQE

    def test_sender_transmits_once(self):
        """The Gleam sender puts ONE copy on its link; the switch makes
        the copies (Fig. 2c vs 2a)."""
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        nbytes = 1 << 20
        rec = g.bcast(nbytes)
        g.run_until_delivered(rec)
        sw = net.sim.switches["SW0"]
        assert sw.stats.data_in >= nbytes // pk.MTU
        # each in-packet fanned out to 3 receivers
        assert sw.stats.data_copies == 3 * sw.stats.data_in

    def test_header_rewrite_matches_receiver_qp(self):
        """Fig. 6: receivers accept because dest IP/QPN are rewritten;
        no_qp_drops (the Fig. 3 failure mode) must be zero."""
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.bcast(64 << 10)
        g.run_until_delivered(rec)
        for h in ("h1", "h2", "h3"):
            assert net.sim.hosts[h].no_qp_drops == 0

    def test_without_rewrite_receivers_drop(self):
        """Ablation — reproduce Fig. 3: forward multicast copies WITHOUT
        the layer-4 rewrite and watch every receiver discard them."""
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        sw = net.sim.switches["SW0"]
        t = sw.tables.get(g.group_ip)
        for e in t.entries.values():
            e.type = FORWARDED          # strip the rewrite capability
        rec = g.bcast(16 << 10)
        net.sim.run(until=net.sim.now + 0.05)
        drops = sum(net.sim.hosts[h].no_qp_drops for h in ("h1", "h2", "h3"))
        assert drops > 0
        assert len(rec.t_deliver) == 0

    def test_multicast_jct_beats_multiunicast(self):
        """Fig. 9's structure: for large messages Gleam ~n-1 times faster
        than multiple unicasts on the testbed."""
        nbytes = 8 << 20
        net1 = make_net()
        g = net1.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.bcast(nbytes)
        jct_gleam = g.run_until_delivered(rec)
        net2 = make_net()
        mu = MultiUnicastBcast(net2, ["h0", "h1", "h2", "h3"])
        mu.start(nbytes)
        jct_mu = mu.run()
        assert jct_gleam < jct_mu
        assert jct_mu / jct_gleam > 2.0      # ~3x at 3 receivers

    def test_gleam_beats_overlays(self):
        nbytes = 4 << 20
        members = ["h0", "h1", "h2", "h3"]
        net = make_net()
        g = net.multicast_group(members)
        g.register()
        rec = g.bcast(nbytes)
        jct_gleam = g.run_until_delivered(rec)
        for cls in (RingBcast, BinaryTreeBcast):
            net_b = make_net()
            b = cls(net_b, members, chunks=8)
            b.start(nbytes)
            jct_b = b.run()
            assert jct_gleam < jct_b, f"{cls.__name__} beat Gleam?"


class TestWrite:
    def test_one_to_many_write(self):
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.write(256 << 10)
        jct = g.run_until_delivered(rec)
        assert jct < float("inf")
        for h in ("h1", "h2", "h3"):
            assert net.sim.hosts[h].no_qp_drops == 0
            qp = g.qps[h]
            assert qp.mr_violations == 0

    def test_write_same_mr_appendix_c(self):
        """Appendix C: shared VA/R_key removes the MR_UPDATE traffic."""
        net1 = make_net()
        g1 = net1.multicast_group(["h0", "h1", "h2", "h3"])
        g1.register()
        tx0 = net1.sim.tx_bytes
        rec = g1.write(64 << 10, same_mr=False)
        g1.run_until_delivered(rec)
        with_update = net1.sim.tx_bytes - tx0

        net2 = make_net()
        g2 = net2.multicast_group(["h0", "h1", "h2", "h3"])
        g2.register()
        # receivers must share the sender's MR for Appendix-C mode
        rkey0 = next(iter(g2.qps["h0"].mrs.keys()))
        va0 = g2.qps["h0"].mrs[rkey0][0]
        for m in ("h1", "h2", "h3"):
            g2.qps[m].register_mr(rkey0, va0, 1 << 30)
        sw = net2.sim.switches["SW0"]
        for e in sw.tables.get(g2.group_ip).entries.values():
            e.va, e.rkey = va0, rkey0
        tx0 = net2.sim.tx_bytes
        rec2 = g2.write(64 << 10, same_mr=True)
        g2.run_until_delivered(rec2)
        without_update = net2.sim.tx_bytes - tx0
        assert without_update < with_update


# ================================================================ feedback

class TestFeedbackAggregation:
    def test_sender_sees_unicast_like_ack_stream(self):
        """§3.4: ACKs reaching the sender must be a single aggregated
        stream — fewer ACKs than 3 receivers would send individually."""
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.bcast(1 << 20)
        g.run_until_delivered(rec)
        sw = net.sim.switches["SW0"]
        assert sw.stats.acks_out < sw.stats.acks_in
        # aggregated stream cannot outnumber one receiver's stream
        assert sw.stats.acks_out <= sw.stats.acks_in // 3 + 2

    def test_ack_only_after_all_receivers(self):
        """Principle (i): the source receives an ACK for PSN p only when
        ALL receivers have acked p. Verified via sender CQE vs deliveries:
        the CQE time must be >= every receiver's delivery time."""
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.bcast(512 << 10)
        g.run_until_delivered(rec)
        assert rec.t_sender_cqe >= max(rec.t_deliver.values()) - 1e-9

    def test_loss_recovery_single_receiver_loss(self):
        """Packets dropped in-fabric are go-back-N retransmitted; message
        still completes and every receiver gets full data."""
        net = make_net(loss_rate=1e-3, seed=7)
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        nbytes = 2 << 20
        rec = g.bcast(nbytes)
        jct = g.run_until_delivered(rec, timeout=10.0)
        assert jct < float("inf")
        assert net.sim.dropped > 0, "loss was configured but none injected"
        assert g.qps["h0"].retransmitted > 0
        for h in ("h1", "h2", "h3"):
            assert g.qps[h].delivered_bytes >= nbytes

    def test_goodput_degrades_gracefully(self):
        """Fig. 16's structure: goodput at 1e-4 loss stays within ~15% of
        lossless; 1e-3 degrades much more."""
        def jct_at(loss):
            net = make_net(loss_rate=loss, seed=3)
            g = net.multicast_group(["h0", "h1", "h2", "h3"])
            g.register()
            rec = g.bcast(4 << 20)
            return g.run_until_delivered(rec, timeout=30.0)

        j0 = jct_at(0.0)
        j4 = jct_at(1e-4)
        j3 = jct_at(1e-3)
        assert j0 < float("inf") and j4 < float("inf") and j3 < float("inf")
        assert j4 <= j3
        assert j0 / j4 > 0.5                  # goodput >= 50% at 1e-4

    def test_nack_filtering_fig7_hazard(self):
        """Fig. 7: a NACK for p2 (receiver B) must NOT reach the sender
        before everything below p2 is acked by ALL receivers — otherwise
        p1's loss at receiver A would be masked. We assert the invariant
        at the switch: every emitted NACK's ePSN == min_ack + 1."""
        from repro.core.switch import GleamSwitch
        topo = fattree.testbed()
        hosts = fattree.host_ip_map(topo)
        sw = GleamSwitch("SW0", topo, hosts)
        t = sw.tables.create(group_ip=999)
        t.add_connected(0, dest_ip=hosts["h0"], dest_qpn=17)  # source side
        t.add_connected(1, dest_ip=hosts["h1"], dest_qpn=18)
        t.add_connected(2, dest_ip=hosts["h2"], dest_qpn=19)
        t.ack_out_port = 0
        # R1 (port 1) lost p1: acks p0 (psn 0), then NACK ePSN=1
        # R2 (port 2) got p1, lost p2: acks p1 (psn 1), then NACK ePSN=2
        out = []
        out += sw.on_packet(pk.ack_packet(hosts["h1"], 999, 0), 1, 0.0)
        out += sw.on_packet(pk.ack_packet(hosts["h2"], 999, 1), 2, 0.0)
        out += sw.on_packet(pk.nack_packet(hosts["h2"], 999, 2), 2, 0.0)
        # R2's NACK(2) must be withheld: R1 has only acked up to 0
        nacks = [p for _, p in out if p.kind == pk.NACK]
        assert nacks == [], "NACK(2) leaked before R1 acked p1"
        out2 = sw.on_packet(pk.nack_packet(hosts["h1"], 999, 1), 1, 0.0)
        nacks2 = [p for _, p in out2 if p.kind == pk.NACK]
        assert len(nacks2) == 1 and nacks2[0].psn == 1, (
            "the minimum NACK (ePSN=1) must be forwarded")

    def test_ack_aggregation_is_min(self):
        from repro.core.switch import GleamSwitch
        topo = fattree.testbed()
        hosts = fattree.host_ip_map(topo)
        sw = GleamSwitch("SW0", topo, hosts)
        t = sw.tables.create(group_ip=999)
        t.add_connected(0, dest_ip=hosts["h0"], dest_qpn=17)
        t.add_connected(1, dest_ip=hosts["h1"], dest_qpn=18)
        t.add_connected(2, dest_ip=hosts["h2"], dest_qpn=19)
        t.ack_out_port = 0
        out = sw.on_packet(pk.ack_packet(hosts["h1"], 999, 5), 1, 0.0)
        assert out == []                      # h2 hasn't acked anything
        out = sw.on_packet(pk.ack_packet(hosts["h2"], 999, 3), 2, 0.0)
        acks = [p for _, p in out if p.kind == pk.ACK]
        assert len(acks) == 1 and acks[0].psn == 3   # min(5, 3)


# ================================================================ §3.5 / B

class TestSourceSwitchingAndCC:
    def test_source_switching_no_reregistration(self):
        """Appendix B: rotate the source; next transfer works with the
        same QPs and tables."""
        net = make_net()
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec0 = g.bcast(128 << 10)
        g.run_until_delivered(rec0)
        g.switch_source("h1")
        rec1 = g.bcast(128 << 10)
        jct = g.run_until_delivered(rec1)
        assert jct < float("inf")
        assert len(rec1.t_deliver) == 3
        # h0 (old source) must be among the new receivers
        assert "h0" in rec1.t_deliver

    def test_psn_sync(self):
        """The PSN synchronization of Fig. 19."""
        net = make_net()
        g = net.multicast_group(["h0", "h1"])
        g.register()
        rec = g.bcast(1 << 20)
        g.run_until_delivered(rec)
        old, new = g.qps["h0"], g.qps["h1"]
        sq_before = new.sq_psn
        g.switch_source("h1")
        assert new.sq_psn == new.rq_psn       # new source aligned
        assert old.rq_psn == old.sq_psn       # old source aligned
        assert new.sq_psn >= sq_before

    def test_cnp_filtering_most_congested_only(self):
        """§3.5: only the most congested port's CNP passes upstream."""
        from repro.core.switch import GleamSwitch
        topo = fattree.testbed()
        hosts = fattree.host_ip_map(topo)
        sw = GleamSwitch("SW0", topo, hosts)
        t = sw.tables.create(group_ip=999)
        t.add_connected(0, dest_ip=hosts["h0"], dest_qpn=17)
        t.add_connected(1, dest_ip=hosts["h1"], dest_qpn=18)
        t.add_connected(2, dest_ip=hosts["h2"], dest_qpn=19)
        t.ack_out_port = 0
        # port 1 becomes the hot link: 3 CNPs vs port 2's 1
        now = 0.0
        passed = []
        for i in range(3):
            now += 1e-6
            passed += sw.on_packet(pk.cnp_packet(hosts["h1"], 999), 1, now)
        now += 1e-6
        blocked = sw.on_packet(pk.cnp_packet(hosts["h2"], 999), 2, now)
        assert len(passed) >= 1               # hot-path CNPs pass
        assert blocked == []                  # cold-path CNP filtered

    def test_cc_slows_sender_on_congestion(self):
        """DCQCN reaction: ECN-marked queues produce CNPs that cut the
        sender's rate below line rate."""
        net = make_net(ecn_backlog=20e-6)
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        peak = g.qps["h0"].rate.peak
        rec = g.bcast(8 << 20)
        g.run_until_delivered(rec)
        assert g.qps["h0"].rate.rate <= peak


# ================================================================ P4 mode

class TestP4Mode:
    def test_p4_window_bcast(self):
        """§4: the 2^22 comparison window still delivers correctly."""
        net = make_net(p4_mode=True)
        g = net.multicast_group(["h0", "h1", "h2", "h3"])
        g.register()
        rec = g.bcast(1 << 20)
        jct = g.run_until_delivered(rec)
        assert jct < float("inf")
        assert len(rec.t_deliver) == 3

    def test_psn_wraparound_comparisons(self):
        w22 = pk.PSN_WINDOW_P4
        near_top = pk.PSN_MOD - 10
        assert pk.psn_gt(5, near_top, w22)        # wrapped: 5 "after" top
        assert not pk.psn_geq(near_top, 5, w22)
        assert pk.psn_min(near_top, 5, w22) == near_top
        assert pk.psn_max(near_top, 5, w22) == 5
