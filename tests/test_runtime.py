"""Integration tests: data pipeline, checkpointing, fault-tolerant
training (failure injection + restart), gradient compression, straggler
detection, elastic re-mesh, and the continuous-batching server.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.sharded import CheckpointManager
from repro.configs.base import get_config
from repro.data.pipeline import (DataConfig, FileSource, Pipeline,
                                 write_token_file)
from repro.launch.mesh import single_device_mesh
from repro.models import model as mdl
from repro.models.blocks import init_params
from repro.runtime import train as rt
from repro.runtime.elastic import ElasticGroup, remesh_tree
from repro.runtime.serve import Server

ARCH = "granite_3_2b"


def small_cfg():
    return get_config(ARCH, smoke=True)


def data_cfg(cfg, batch=4, seq=32):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=7)


# ================================================================= data

class TestPipeline:
    def test_deterministic_and_resumable(self):
        cfg = small_cfg()
        p1 = Pipeline(data_cfg(cfg))
        p2 = Pipeline(data_cfg(cfg))
        b1 = p1.batch_at(13)
        b2 = p2.batch_at(13)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (4, 32)
        # targets are next-token
        np.testing.assert_array_equal(b1["targets"][:, :-1],
                                      b1["tokens"][:, 1:])

    def test_replica_sharding_disjoint_and_covering(self):
        cfg = small_cfg()
        base = data_cfg(cfg, batch=8)
        full = Pipeline(base).batch_at(3)["tokens"]
        parts = []
        for r in range(4):
            dc = DataConfig(**{**base.__dict__, "n_replicas": 4,
                               "replica_id": r})
            parts.append(Pipeline(dc).batch_at(3)["tokens"])
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_file_source_roundtrip(self, tmp_path):
        toks = np.arange(10_000, dtype=np.int32) % 97
        path = tmp_path / "corpus.bin"
        write_token_file(path, toks)
        cfg = small_cfg()
        dc = DataConfig(vocab_size=97, seq_len=32, global_batch=4,
                        path=str(path))
        batch = Pipeline(dc).batch_at(0)
        assert batch["tokens"].shape == (4, 32)
        # windows must come from the corpus (consecutive mod-97 runs)
        row = batch["tokens"][0]
        diffs = np.diff(row.astype(np.int64)) % 97
        assert (diffs == 1).all()


# ============================================================ checkpoint

class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)}}
        mgr.save(10, tree, meta={"loss": 1.5})
        got, step, meta = mgr.restore(tree)
        assert step == 10 and meta["loss"] == 1.5
        np.testing.assert_array_equal(got["a"], tree["a"])

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_write_commits(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3, async_write=True)
        tree = {"x": jnp.arange(5.0)}
        mgr.save(1, tree)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_crash_leaves_no_partial_checkpoint(self, tmp_path):
        """Only COMMITTED checkpoints are visible (atomic rename)."""
        mgr = CheckpointManager(tmp_path, keep=3, async_write=False)
        tree = {"x": jnp.arange(5.0)}
        mgr.save(1, tree)
        # fake a crash mid-write: tmp dir without COMMITTED
        (tmp_path / "step_000000099").mkdir()
        assert mgr.all_steps() == [1]


# ========================================================= fault-tolerant

class TestTrainerFT:
    def _mk(self, tmp_path, **kw):
        cfg = small_cfg().replace(n_layers=2)
        mesh = single_device_mesh()
        tc = rt.TrainerConfig(total_steps=8, ckpt_every=4,
                              ckpt_dir=str(tmp_path), keep=3,
                              log_every=100, **kw)
        return rt.Trainer(cfg, mesh, data_cfg(cfg), tc,
                          log=lambda *_: None)

    def test_loss_decreases(self, tmp_path):
        t = self._mk(tmp_path)
        out = t.run()
        losses = [h["loss"] for h in out["history"]]
        assert losses[-1] < losses[0]

    def test_failure_injection_and_restart_is_exact(self, tmp_path):
        """Crash at step 6, restart from the step-4 checkpoint: final
        state must equal an uninterrupted run (deterministic data +
        deterministic step)."""
        ref = self._mk(tmp_path / "ref")
        ref_out = ref.run()

        t = self._mk(tmp_path / "ft", fail_at_steps=(6,))
        with pytest.raises(rt.SimulatedFailure):
            t.run()
        # simulate process restart: fresh Trainer, same ckpt dir
        t2 = self._mk(tmp_path / "ft")
        out = t2.run(resume=True)
        assert t2.ckpt.latest_step() == 8
        np.testing.assert_allclose(out["final_loss"],
                                   ref_out["final_loss"], rtol=1e-6)

    def test_straggler_detector_flags_outlier(self):
        det = rt.StragglerDetector(warmup=3)
        for i in range(10):
            det.observe(i, 0.10)
        assert det.observe(99, 1.0)            # 10x step: flagged
        assert det.flagged and det.flagged[-1][0] == 99

    def test_grad_compression_error_feedback(self):
        """int8+EF: the quantization error is carried, so the SUM of
        applied gradients converges to the true sum (lossless in
        expectation)."""
        g = {"w": jnp.asarray(np.random.default_rng(0)
                              .normal(size=(64,)).astype(np.float32))}
        err = {"w": jnp.zeros(64)}
        applied = jnp.zeros(64)
        for _ in range(50):
            g_hat, err = rt.compressed_grads(g, err)
            applied = applied + g_hat["w"]
        np.testing.assert_allclose(np.asarray(applied) / 50,
                                   np.asarray(g["w"]), atol=1e-2)

    def test_compressed_training_still_learns(self, tmp_path):
        t = self._mk(tmp_path, grad_compression="int8_ef")
        out = t.run()
        losses = [h["loss"] for h in out["history"]]
        assert losses[-1] < losses[0]


# ============================================================== elastic

class TestElastic:
    def test_remesh_roundtrip(self):
        cfg = small_cfg().replace(n_layers=2)
        defs = mdl.model_defs(cfg)
        params = init_params(defs, jax.random.PRNGKey(0))
        mesh = single_device_mesh()
        moved = remesh_tree(params, defs, mesh)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(moved)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_group_epoch_fencing(self):
        g = ElasticGroup(["pod0", "pod1"])
        e0 = g.epoch
        g.fail("pod1")
        assert g.active() == ["pod0"]
        assert not g.is_current(e0)            # stale epoch fenced
        g.join("pod2")
        assert "pod2" in g.active()


# ================================================================ server

class TestServer:
    def _server(self, pool=3):
        cfg = small_cfg().replace(n_layers=2)
        params = init_params(mdl.model_defs(cfg), jax.random.PRNGKey(0))
        mesh = single_device_mesh()
        return Server(cfg, params, mesh, pool=pool, max_seq=64), cfg

    def test_serves_batched_requests(self):
        srv, cfg = self._server()
        reqs = [srv.submit([1, 2, 3], max_new_tokens=5) for _ in range(7)]
        stats = srv.run_until_drained()
        assert stats.completed == 7
        assert all(len(r.out_tokens) == 5 for r in reqs)
        assert all(0 <= t < cfg.vocab_size
                   for r in reqs for t in r.out_tokens)

    def test_continuous_batching_overlaps(self):
        """A request submitted mid-flight shares decode steps with the
        running pool (steps < sequential total)."""
        srv, _ = self._server(pool=2)
        srv.submit([1, 2, 3, 4], max_new_tokens=8)
        srv.submit([5, 6], max_new_tokens=8)
        for _ in range(4):
            srv.step()
        srv.submit([7, 8, 9], max_new_tokens=8)
        stats = srv.run_until_drained()
        assert stats.completed == 3
        sequential = (4 + 8) + (2 + 8) + (3 + 8)
        assert stats.steps < sequential

    def test_server_matches_manual_decode(self):
        """Greedy continuation from the server == manual decode loop."""
        srv, cfg = self._server(pool=2)
        prompt = [3, 1, 4, 1, 5]
        r = srv.submit(prompt, max_new_tokens=4)
        srv.run_until_drained()
        # manual: same params, one-at-a-time
        params = srv.params
        mesh = srv.mesh
        caches = mdl.init_caches(cfg.replace(n_layers=2), 1, 64)
        toks = list(prompt)
        out = []
        pos = 0
        for t in range(len(prompt) + 3):
            tok = jnp.asarray([[toks[t] if t < len(toks) else out[-1]]],
                              jnp.int32)
            cur = toks[t] if t < len(toks) else out[-1]
            logits, caches = mdl.decode_forward(
                params, caches, jnp.asarray([[cur]], jnp.int32),
                jnp.int32(pos), cfg.replace(n_layers=2), mesh,
                batch_shardable=False)
            pos += 1
            if t >= len(prompt) - 1:
                out.append(int(jnp.argmax(logits[0, 0])))
        assert r.out_tokens == out[:4]
