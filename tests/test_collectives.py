"""Distributed-layer tests: the Gleam collectives (tree broadcast /
reduce / butterfly, split-KV softmax combine) and the MoE dispatch run on
an 8-device host mesh in a subprocess (device count locks at jax init, so
the main test process stays at 1 device).
"""
from __future__ import annotations

import pytest

from tests.conftest import run_devices

COLLECTIVES_SRC = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import collectives as coll

mesh = jax.make_mesh((8,), ("x",))
v = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

def on_mesh(fn, in_specs=P("x"), out_specs=P("x")):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False))

# --- tree_broadcast: every rank ends with the root's shard
for root in (0, 3, 7):
    got = on_mesh(lambda s, r=root: coll.tree_broadcast(s, "x", root=r))(v)
    want = jnp.tile(v[root], (8, 1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want)), root

# --- unicast / ring broadcast agree with tree
for fn in (coll.unicast_broadcast, coll.ring_broadcast):
    got = on_mesh(lambda s, f=fn: f(s, "x", root=2))(v)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.tile(np.asarray(v[2]), (8, 1)))
got = on_mesh(lambda s: coll.ring_broadcast(s, "x", root=1, chunks=2))(v)
np.testing.assert_array_equal(np.asarray(got),
                              np.tile(np.asarray(v[1]), (8, 1)))

# --- tree_reduce to root == sum over shards
got = on_mesh(lambda s: coll.tree_reduce(s, "x", jnp.add, root=0))(v)
np.testing.assert_allclose(np.asarray(got)[0], np.asarray(v).sum(0))

# --- butterfly allreduce == psum, for sum AND min (PSN-style monoid)
got = on_mesh(lambda s: coll.butterfly_allreduce(s, "x", jnp.add))(v)
np.testing.assert_allclose(np.asarray(got),
                           np.tile(np.asarray(v).sum(0), (8, 1)))
got = on_mesh(lambda s: coll.butterfly_allreduce(s, "x", jnp.minimum))(v)
np.testing.assert_allclose(np.asarray(got),
                           np.tile(np.asarray(v).min(0), (8, 1)))

# --- allreduce_sum schedules all agree
ref = None
for sched in ("xla", "gleam_tree", "ring", "unicast"):
    got = on_mesh(lambda s, sc=sched:
                  coll.allreduce_sum(s, ("x",), schedule=sc))(v)
    if ref is None:
        ref = np.asarray(got)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-6), sched

# --- softmax_combine: both schedules merge split-KV partials exactly
key = jax.random.PRNGKey(0)
B, H, S, D = 2, 4, 64, 16
q = jax.random.normal(key, (B, 1, H, D), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D), jnp.float32)
vv = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D), jnp.float32)

def full_attn():
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k) / jnp.sqrt(D)
    w = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhqs,bshd->bqhd", w, vv)

def sharded(schedule):
    def body(ql, kl, vl):
        logits = jnp.einsum("bqhd,bshd->bhqs", ql, kl) / jnp.sqrt(D)
        m = logits.max(-1)
        p = jnp.exp(logits - m[..., None])
        l = p.sum(-1)
        acc = jnp.einsum("bhqs,bshd->bhqd", p, vl)
        m, l, acc = coll.softmax_combine((m, l, acc), ("x",),
                                         schedule=schedule)
        out = acc / l[..., None]
        return out.transpose(0, 2, 1, 3)
    f = shard_map(body, mesh=mesh,
                  in_specs=(P(), P(None, "x"), P(None, "x")),
                  out_specs=P(), check_vma=False)
    return jax.jit(f)(q, k, vv)

want = np.asarray(full_attn())
for schedule in ("xla", "gleam_tree"):
    got = np.asarray(sharded(schedule))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5), schedule
print("COLLECTIVES_OK")
"""


MOE_SRC = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import get_config
from repro.models import moe as moe_mod
from repro.models.blocks import init_params
from repro.models.model import model_defs

# 1x4 mesh: 4-way expert parallelism over "model"
mesh = jax.make_mesh((1, 4), ("data", "model"))
cfg = get_config("qwen3_moe_235b_a22b", smoke=True)
assert moe_mod.expert_mode(cfg, 4) == "ep"
defs = moe_mod.moe_defs(cfg)
params = init_params(defs, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                      jnp.float32).astype(jnp.bfloat16)

with mesh:
    y_ep, aux_ep = moe_mod.moe_train(params, x, cfg, mesh,
                                     ("pod", "data"))
    y_dec, aux_dec = moe_mod.moe_decode(params, x, cfg, mesh,
                                        ("pod", "data"))

# single-device reference: dense top-k MoE
def ref_moe(params, x):
    t = x.reshape(-1, x.shape[-1])
    gates, ids, aux = moe_mod._router(t, params["router"], cfg.top_k)
    cd = jnp.bfloat16
    out = jnp.zeros((t.shape[0], x.shape[-1]), jnp.float32)
    for e in range(cfg.n_experts):
        h = (jax.nn.silu(t.astype(cd) @ params["we_g"][e].astype(cd))
             * (t.astype(cd) @ params["we_i"][e].astype(cd)))
        ye = (h @ params["we_o"][e].astype(cd)).astype(jnp.float32)
        for kk in range(cfg.top_k):
            sel = (ids[:, kk] == e)
            out = out + jnp.where(sel[:, None],
                                  ye * gates[:, kk][:, None], 0)
    return out.reshape(x.shape), aux

y_ref, aux_ref = ref_moe(params, x)
np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                           np.asarray(y_ref, np.float32),
                           rtol=0.05, atol=0.05)
# EP path drops tokens only above capacity; at cf=1.25 and uniform-ish
# routing the outputs should match closely
match = np.isclose(np.asarray(y_ep, np.float32),
                   np.asarray(y_ref, np.float32),
                   rtol=0.05, atol=0.05).mean()
assert match > 0.95, f"EP/ref mismatch fraction {1 - match:.3f}"
print("MOE_OK")
"""


DECODE_SHARDED_SRC = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import get_config
from repro.launch.steps import make_serve_step
from repro.models import model as mdl
from repro.models.blocks import init_params

# 2x4 mesh: KV sharded over model axis during decode
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("granite_3_2b", smoke=True).replace(n_layers=2)
params = init_params(mdl.model_defs(cfg), jax.random.PRNGKey(0))
B, S = 4, 64
caches = mdl.init_caches(cfg, B, S)
serve = make_serve_step(cfg, mesh, batch_shardable=True)
tok = jnp.ones((B, 1), jnp.int32)

with mesh:
    jit_serve = jax.jit(serve)
    logits8 = None
    c = caches
    for t in range(3):
        logits8, c = jit_serve(params, c, tok + t, jnp.int32(t))

# single-device reference
mesh1 = jax.make_mesh((1, 1), ("data", "model"))
serve1 = make_serve_step(cfg, mesh1, batch_shardable=False)
with mesh1:
    c = mdl.init_caches(cfg, B, S)
    for t in range(3):
        logits1, c = jax.jit(serve1)(params, c, tok + t, jnp.int32(t))

np.testing.assert_allclose(np.asarray(logits8), np.asarray(logits1),
                           rtol=2e-2, atol=2e-2)
print("DECODE_SHARDED_OK")
"""


@pytest.mark.slow
def test_collectives_on_8_devices():
    out = run_devices(COLLECTIVES_SRC, n_devices=8)
    assert "COLLECTIVES_OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_matches_reference():
    out = run_devices(MOE_SRC, n_devices=4)
    assert "MOE_OK" in out


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    out = run_devices(DECODE_SHARDED_SRC, n_devices=8)
    assert "DECODE_SHARDED_OK" in out
