"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED config (same family:
MoE stays MoE, hybrid stays hybrid, enc-dec keeps its encoder) and runs
one train step and one decode step on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch import steps
from repro.launch.mesh import single_device_mesh
from repro.models import model as mdl
from repro.models.blocks import count_params, init_params
from repro.models.model import model_defs
from repro.optim import adamw

SEQ, BATCH = 64, 2


def _batch(cfg, *, train: bool, key=0):
    rng = jax.random.PRNGKey(key)
    structs = steps.batch_structs(cfg, SEQ, BATCH, train=train)
    out = {}
    for k, v in structs.items():
        kk, rng = jax.random.split(rng)[0], jax.random.split(rng)[1]
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(kk, v.shape, 0, cfg.vocab_size)
        elif k == "loss_mask":
            out[k] = jnp.ones(v.shape, v.dtype)
        else:
            out[k] = jax.random.normal(kk, v.shape, jnp.float32).astype(
                v.dtype)
    return out


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, mesh):
    cfg = get_config(arch, smoke=True)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    batch = _batch(cfg, train=True)
    step_fn = steps.make_train_step(cfg, mesh)
    with mesh:
        params2, opt2, metrics = jax.jit(step_fn)(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert loss > 0.0
    assert float(metrics["grad_norm"]) > 0.0
    assert int(opt2["step"]) == 1
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"{arch}: optimizer step was a no-op"
    # loss decreases after a few steps on a fixed batch (sanity, not perf)
    for _ in range(3):
        params2, opt2, metrics2 = jax.jit(step_fn)(params2, opt2, batch)
    assert float(metrics2["loss"]) < loss, (
        f"{arch}: loss did not decrease ({loss} -> "
        f"{float(metrics2['loss'])})")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, mesh):
    cfg = get_config(arch, smoke=True)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(1))
    batch = _batch(cfg, train=False)
    with mesh:
        logits, aux = mdl.forward(params, batch, cfg, mesh)
    s_text = SEQ - cfg.vision_prefix if cfg.vision_prefix else SEQ
    assert logits.shape == (BATCH, s_text, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    if cfg.n_experts:
        assert float(aux) > 0.0, f"{arch}: MoE aux loss missing"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, mesh):
    cfg = get_config(arch, smoke=True)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(2))
    caches = mdl.init_caches(cfg, BATCH, SEQ)
    serve = steps.make_serve_step(cfg, mesh, batch_shardable=False)
    tok = jnp.ones((BATCH, 1), jnp.int32)
    with mesh:
        jit_serve = jax.jit(serve)
        logits, caches = jit_serve(params, caches, tok, jnp.int32(0))
        logits2, caches = jit_serve(params, caches, tok, jnp.int32(1))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch, mesh):
    """Prefill logits at position t == decode logits after feeding tokens
    0..t-1 — the KV-cache path must agree with the parallel path."""
    # f32 compute: this test checks PATH equivalence (cache vs parallel),
    # not bf16 accumulation noise (jamba's 8 heterogeneous sublayers show
    # ~0.45 max log-softmax drift in bf16; 1e-5 in f32).
    cfg = get_config(arch, smoke=True).replace(compute_dtype="float32")
    if cfg.enc_layers > 0:
        pytest.skip("enc-dec decode consumes a fixed encoder memory stub; "
                    "covered by test_decode_step")
    params = init_params(model_defs(cfg), jax.random.PRNGKey(3))
    n = 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, n), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.vision_prefix:
        pytest.skip("VLM prefix offsets positions; covered by smoke tests")
    with mesh:
        full_logits, _ = mdl.forward(params, batch, cfg, mesh)
        caches = mdl.init_caches(cfg, 1, n, dtype=jnp.float32)
        dec = []
        for t in range(n):
            logits, caches = mdl.decode_forward(
                params, caches, toks[:, t:t + 1], jnp.int32(t), cfg, mesh,
                batch_shardable=False)
            dec.append(logits[:, 0])
    dec = jnp.stack(dec, axis=1)
    err = jnp.max(jnp.abs(jax.nn.log_softmax(full_logits)
                          - jax.nn.log_softmax(dec)))
    assert float(err) < 1e-3, f"{arch}: decode/prefill diverge, max={err}"


def test_all_archs_have_smoke_and_full():
    for arch in ARCH_IDS:
        full = get_config(arch)
        smoke = get_config(arch, smoke=True)
        assert full.name == smoke.name
        assert full.family == smoke.family
        # smoke must be materially smaller
        assert count_params(model_defs(smoke)) < 1e7
