import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, collections
sys.path.insert(0, "src")
from repro.configs.base import get_config
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import _probe_cfg
from repro.launch.roofline import _SHAPE_RE, _DTYPE_BYTES

arch, shape, k = sys.argv[1], sys.argv[2], int(sys.argv[3])
cfg = get_config(arch)
seq = steps.SHAPE_TABLE[shape]["seq"]
if k > 0:
    cfg = _probe_cfg(cfg, k, seq)
mesh = make_production_mesh(multi_pod=False)
lowered, _ = steps.lower_cell(cfg, shape, mesh)
compiled = lowered.compile()
txt = compiled.as_text()
COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
def shape_bytes(ts):
    total = 0
    for dt, dims in _SHAPE_RE.findall(ts):
        if dt not in _DTYPE_BYTES: continue
        n = 1
        for d in dims.split(","):
            if d: n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total
rows = []
for line in txt.splitlines():
    line = line.strip()
    m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", line)
    if not m: continue
    ts, op = m.group(1), m.group(2)
    base = next((c for c in COLL if op == c or op.startswith(c + "-start")), None)
    if base is None: continue
    rows.append((shape_bytes(ts), base, line[:220]))
rows.sort(reverse=True)
tot = collections.Counter()
for b, base, _ in rows: tot[base] += b
print("TOTALS:", {k: f"{v/1e9:.2f}GB" for k, v in tot.items()})
for b, base, line in rows[:25]:
    print(f"{b/1e9:8.3f}GB {base:20s} {line}")
