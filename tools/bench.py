#!/usr/bin/env python3
"""Flow-solver performance tracking — writes BENCH_flowsim.json.

Times the fixed fig14 workload (HPL scales 8/16/32 on the flow engine,
1024-host fat-tree) through two solver paths, each in its OWN
subprocess so neither warms the other's topology/routing/jit caches:

- **before** — the PR-1 solver discipline: one engine + one solve per
  scenario, shape bucketing off, fresh topology per scenario, no
  persistent compilation cache (PR-1 recompiled every process);
- **after**  — the stage-then-batch path: the whole sweep staged on one
  engine, solved by a single ``run_many`` (shape-bucketed, vmapped
  epoch batches), persistent compilation cache on.  Measured twice:
  a cold process with an empty cache directory, then a second fresh
  process against the now-warm directory (the steady state every run
  after the first sees).

Every measurement is the sweep wall-clock around ``fig14_scale.run()``
(imports excluded — the same basis as the time fig14 prints).  Inside
each subprocess the sweep runs twice; pass2 hits the in-process jit
cache, so ``pass1 - pass2`` estimates compile cost, and the solver's
own device time (``flowsim_jax.SOLVE_STATS``) splits python staging
from solve.

``--before-git REF`` additionally times the ACTUAL code at a git ref
(e.g. the PR-1 commit) via ``git archive``, same basis, for a
ground-truth baseline.

    PYTHONPATH=src python tools/bench.py                     # full
    PYTHONPATH=src python tools/bench.py --before-git HEAD~1 # + git ref
    PYTHONPATH=src python tools/bench.py --smoke             # CI-sized

``--smoke`` shrinks the workload (one small scale, batched path only)
and still writes the json — CI uses it to catch perf-path regressions
(import errors, recompile storms) rather than to produce numbers.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

DEFAULT_SCALES = (8, 16, 32)

# the 'before' baselines must really run without a persistent
# compilation cache, even when the surrounding shell (e.g. CI) exports
# one — PR-1 recompiled every process
_JAX_CACHE_VARS = ("JAX_COMPILATION_CACHE_DIR",
                   "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS")


# ----------------------------------------------------- child measurement

def _timed_sweep(scales, batched: bool, bucketing: bool) -> dict:
    """One fig14 sweep in-process; wall/solve/python split + shapes."""
    from benchmarks import fig14_scale
    from repro.core import flowsim_jax

    prev = flowsim_jax.JaxFlowSim.bucketing
    flowsim_jax.JaxFlowSim.bucketing = bucketing
    flowsim_jax.reset_solve_stats()
    rows: list = []
    t0 = time.perf_counter()
    try:
        fig14_scale.run(rows, engine="flow", scales=scales,
                        batched=batched)
    finally:
        flowsim_jax.JaxFlowSim.bucketing = prev
    wall = time.perf_counter() - t0
    stats = dict(flowsim_jax.SOLVE_STATS)
    return {
        "wall_s": round(wall, 4),
        "solve_s": round(stats["solve_s"], 4),
        "python_s": round(wall - stats["solve_s"], 4),
        "solve_calls": stats["calls"],
        "solve_shapes": [list(s) for s in stats["shapes"]],
        "rows": [[n, round(v, 4)] for n, v, _ in rows],
    }


def _child_main(kind: str, scales) -> int:
    """Two passes: pass1 pays compilation, pass2 hits the jit cache."""
    if kind == "serial":
        # PR-1 discipline also rebuilt the topology on every scenario
        # call (no lru_cache); bypass the cache to reproduce that
        from benchmarks import fig14_scale
        fig14_scale._build = fig14_scale._build.__wrapped__
    batched = kind == "batched"
    p1 = _timed_sweep(scales, batched, bucketing=batched)
    p2 = _timed_sweep(scales, batched, bucketing=batched)
    print(json.dumps({
        "pass1": p1,
        "pass2": p2,
        "compile_est_s": round(max(p1["wall_s"] - p2["wall_s"], 0.0), 4),
    }))
    return 0


# ---------------------------------------------------- parent orchestration

def _run_child(kind: str, scales, env_extra: dict) -> dict:
    env = dict(os.environ, **env_extra)
    env = {k: v for k, v in env.items() if v != ""}   # "" = unset
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--_child", kind,
         "--scales", ",".join(str(s) for s in scales)],
        capture_output=True, text=True, env=env, cwd=REPO, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def _run_git_ref(ref: str, scales) -> dict:
    """Time the sweep of the ACTUAL tree at ``ref``, same basis as the
    in-tree measurements (wall around ``fig14_scale.run()``, imports
    excluded) and the same ``scales``."""
    tmp = tempfile.mkdtemp(prefix="bench-ref-")
    driver = (
        "import sys, time\n"
        "sys.path.insert(0, 'src')\n"
        "from benchmarks import fig14_scale\n"
        "rows = []\n"
        "t0 = time.perf_counter()\n"
        f"fig14_scale.run(rows, engine='flow', scales={tuple(scales)!r})\n"
        "print('sweep done in %.4fs' % (time.perf_counter() - t0))\n")
    try:
        tar = subprocess.run(["git", "archive", ref], cwd=REPO,
                             capture_output=True, check=True)
        subprocess.run(["tar", "-x", "-C", tmp], input=tar.stdout,
                       check=True)
        walls = []
        env = dict(os.environ, REPRO_JAX_CACHE="0")
        for k in ("PYTHONPATH", *_JAX_CACHE_VARS):
            env.pop(k, None)
        for _ in range(2):
            out = subprocess.run([sys.executable, "-c", driver],
                                 capture_output=True, text=True,
                                 env=env, cwd=tmp, check=True)
            m = re.search(r"done in ([0-9.]+)s", out.stdout)
            walls.append(float(m.group(1)) if m else -1.0)
        return {"ref": ref, "wall_s": walls}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: one small scale, batched path only")
    ap.add_argument("--scales", default=None,
                    help="comma-separated sweep scales "
                         f"(default {DEFAULT_SCALES})")
    ap.add_argument("--before-git", default=None, metavar="REF",
                    help="also time the actual tree at a git ref "
                         "(ground-truth PR-1 baseline)")
    ap.add_argument("--out", default=os.path.join(REPO,
                                                  "BENCH_flowsim.json"))
    ap.add_argument("--_child", default=None,
                    choices=("batched", "serial"), help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    scales = tuple(int(s) for s in args.scales.split(",")) \
        if args.scales else ((8,) if args.smoke else DEFAULT_SCALES)
    if args._child:
        return _child_main(args._child, scales)

    result = {
        "workload": {"figure": "fig14", "engine": "flow",
                     "scales": list(scales), "smoke": args.smoke},
        "env": {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")},
    }
    t_all = time.perf_counter()
    cache_dir = tempfile.mkdtemp(prefix="bench-jax-cache-")
    try:
        if not args.smoke:
            # before: PR-1 solver discipline, no persistent cache
            no_cache = {"REPRO_JAX_CACHE": "0",
                        **{k: "" for k in _JAX_CACHE_VARS}}
            result["before"] = _run_child("serial", scales, no_cache)
            if args.before_git:
                result["before_git"] = _run_git_ref(args.before_git,
                                                    scales)
        # after, cold: fresh process + empty compilation-cache dir
        cache_env = {"JAX_COMPILATION_CACHE_DIR": cache_dir}
        result["after_cold"] = _run_child("batched", scales, cache_env)
        # after, steady state: fresh process, warm cache dir
        result["after_warm"] = _run_child("batched", scales, cache_env)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    if "before" in result:
        b = result["before"]["pass1"]["wall_s"]
        result["speedup_cold"] = round(
            b / result["after_cold"]["pass1"]["wall_s"], 2)
        result["speedup_steady"] = round(
            b / result["after_warm"]["pass1"]["wall_s"], 2)
    result["bench_wall_s"] = round(time.perf_counter() - t_all, 2)

    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    print(json.dumps(result, indent=2))
    print(f"# wrote {args.out}", file=sys.stderr)

    if args.smoke:       # regression tripwires for CI
        cold, warm = result["after_cold"], result["after_warm"]
        assert cold["pass1"]["solve_calls"] > 0
        assert cold["pass1"]["rows"], "sweep produced no rows"
        same = cold["pass1"]["solve_shapes"] == \
            warm["pass1"]["solve_shapes"]
        assert same, "bucketed shapes changed between processes"
    return 0


if __name__ == "__main__":
    sys.exit(main())
