#!/usr/bin/env python3
"""Engine performance tracking — writes BENCH_flowsim.json /
BENCH_packetsim.json.

``--engine flow`` (default) times the fixed fig14 workload (HPL scales
8/16/32 on the flow engine, 1024-host fat-tree) through two solver
paths, each in its OWN subprocess so neither warms the other's
topology/routing/jit caches:

- **before** — the PR-1 solver discipline: one engine + one solve per
  scenario, shape bucketing off, fresh topology per scenario, no
  persistent compilation cache (PR-1 recompiled every process);
- **after**  — the stage-then-batch path: the whole sweep staged on one
  engine, solved by a single ``run_many`` (shape-bucketed, vmapped
  epoch batches), persistent compilation cache on.  Measured twice:
  a cold process with an empty cache directory, then a second fresh
  process against the now-warm directory (the steady state every run
  after the first sees).

It also records a **dyn-segments** point (the ISSUE-10 churn-under-
loss sweep — 64 dynamic ops cut into 320 piecewise segments on a
1024-host fat-tree — solved by the batched device-resident segment
solver vs the legacy per-segment ``static_maxmin`` closures, with a
zero-loss <= 1e-6 JCT-match tripwire between the two modes), a
**loss-sweep** point (the fig15 flow sweep through
the loss-aware solver path, so a perf regression in ``loss_factors``
shows up next to the fig14 numbers), an **apps-sweep** point (the
fig_apps train-step/serving lowering through the phase-split execution
path, with a gleam-no-slower-than-multiunicast tripwire), and the
**fleet-scale** headline (a 16k-host fat-tree carrying 1k multicast
groups plus background traffic, staged and solved twice over the same
fabric — pass 2 is the staging-cache steady state every sweep pass
after the first sees).

``--engine packet`` times the packet engine's hot path on fig15 loss
points (the fidelity regime only it can simulate):

- **single** — one (group, loss) gleam bcast point, wall around
  ``run()`` (staging/registration excluded — the same basis at every
  ref), two fresh engines per child process;
- **sweep**  — the multi-seed fig15 batch (both sweep points x
  ``seeds`` repetitions) through ``run_many``, serial (workers=1) vs
  scenario-parallel (one worker process per CPU).  The serial and
  parallel record streams are asserted IDENTICAL — the bench doubles
  as a determinism tripwire.  The json records the ``cpu_count`` the
  comparison ran with; on a single-CPU box the parallel leg is skipped
  with a note instead of reporting a meaningless 1-worker "speedup";
- **before_git** — the same single points (and the per-point serial
  basis for the sweep estimate: the old engine had no multi-seed
  batching, so its sweep cost is seeds x the measured single-point
  wall) at the actual tree of ``--before-git REF``.

Every measurement excludes imports, and the ``env`` block records git
sha, interpreter/library versions and platform so numbers are
attributable.

    PYTHONPATH=src python tools/bench.py                     # flow, full
    PYTHONPATH=src python tools/bench.py --before-git HEAD~1 # + git ref
    PYTHONPATH=src python tools/bench.py --smoke             # CI-sized
    PYTHONPATH=src python tools/bench.py --engine packet --before-git REF
    PYTHONPATH=src python tools/bench.py --engine packet --smoke

``--smoke`` shrinks the workload and still writes the json — CI uses it
to catch perf-path regressions (import errors, recompile storms, a
broken parallel path) rather than to produce numbers.

``BENCH_*.json`` writes are refused from a dirty work tree (the json
records a ``git_sha`` the dirty diff would silently invalidate) unless
``--allow-dirty`` is passed.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import re
import shutil
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

DEFAULT_SCALES = (8, 16, 32)

# the 'before' baselines must really run without a persistent
# compilation cache, even when the surrounding shell (e.g. CI) exports
# one — PR-1 recompiled every process
_JAX_CACHE_VARS = ("JAX_COMPILATION_CACHE_DIR",
                   "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS")

# packet bench workloads: fig15 points (group, loss).  The 512-host
# point is the headline (feedback aggregation scales with group size);
# the sweep uses the cheaper 64-host points at seeds repetitions.
PACKET_SINGLE_POINTS = ((512, 1e-4), (64, 1e-3))
PACKET_SWEEP_POINTS = ((64, 1e-4), (64, 1e-3))
PACKET_SWEEP_SEEDS = 6
PACKET_SMOKE_POINT = (16, 1e-3)
PACKET_SMOKE_SEEDS = 2


def _env_info() -> dict:
    """Provenance block shared by both bench outputs."""
    def _git(*args):
        try:
            return subprocess.run(
                ["git", *args], cwd=REPO, capture_output=True, text=True,
                check=True).stdout.strip()
        except Exception:
            return None

    info = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_sha": _git("rev-parse", "HEAD"),
        "git_dirty": bool(_git("status", "--porcelain")),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import numpy
        info["numpy"] = numpy.__version__
    except Exception:
        info["numpy"] = None
    try:
        import jax
        info["jax"] = jax.__version__
    except Exception:
        info["jax"] = None
    return info


# ------------------------------------------------ flow child measurement

def _timed_sweep(scales, batched: bool, bucketing: bool) -> dict:
    """One fig14 sweep in-process; wall/solve/python split + shapes."""
    from benchmarks import fig14_scale
    from repro.core import flowsim_jax

    prev = flowsim_jax.JaxFlowSim.bucketing
    flowsim_jax.JaxFlowSim.bucketing = bucketing
    flowsim_jax.reset_solve_stats()
    rows: list = []
    t0 = time.perf_counter()
    try:
        fig14_scale.run(rows, engine="flow", scales=scales,
                        batched=batched)
    finally:
        flowsim_jax.JaxFlowSim.bucketing = prev
    wall = time.perf_counter() - t0
    stats = dict(flowsim_jax.SOLVE_STATS)
    return {
        "wall_s": round(wall, 4),
        "solve_s": round(stats["solve_s"], 4),
        "python_s": round(wall - stats["solve_s"], 4),
        "solve_calls": stats["calls"],
        "solve_shapes": [list(s) for s in stats["shapes"]],
        "rows": [[n, round(v, 4)] for n, v, _ in rows],
    }


def _child_flow(kind: str, scales) -> int:
    """Two passes: pass1 pays compilation, pass2 hits the jit cache."""
    if kind == "serial":
        # PR-1 discipline also rebuilt the topology on every scenario
        # call (no lru_cache); bypass the cache to reproduce that
        from benchmarks import fig14_scale
        fig14_scale._build = fig14_scale._build.__wrapped__
    batched = kind == "batched"
    p1 = _timed_sweep(scales, batched, bucketing=batched)
    p2 = _timed_sweep(scales, batched, bucketing=batched)
    print(json.dumps({
        "pass1": p1,
        "pass2": p2,
        "compile_est_s": round(max(p1["wall_s"] - p2["wall_s"], 0.0), 4),
    }))
    return 0


def _flow_apps_sweep(smoke: bool) -> dict:
    """Flow-engine fig_apps point — the application traffic plane's
    lowering + phase-split execution path (ISSUE 8).  Full mode runs
    the train-step sweep (every transport) for both fig_apps configs
    plus one open-loop serving point; smoke runs one config's gleam /
    multiunicast train steps."""
    from benchmarks import fig_apps
    from repro.apps.metrics import run_phased, step_time
    from repro.apps.traffic import ArrivalSpec, ServingGenerator
    from repro.configs.base import get_config
    from repro.core import fattree
    from repro.core.engine import make_engine

    configs = fig_apps.CONFIGS[:1] if smoke else fig_apps.CONFIGS
    transports = ("gleam", "multiunicast") if smoke \
        else fig_apps.TRANSPORTS
    rows: list = []
    t0 = time.perf_counter()
    for name in configs:
        cfg = get_config(name, smoke=True)
        from repro.apps.collectives_lowering import train_step_workload
        for tr in transports:
            eng = make_engine("flow", fattree.testbed(
                n_hosts=fig_apps.TRAIN_MESH.n_chips))
            wl = train_step_workload(
                cfg, fig_apps.TRAIN_MESH, seq=fig_apps.TRAIN_SEQ,
                batch=fig_apps.TRAIN_BATCH, transport=tr)
            st = step_time(*run_phased(eng, wl, timeout=120.0))
            rows.append((f"figapps/train_{name}_{tr}/flow_ms", st * 1e3))
    if not smoke:
        cfg = get_config(configs[0], smoke=True)
        gen = ServingGenerator(
            cfg, fig_apps.N_REPLICAS, fig_apps.TP,
            prompt_len=fig_apps.PROMPT_LEN,
            decode_len=fig_apps.DECODE_LEN,
            kv_replicas=fig_apps.KV_REPLICAS)
        eng = make_engine("flow", fattree.testbed(
            n_hosts=fig_apps.N_REPLICAS * fig_apps.TP))
        rep = gen.run(eng, ArrivalSpec(rate=fig_apps.SERVE_RATE,
                                       n=fig_apps.SERVE_N, seed=0),
                      timeout=120.0)
        rows.append((f"figapps/serve_{configs[0]}_gleam/flow_qps",
                     rep.achieved_qps))
        rows.append((f"figapps/serve_{configs[0]}_gleam/flow_p99_us",
                     rep.quantiles["p99"] * 1e6))
    return {
        "wall_s": round(time.perf_counter() - t0, 4),
        "rows": [[n, round(v, 4)] for n, v in rows],
    }


def _flow_fleet_point(smoke: bool) -> dict:
    """The fleet-scale headline: one contended multi-tenant scenario
    (1k multicast groups + background traffic on a 16k-host fat-tree;
    CI-sized in smoke) staged and solved twice on fresh engines over
    the SAME fabric.  Pass 2 is the sweep steady state: every derived
    artifact (paths, trees, latencies, per-op layouts) replays from the
    staging cache, which is what makes this point feasible at all."""
    from repro.apps.fleet import FleetSpec, fleet_workload
    from repro.core import fattree, flowsim_jax
    from repro.core.engine import make_engine

    if smoke:
        topo = fattree.fat_tree(n_pods=8, leaves_per_pod=8,
                                hosts_per_leaf=16, aggs_per_pod=8,
                                bw=200 * fattree.GBPS)      # 1024 hosts
        spec = FleetSpec(n_tenants=4, groups_per_tenant=16, group_size=8,
                         nbytes=1 << 20, bg_unicasts=16, bg_incasts=4,
                         bg_fan_in=8, bg_nbytes=1 << 20, seed=0)
    else:
        topo = fattree.fat_tree(n_pods=32, leaves_per_pod=16,
                                hosts_per_leaf=32, aggs_per_pod=16,
                                bw=200 * fattree.GBPS)      # 16384 hosts
        spec = FleetSpec(n_tenants=10, groups_per_tenant=100,
                         group_size=8, nbytes=1 << 20, bg_unicasts=64,
                         bg_incasts=8, bg_fan_in=8, bg_nbytes=1 << 20,
                         seed=0)
    wl = fleet_workload(topo.hosts, spec)
    passes = []
    for _ in range(2):
        flowsim_jax.reset_solve_stats()
        eng = make_engine("flow", topo)
        t0 = time.perf_counter()
        recs = eng.run_workloads([wl], timeout=600.0)[0]
        wall = time.perf_counter() - t0
        stats = dict(flowsim_jax.SOLVE_STATS)
        passes.append({
            "wall_s": round(wall, 4),
            "solve_s": round(stats["solve_s"], 4),
            "python_s": round(wall - stats["solve_s"], 4),
            "errors": sum(1 for r in recs if r.error),
            "hit_rate": round(eng.staging_stats()["hit_rate"], 4),
        })
    return {
        "hosts": len(topo.hosts),
        "groups": spec.n_tenants * spec.groups_per_tenant,
        "ops": len(wl.ops),
        "pass1": passes[0],
        "pass2": passes[1],
        "warm_speedup": round(passes[0]["wall_s"]
                              / max(passes[1]["wall_s"], 1e-9), 2),
    }


def _flow_loss_sweep(smoke: bool) -> dict:
    """Flow-engine fig15 loss sweep — the regime the loss/DCQCN
    correction added to the solver hot path.  Full mode runs both
    sweep sections (calibration grid + 4096-host fat-tree scale grid);
    smoke runs one lossy calibration point."""
    from benchmarks import fig15_16_loss
    from repro.core import flowsim_jax

    flowsim_jax.reset_solve_stats()
    rows: list = []
    t0 = time.perf_counter()
    if smoke:
        jct = fig15_16_loss.flow_jct(8, 1e-3, "gleam")
        rows.append(("fig15/diff_g8_loss1e-03/gleam_us", jct * 1e6, ""))
    else:
        fig15_16_loss.run(rows, engine="flow")
    wall = time.perf_counter() - t0
    stats = dict(flowsim_jax.SOLVE_STATS)
    return {
        "wall_s": round(wall, 4),
        "solve_s": round(stats["solve_s"], 4),
        "solve_calls": stats["calls"],
        "rows": [[n, round(v, 4)] for n, v, _ in rows],
    }


def _flow_dyn_segments(smoke: bool, mode: str) -> dict:
    """The dyn_segments point: a churn-under-loss sweep (ISSUE 10) with
    the segment solver pinned to ``mode`` — ``legacy`` is the honest
    "before" leg (per-segment ``static_maxmin_loops`` closures inside
    the staging path), ``batched`` the device-resident timeline solver.

    Two timed passes per mode: pass 1 is cold (jit compile for the
    batched mode), pass 2 the sweep steady state (same process; the
    batched mode additionally replays memoized segment rates from the
    shared staging cache, exactly what later sweep passes see).  The
    zero-loss leg reports full-precision JCTs — the parent asserts the
    two modes agree there, where they solve the SAME per-segment
    problems."""
    from benchmarks import fig_matrix
    from repro.core import fattree
    from repro.core.engine import make_engine

    if smoke:
        topo = fattree.fat_tree(n_pods=2, leaves_per_pod=2,
                                hosts_per_leaf=8, aggs_per_pod=2)
        n_groups = 2                               # 32 hosts
    else:
        topo = fattree.fat_tree(n_pods=8, leaves_per_pod=8,
                                hosts_per_leaf=16, aggs_per_pod=8)
        n_groups = 64                              # 1024 hosts
    ops = fig_matrix.cell_ops(topo.hosts, n_groups, 12, 5e4, 0,
                              nbytes=1 << 20)
    out = {"mode": mode, "ops": len(ops)}

    def timed(loss):
        kw = {"loss_rate": loss} if loss else {}
        eng = make_engine("flow", topo, segment_solver=mode, **kw)
        recs = [eng.stage(op) for op in ops]
        segs = sum(len(tl) for tl in eng._dyn_links.values())
        t0 = time.perf_counter()
        eng.run(timeout=120.0)
        return segs, round(time.perf_counter() - t0, 4), recs

    out["segments"], out["pass1_wall_s"], _ = timed(1e-3)
    _, out["pass2_wall_s"], _ = timed(1e-3)
    out["segments_per_s"] = round(
        out["segments"] / max(out["pass2_wall_s"], 1e-9), 1)
    _, _, recs0 = timed(0.0)
    out["jcts0"] = [r.t_sender_cqe for r in recs0]
    return out


# ---------------------------------------------- packet child measurement

def _packet_single(group: int, loss: float) -> dict:
    """Wall around ``run()`` of one staged fig15 gleam point — the same
    basis as the git-ref driver below."""
    from benchmarks.fig15_16_loss import _point
    eng, rec = _point(group, loss, "gleam")
    t0 = time.perf_counter()
    eng.run(timeout=240.0)
    wall = time.perf_counter() - t0
    sim = eng.net.sim
    return {"group": group, "loss": loss, "wall_s": round(wall, 4),
            "jct_ms": rec.jct(group - 1) * 1e3,     # full precision:
            "events": sim.events, "dropped": sim.dropped}  # ref-compared


def _packet_sweep(points, seeds: int, workers) -> dict:
    """The multi-seed fig15 batch through run_many; returns per-point
    mean/std and the raw per-seed JCTs — the serial==parallel assertion
    compares those record for record, so a scenario-index permutation
    in the parallel scheduler cannot hide behind identical aggregates."""
    from benchmarks.fig15_16_loss import _sweep_point
    out = {"points": [], "jcts": [], "wall_s": 0.0}
    t0 = time.perf_counter()
    for group, loss in points:
        mean, std, jcts = _sweep_point(group, loss, "gleam", seeds,
                                       workers, 240.0)
        out["points"].append({"group": group, "loss": loss,
                              "mean_ms": round(mean * 1e3, 6),
                              "std_ms": round(std * 1e3, 6),
                              "seeds": seeds})
        out["jcts"].append(jcts)
    out["wall_s"] = round(time.perf_counter() - t0, 4)
    return out


def _packet_faults(group: int) -> dict:
    """Fault-sweep point: the fig_faults recovery axis (one scenario per
    fault class, fresh fabric each — see benchmarks/fig_faults.py) on
    the packet engine; wall time plus measured recovery per class."""
    from benchmarks.fig_faults import _sweep, members_for, recovery_cases
    t0 = time.perf_counter()
    jct = _sweep("packet", group)
    wall = time.perf_counter() - t0
    base = jct["r0"][0]
    return {"group": group, "wall_s": round(wall, 4),
            "jct_ms": base * 1e3,
            "recovery_us": {
                label: round((jct[label][0] - base) * 1e6, 3)
                for label, _ in recovery_cases(members_for(group))}}


def _child_packet(kind: str, spec: dict) -> int:
    if kind == "packet-single":
        res = {"passes": [_packet_single(spec["group"], spec["loss"])
                          for _ in range(2)]}
    elif kind == "packet-sweep":
        res = _packet_sweep([tuple(p) for p in spec["points"]],
                            spec["seeds"], spec["workers"])
    elif kind == "packet-faults":
        res = _packet_faults(spec["group"])
    else:
        raise ValueError(kind)
    print(json.dumps(res))
    return 0


# ---------------------------------------------------- parent orchestration

def _run_child(kind: str, env_extra: dict, *, scales=None,
               spec: dict = None) -> dict:
    env = dict(os.environ, **env_extra)
    env = {k: v for k, v in env.items() if v != ""}   # "" = unset
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    argv = [sys.executable, os.path.abspath(__file__), "--_child", kind]
    if scales is not None:
        argv += ["--scales", ",".join(str(s) for s in scales)]
    if spec is not None:
        argv += ["--_spec", json.dumps(spec)]
    out = subprocess.run(argv, capture_output=True, text=True, env=env,
                         cwd=REPO, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def _git_ref_tree(ref: str) -> str:
    tmp = tempfile.mkdtemp(prefix="bench-ref-")
    tar = subprocess.run(["git", "archive", ref], cwd=REPO,
                         capture_output=True, check=True)
    subprocess.run(["tar", "-x", "-C", tmp], input=tar.stdout, check=True)
    return tmp


def _run_git_ref_flow(ref: str, scales) -> dict:
    """Time the fig14 sweep of the ACTUAL tree at ``ref``, same basis as
    the in-tree measurements (wall around ``fig14_scale.run()``, imports
    excluded) and the same ``scales``."""
    tmp = _git_ref_tree(ref)
    driver = (
        "import sys, time\n"
        "sys.path.insert(0, 'src')\n"
        "from benchmarks import fig14_scale\n"
        "rows = []\n"
        "t0 = time.perf_counter()\n"
        f"fig14_scale.run(rows, engine='flow', scales={tuple(scales)!r})\n"
        "print('sweep done in %.4fs' % (time.perf_counter() - t0))\n")
    try:
        walls = []
        env = dict(os.environ, REPRO_JAX_CACHE="0")
        for k in ("PYTHONPATH", *_JAX_CACHE_VARS):
            env.pop(k, None)
        for _ in range(2):
            out = subprocess.run([sys.executable, "-c", driver],
                                 capture_output=True, text=True,
                                 env=env, cwd=tmp, check=True)
            m = re.search(r"done in ([0-9.]+)s", out.stdout)
            walls.append(float(m.group(1)) if m else -1.0)
        return {"ref": ref, "wall_s": walls}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _run_git_ref_packet(ref: str, points) -> dict:
    """Time fig15 single points at the actual tree of ``ref`` — the
    ``_point``+``run()`` basis (both trees carry that helper)."""
    tmp = _git_ref_tree(ref)
    results = []
    try:
        env = dict(os.environ)
        env.pop("PYTHONPATH", None)
        for group, loss in points:
            driver = (
                "import sys, time\n"
                "sys.path.insert(0, 'src'); sys.path.insert(0, '.')\n"
                "from benchmarks.fig15_16_loss import _point\n"
                f"eng, rec = _point({group}, {loss!r}, 'gleam')\n"
                "t0 = time.perf_counter()\n"
                "eng.run(timeout=240.0)\n"
                "print('point done in %.4fs jct %.9g'\n"
                f"      % (time.perf_counter() - t0, rec.jct({group}-1)))\n")
            out = subprocess.run([sys.executable, "-c", driver],
                                 capture_output=True, text=True,
                                 env=env, cwd=tmp, check=True)
            m = re.search(r"done in ([0-9.]+)s jct ([0-9.e+-]+)",
                          out.stdout)
            results.append({"group": group, "loss": loss,
                            "wall_s": float(m.group(1)) if m else -1.0,
                            "jct_ms": float(m.group(2)) * 1e3
                            if m else -1.0})
        return {"ref": ref, "points": results}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


# ------------------------------------------------------------ engines

def _main_flow(args, result: dict) -> None:
    scales = tuple(int(s) for s in args.scales.split(",")) \
        if args.scales else ((8,) if args.smoke else DEFAULT_SCALES)
    result["workload"] = {"figure": "fig14", "engine": "flow",
                          "scales": list(scales), "smoke": args.smoke}
    cache_dir = tempfile.mkdtemp(prefix="bench-jax-cache-")
    try:
        if not args.smoke:
            # before: PR-1 solver discipline, no persistent cache
            no_cache = {"REPRO_JAX_CACHE": "0",
                        **{k: "" for k in _JAX_CACHE_VARS}}
            result["before"] = _run_child("serial", no_cache,
                                          scales=scales)
            if args.before_git:
                result["before_git"] = _run_git_ref_flow(args.before_git,
                                                         scales)
        # after, cold: fresh process + empty compilation-cache dir
        cache_env = {"JAX_COMPILATION_CACHE_DIR": cache_dir}
        result["after_cold"] = _run_child("batched", cache_env,
                                          scales=scales)
        # after, steady state: fresh process, warm cache dir
        result["after_warm"] = _run_child("batched", cache_env,
                                          scales=scales)
        # loss-sweep point: fig15 on the flow engine (loss-aware solver)
        result["loss_sweep"] = _run_child("flow-loss", cache_env,
                                          spec={"smoke": args.smoke})
        # app-plane point: fig_apps lowering + phase-split execution
        result["apps_sweep"] = _run_child("flow-apps", cache_env,
                                          spec={"smoke": args.smoke})
        # fleet-scale headline: 16k hosts x 1k groups, cold vs warm
        # staging cache (CI-sized in smoke)
        result["fleet_scale"] = _run_child("flow-fleet", cache_env,
                                           spec={"smoke": args.smoke})
        # dyn-segments point: churn-under-loss piecewise segments,
        # batched device solver vs the legacy per-segment closures
        dyn = {mode: _run_child("flow-dyn", cache_env,
                                spec={"smoke": args.smoke, "mode": mode})
               for mode in ("legacy", "batched")}
        dyn["speedup_cold"] = round(dyn["legacy"]["pass1_wall_s"]
                                    / dyn["batched"]["pass1_wall_s"], 2)
        dyn["speedup_steady"] = round(dyn["legacy"]["pass2_wall_s"]
                                      / dyn["batched"]["pass2_wall_s"], 2)
        # zero-loss JCT-match tripwire: both modes solve the same
        # per-segment problems there, so they must agree to 1e-6
        rel = max((abs(a - b) / abs(b) for a, b in
                   zip(dyn["legacy"]["jcts0"], dyn["batched"]["jcts0"])),
                  default=0.0)
        dyn["jct0_max_rel_diff"] = rel
        assert rel <= 1e-6, \
            f"dyn_segments modes diverge on zero-loss JCTs: {rel:g}"
        result["dyn_segments"] = dyn
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    if "before" in result:
        b = result["before"]["pass1"]["wall_s"]
        result["speedup_cold"] = round(
            b / result["after_cold"]["pass1"]["wall_s"], 2)
        result["speedup_steady"] = round(
            b / result["after_warm"]["pass1"]["wall_s"], 2)

    if args.smoke:       # regression tripwires for CI
        cold, warm = result["after_cold"], result["after_warm"]
        assert cold["pass1"]["solve_calls"] > 0
        assert cold["pass1"]["rows"], "sweep produced no rows"
        same = cold["pass1"]["solve_shapes"] == \
            warm["pass1"]["solve_shapes"]
        assert same, "bucketed shapes changed between processes"
        loss = result["loss_sweep"]
        assert loss["solve_calls"] > 0
        assert loss["rows"] and all(v > 0 for _, v in loss["rows"]), \
            "loss sweep produced no positive JCTs"
        apps = result["apps_sweep"]
        assert apps["rows"] and all(v > 0 for _, v in apps["rows"]), \
            "apps sweep produced no positive step times"
        dyn = result["dyn_segments"]
        assert dyn["batched"]["segments"] > 0, \
            "dyn_segments staged no piecewise segments"
        assert dyn["batched"]["segments"] == dyn["legacy"]["segments"]
        fleet = result["fleet_scale"]
        assert fleet["pass1"]["errors"] == fleet["pass2"]["errors"] == 0
        assert fleet["pass2"]["hit_rate"] > 0, \
            "fleet warm pass saw no staging-cache hits"
        by = dict(apps["rows"])
        gleam = [v for n, v in by.items() if n.endswith("gleam/flow_ms")]
        multi = [v for n, v in by.items()
                 if n.endswith("multiunicast/flow_ms")]
        assert gleam and multi and gleam[0] <= multi[0], \
            "gleam train step slower than multiunicast"


def _main_packet(args, result: dict) -> None:
    if args.smoke:
        points = [PACKET_SMOKE_POINT]
        sweep_points, seeds = [PACKET_SMOKE_POINT], PACKET_SMOKE_SEEDS
    else:
        points = [list(p) for p in PACKET_SINGLE_POINTS]
        sweep_points = [list(p) for p in PACKET_SWEEP_POINTS]
        seeds = PACKET_SWEEP_SEEDS
    result["workload"] = {
        "figure": "fig15", "engine": "packet", "smoke": args.smoke,
        "single_points": [list(p) for p in points],
        "sweep": {"points": [list(p) for p in sweep_points],
                  "seeds": seeds}}

    result["single"] = [
        _run_child("packet-single", {},
                   spec={"group": g, "loss": l})
        for g, l in points]
    result["sweep_serial"] = _run_child(
        "packet-sweep", {},
        spec={"points": sweep_points, "seeds": seeds, "workers": 1})
    # the parallel-vs-serial comparison is only meaningful with real
    # parallelism; record the cpu count it ran with either way so the
    # speedup number is attributable to the box
    ncpu = os.cpu_count() or 1
    result["sweep_cpu_count"] = ncpu
    if ncpu == 1:
        result["sweep_parallel"] = None
        result["sweep_note"] = (
            "cpu_count == 1: parallel-vs-serial comparison skipped "
            "(a one-worker pool would re-measure the serial path)")
    else:
        result["sweep_parallel"] = _run_child(
            "packet-sweep", {},
            spec={"points": sweep_points, "seeds": seeds,
                  "workers": ncpu})
        # determinism tripwire: the serial and parallel sweeps must
        # agree exactly, record for record
        assert result["sweep_serial"]["jcts"] == \
            result["sweep_parallel"]["jcts"], \
            "serial and parallel run_many diverged"
        result["speedup_parallel_vs_serial"] = round(
            result["sweep_serial"]["wall_s"]
            / result["sweep_parallel"]["wall_s"], 2)

    if args.before_git and not args.smoke:
        result["before_git"] = _run_git_ref_packet(
            args.before_git, [tuple(p) for p in points])
        before_sweep = _run_git_ref_packet(
            args.before_git, [tuple(p) for p in sweep_points])
        # the old engine ran scenarios serially at one seed; its
        # multi-seed sweep cost is seeds x the measured per-point wall
        est = sum(p["wall_s"] for p in before_sweep["points"]) * seeds
        result["before_git"]["sweep_points"] = before_sweep["points"]
        result["before_git"]["sweep_est_s"] = round(est, 4)
        # headline gates
        b0 = result["before_git"]["points"][0]
        a0 = result["single"][0]["passes"]
        result["speedup_single"] = round(
            b0["wall_s"] / min(p["wall_s"] for p in a0), 2)
        best_sweep = result["sweep_parallel"] or result["sweep_serial"]
        result["sweep_reduction_vs_before"] = round(
            est / best_sweep["wall_s"], 2)
        # fixed-seed results must be unchanged, ref vs tree
        for b, s in zip(result["before_git"]["points"],
                        result["single"]):
            assert abs(b["jct_ms"] - s["passes"][0]["jct_ms"]) \
                <= 1e-9 + 1e-6 * abs(b["jct_ms"]), \
                f"fixed-seed JCT changed vs {args.before_git}: {b} {s}"

    # fault-sweep point: the ISSUE-7 recovery axis (benchmarks/
    # fig_faults.py) — every fault class must end in measured recovery
    result["fault_sweep"] = _run_child(
        "packet-faults", {}, spec={"group": 4 if args.smoke else 8})

    if args.smoke:       # regression tripwires for CI
        assert result["single"][0]["passes"][0]["events"] > 0
        sweep = result["sweep_parallel"] or result["sweep_serial"]
        assert all(p["mean_ms"] > 0 for p in sweep["points"])
        assert all(v > 0
                   for v in result["fault_sweep"]["recovery_us"].values())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--engine", choices=("flow", "packet"),
                    default="flow",
                    help="which engine's hot path to benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny workload, regression tripwires")
    ap.add_argument("--scales", default=None,
                    help="comma-separated fig14 sweep scales, flow only "
                         f"(default {DEFAULT_SCALES})")
    ap.add_argument("--before-git", default=None, metavar="REF",
                    help="also time the actual tree at a git ref "
                         "(ground-truth baseline)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--allow-dirty", action="store_true",
                    help="permit writing BENCH_*.json from a dirty "
                         "work tree (the json records git_sha for "
                         "provenance; a dirty tree makes it a lie)")
    ap.add_argument("--_child", default=None,
                    choices=("batched", "serial", "flow-loss",
                             "flow-apps", "flow-fleet", "flow-dyn",
                             "packet-single", "packet-sweep",
                             "packet-faults"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--_spec", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args._child in ("batched", "serial"):
        scales = tuple(int(s) for s in args.scales.split(",")) \
            if args.scales else DEFAULT_SCALES
        return _child_flow(args._child, scales)
    if args._child == "flow-loss":
        print(json.dumps(_flow_loss_sweep(
            json.loads(args._spec)["smoke"])))
        return 0
    if args._child == "flow-apps":
        print(json.dumps(_flow_apps_sweep(
            json.loads(args._spec)["smoke"])))
        return 0
    if args._child == "flow-fleet":
        print(json.dumps(_flow_fleet_point(
            json.loads(args._spec)["smoke"])))
        return 0
    if args._child == "flow-dyn":
        spec = json.loads(args._spec)
        print(json.dumps(_flow_dyn_segments(spec["smoke"],
                                            spec["mode"])))
        return 0
    if args._child:
        return _child_packet(args._child, json.loads(args._spec))

    out_path = args.out or os.path.join(
        REPO, "BENCH_flowsim.json" if args.engine == "flow"
        else "BENCH_packetsim.json")
    result = {"env": _env_info()}
    if (os.path.basename(out_path).startswith("BENCH_")
            and result["env"]["git_dirty"] and not args.allow_dirty):
        print("bench: refusing to write "
              f"{os.path.basename(out_path)} from a dirty work tree — "
              "the json's git_sha would not describe the measured code. "
              "Commit (or stash) first, or pass --allow-dirty.",
              file=sys.stderr)
        return 2
    t_all = time.perf_counter()
    if args.engine == "flow":
        _main_flow(args, result)
    else:
        _main_packet(args, result)
    result["bench_wall_s"] = round(time.perf_counter() - t_all, 2)

    with open(out_path, "w", encoding="utf-8") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    print(json.dumps(result, indent=2))
    print(f"# wrote {out_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
