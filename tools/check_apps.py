#!/usr/bin/env python3
"""Application-traffic-plane smoke gate (wired into CI).

Three invariants from ISSUE 8:

1. **lowering math** — the analytic ``param_count`` mirror equals
   ``count_params(model_defs(cfg))`` exactly for every smoke arch the
   gate drives (the collective sizes all derive from it);
2. **train-step parity** — a small phase-split training step completes
   on BOTH engines for gleam and the multiunicast baseline, with
   step-time divergence <= 10%, and gleam no slower than multiunicast;
3. **serving tails** — the open-loop generator produces a full report
   (achieved <= offered load, monotone p50 <= p99 <= p999 quantiles)
   with packet-vs-flow achieved-QPS divergence <= 10%.

Exit code 0 = clean; 1 = divergence (details on stderr).

    PYTHONPATH=src python tools/check_apps.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.collectives_lowering import (MeshShape, param_count,
                                             train_step_workload)  # noqa: E402
from repro.apps.metrics import run_phased, step_time      # noqa: E402
from repro.apps.traffic import (ArrivalSpec,
                                ServingGenerator)         # noqa: E402
from repro.configs.base import get_config                 # noqa: E402
from repro.core import fattree                            # noqa: E402
from repro.core.engine import make_engine                 # noqa: E402

TOL = 0.10
ARCHS = ("llama3_2_3b", "mixtral_8x7b")
MESH = MeshShape(data=2, model=2)
SEQ, BATCH = 64, 8


def check_param_math(problems):
    from repro.models.blocks import count_params
    from repro.models.model import model_defs
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        analytic, real = param_count(cfg), count_params(model_defs(cfg))
        if analytic != real:
            problems.append(f"{arch}: param_count {analytic} != "
                            f"model_defs {real}")
        else:
            print(f"check_apps: {arch:15s} param_count == model_defs "
                  f"({real / 1e3:.1f}K smoke params)")


def _step(engine_name, cfg, transport):
    eng = make_engine(engine_name, fattree.testbed(n_hosts=MESH.n_chips))
    wl = train_step_workload(cfg, MESH, seq=SEQ, batch=BATCH,
                             transport=transport)
    return step_time(*run_phased(eng, wl, timeout=60.0))


def check_train_parity(problems):
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        steps = {}
        for tr in ("gleam", "multiunicast"):
            p = _step("packet", cfg, tr)
            f = _step("flow", cfg, tr)
            div = abs(p - f) / p
            steps[tr] = p
            print(f"check_apps: {arch:15s} train/{tr:13s} packet="
                  f"{p * 1e6:8.2f}us flow={f * 1e6:8.2f}us "
                  f"div={100 * div:.1f}%")
            if div > TOL:
                problems.append(
                    f"{arch} train/{tr}: packet-vs-flow step-time "
                    f"divergence {100 * div:.1f}% > {100 * TOL:.0f}%")
        if steps["gleam"] > steps["multiunicast"]:
            problems.append(
                f"{arch}: gleam step {steps['gleam'] * 1e6:.2f}us slower "
                f"than multiunicast {steps['multiunicast'] * 1e6:.2f}us")


def check_serving(problems):
    cfg = get_config("llama3_2_3b", smoke=True)
    gen = ServingGenerator(cfg, n_replicas=4, tp=2, prompt_len=64,
                           decode_len=16, kv_replicas=2)
    spec = ArrivalSpec(rate=2e4, n=24, seed=0)
    reps = {}
    for engine in ("packet", "flow"):
        eng = make_engine(engine, fattree.testbed(n_hosts=8))
        rep = gen.run(eng, spec, timeout=60.0)
        reps[engine] = rep
        q = rep.quantiles
        print(f"check_apps: serve/{engine:6s} achieved="
              f"{rep.achieved_qps:8.0f}/{spec.rate:.0f} qps "
              f"p50={q['p50'] * 1e6:.1f}us p99={q['p99'] * 1e6:.1f}us "
              f"p999={q['p999'] * 1e6:.1f}us")
        if rep.n_requests != spec.n:
            problems.append(f"serve/{engine}: {rep.n_requests} of "
                            f"{spec.n} requests reported")
        if not 0 < rep.achieved_qps <= spec.rate * 1.05:
            problems.append(f"serve/{engine}: achieved qps "
                            f"{rep.achieved_qps:.0f} outside "
                            f"(0, offered]")
        if not q["p50"] <= q["p99"] <= q["p999"] <= q["max"]:
            problems.append(f"serve/{engine}: non-monotone quantiles {q}")
    p, f = reps["packet"].achieved_qps, reps["flow"].achieved_qps
    div = abs(p - f) / p
    if div > TOL:
        problems.append(f"serve: packet-vs-flow achieved-QPS divergence "
                        f"{100 * div:.1f}% > {100 * TOL:.0f}%")


def main() -> int:
    problems: list = []
    check_param_math(problems)
    check_train_parity(problems)
    check_serving(problems)
    if problems:
        for p in problems:
            print(f"check_apps: {p}", file=sys.stderr)
        return 1
    print("check_apps: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
