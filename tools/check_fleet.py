#!/usr/bin/env python3
"""Fleet-scale sweep-plane smoke gate (wired into CI).

Four invariants from ISSUE 9:

1. **SLO parity** — the multi-tenant contended scenario completes on
   BOTH engines with per-tenant worst-tail (p99 JCT) divergence <= 10%
   for every tenant phase;
2. **monotone tails** — every phase reports p50 <= p99 <= p999 <= max;
3. **census cross-check** — the flow engine's ANALYTIC connection
   census equals the packet engine's MEASURED per-host QP counts
   exactly, and agrees on aggregate MFT group occupancy;
4. **staged-artifact reuse** — the flow sweep reports a staging-cache
   hit rate > 0 (the cached staging plane is live, not bypassed).

Exit code 0 = clean; 1 = divergence (details on stderr).

    PYTHONPATH=src python tools/check_fleet.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.apps.fleet import FleetSpec, run_fleet   # noqa: E402
from repro.core import fattree                      # noqa: E402

TOL = 0.10
SPEC = FleetSpec(n_tenants=4, groups_per_tenant=2, group_size=6,
                 nbytes=2 << 20, bg_unicasts=8, bg_incasts=2,
                 bg_fan_in=4, bg_nbytes=1 << 20, seed=0)


def fabric():
    return fattree.fat_tree(n_pods=2, leaves_per_pod=4, hosts_per_leaf=4,
                            aggs_per_pod=4, bw=100 * fattree.GBPS)


def main() -> int:
    problems: list = []
    rp = run_fleet("packet", fabric(), SPEC, seed=1)
    rf = run_fleet("flow", fabric(), SPEC)
    for rep in (rp, rf):
        if rep["errors"]:
            problems.append(f"{rep['engine']}: {rep['errors']} errored ops")

    for phase, qf in sorted(rf["tenants"].items()):
        qp_ = rp["tenants"][phase]
        a, b = qf["p99"], qp_["p99"]
        div = abs(a - b) / max(a, b)
        print(f"check_fleet: {phase:10s} p99 packet={b * 1e3:8.4f}ms "
              f"flow={a * 1e3:8.4f}ms div={100 * div:.1f}%")
        if phase.startswith("tenant-") and div > TOL:
            problems.append(f"{phase}: packet-vs-flow p99 divergence "
                            f"{100 * div:.1f}% > {100 * TOL:.0f}%")
        for q in (qf, qp_):
            if not q["p50"] <= q["p99"] <= q["p999"] <= q["latency"]:
                problems.append(f"{phase}: non-monotone quantiles {q}")

    cp, cf = rp["census"], rf["census"]
    print(f"check_fleet: census qp_total={cp['qp_total']} "
          f"nic_qp_peak={cp['nic_qp_peak']} "
          f"mft_groups={cp['mft_groups_total']} "
          f"mft_bytes packet={cp['mft_bytes_total']} "
          f"flow={cf['mft_bytes_total']}")
    if cf["qp_per_host"] != cp["qp_per_host"]:
        diff = {h: (cf["qp_per_host"].get(h), cp["qp_per_host"].get(h))
                for h in set(cf["qp_per_host"]) | set(cp["qp_per_host"])
                if cf["qp_per_host"].get(h) != cp["qp_per_host"].get(h)}
        problems.append(f"census: analytic vs measured QP mismatch {diff}")
    if cf["mft_groups_total"] != cp["mft_groups_total"]:
        problems.append(
            f"census: MFT group occupancy {cf['mft_groups_total']} "
            f"(flow) != {cp['mft_groups_total']} (packet)")

    hit_rate = rf["staging"]["hit_rate"]
    print(f"check_fleet: staging hit_rate={hit_rate:.2f} "
          f"hits={rf['staging']['hits']} misses={rf['staging']['misses']}")
    if not hit_rate > 0:
        problems.append("staging cache saw zero hits during the sweep")

    if problems:
        for p in problems:
            print(f"check_fleet: {p}", file=sys.stderr)
        return 1
    print("check_fleet: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
