"""Build the EXPERIMENTS.md roofline/dry-run tables from results/dryrun.

Usage: python tools/roofline_table.py [results/dryrun] [--tag TAG]
Prints markdown to stdout.
"""
import json
import pathlib
import sys

ARCHS = ["mixtral_8x7b", "qwen3_moe_235b_a22b", "granite_3_2b",
         "llama3_2_3b", "h2o_danube_3_4b", "qwen1_5_110b",
         "whisper_medium", "mamba2_370m", "internvl2_26b",
         "jamba_v0_1_52b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_t(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def load(d, tag=""):
    out = {}
    suffix = f"-{tag}" if tag else ""
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("pod", "multipod"):
                f = d / f"{arch}-{shape}-{mesh}{suffix}.json"
                if f.exists():
                    out[(arch, shape, mesh)] = json.loads(f.read_text())
    return out


def main():
    d = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    tag = ""
    if "--tag" in sys.argv:
        tag = sys.argv[sys.argv.index("--tag") + 1]
    cells = load(d, tag)

    print("### Dry-run status (16x16 pod / 2x16x16 multipod)\n")
    print("| arch | " + " | ".join(SHAPES) + " |")
    print("|---" * (len(SHAPES) + 1) + "|")
    for arch in ARCHS:
        row = [arch]
        for shape in SHAPES:
            marks = []
            for mesh in ("pod", "multipod"):
                c = cells.get((arch, shape, mesh))
                if c is None:
                    marks.append("?")
                elif c["status"] == "ok":
                    marks.append("OK")
                elif c["status"] == "skipped":
                    marks.append("skip")
                else:
                    marks.append("FAIL")
            row.append("/".join(marks))
        print("| " + " | ".join(row) + " |")

    print("\n### Roofline terms (single-pod 16x16, per device, per step)\n")
    print("| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
          "useful | frac | HBM peak |")
    print("|---" * 9 + "|")
    worst = []
    for arch in ARCHS:
        for shape in SHAPES:
            c = cells.get((arch, shape, "pod"))
            if not c or c["status"] != "ok":
                continue
            r = c["roofline"]
            peak = r["memory_stats"].get("temp_bytes") or 0
            args = r["memory_stats"].get("argument_bytes") or 0
            hbm = (peak + args) / 1e9
            print(f"| {arch} | {shape} | {fmt_t(r['t_compute_s'])} | "
                  f"{fmt_t(r['t_memory_s'])} | "
                  f"{fmt_t(r['t_collective_s'])} | {r['bottleneck']} | "
                  f"{r['useful_flops_ratio']:.2f} | "
                  f"{r['roofline_fraction']:.3f} | {hbm:.1f}GB |")
            worst.append((r["roofline_fraction"], arch, shape,
                          r["bottleneck"],
                          r["t_collective_s"] / max(r["t_compute_s"],
                                                    1e-12)))
    print("\n### Hillclimb candidates")
    worst.sort()
    print("\nworst roofline fraction:")
    for frac, arch, shape, bn, _ in worst[:6]:
        print(f"  {arch} {shape}: frac={frac:.4f} bottleneck={bn}")
    print("\nmost collective-bound (t_coll / t_comp):")
    for _, arch, shape, bn, ratio in sorted(worst, key=lambda w: -w[4])[:6]:
        print(f"  {arch} {shape}: coll/comp={ratio:.1f} bottleneck={bn}")


if __name__ == "__main__":
    main()
