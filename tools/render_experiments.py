"""Render the §Roofline tables into EXPERIMENTS.md at the
<!-- ROOFLINE_TABLES --> marker, from results/dryrun (optimized) and
results/dryrun_baseline (paper-faithful baseline).
"""
import io
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent))
from roofline_table import ARCHS, SHAPES, fmt_t, load  # noqa: E402


def table(cells, title):
    out = io.StringIO()
    print(f"#### {title}\n", file=out)
    print("| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
          "useful | frac | temp HBM |", file=out)
    print("|---" * 9 + "|", file=out)
    for arch in ARCHS:
        for shape in SHAPES:
            c = cells.get((arch, shape, "pod"))
            if not c:
                continue
            if c["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | skipped "
                      f"(full attention) | — | — | — |", file=out)
                continue
            if c["status"] != "ok":
                print(f"| {arch} | {shape} | FAIL | | | | | | |",
                      file=out)
                continue
            r = c["roofline"]
            temp = (r["memory_stats"].get("temp_bytes") or 0) / 1e9
            print(f"| {arch} | {shape} | {fmt_t(r['t_compute_s'])} | "
                  f"{fmt_t(r['t_memory_s'])} | "
                  f"{fmt_t(r['t_collective_s'])} | {r['bottleneck']} | "
                  f"{r['useful_flops_ratio']:.2f} | "
                  f"{r['roofline_fraction']:.3f} | {temp:.1f}GB |",
                  file=out)
    return out.getvalue()


def dryrun_status(cells):
    out = io.StringIO()
    print("#### Dry-run status — pod / multipod (OK = lower+compile "
          "succeeded)\n", file=out)
    print("| arch | " + " | ".join(SHAPES) + " |", file=out)
    print("|---" * (len(SHAPES) + 1) + "|", file=out)
    for arch in ARCHS:
        row = [arch]
        for shape in SHAPES:
            marks = []
            for mesh in ("pod", "multipod"):
                c = cells.get((arch, shape, mesh))
                marks.append("?" if c is None else
                             {"ok": "OK", "skipped": "skip"}.get(
                                 c["status"], "FAIL"))
            row.append("/".join(marks))
        print("| " + " | ".join(row) + " |", file=out)
    return out.getvalue()


def compile_times(cells):
    ts = [c["t_compile_s"] for c in cells.values()
          if c.get("status") == "ok"]
    n_ok = len(ts)
    n_skip = sum(1 for c in cells.values() if c["status"] == "skipped")
    return (f"{n_ok} cells compiled (+{n_skip} documented skips); "
            f"compile time min/median/max = {min(ts):.1f}/"
            f"{sorted(ts)[len(ts) // 2]:.1f}/{max(ts):.1f}s\n")


def main():
    opt = load(pathlib.Path("results/dryrun"))
    base = load(pathlib.Path("results/dryrun_baseline"))
    md = pathlib.Path("EXPERIMENTS.md").read_text()
    block = (dryrun_status(opt) + "\n" + compile_times(opt) + "\n"
             + table(base, "Baseline (paper-faithful first build) — "
                     "single-pod 16x16, per device, per step")
             + "\n"
             + table(opt, "Optimized (after §Perf iterations) — "
                     "single-pod 16x16, per device, per step"))
    md = md.replace("<!-- ROOFLINE_TABLES -->", block)
    pathlib.Path("EXPERIMENTS.md").write_text(md)
    print("rendered")


if __name__ == "__main__":
    main()
