#!/usr/bin/env python3
"""Matrix-plane smoke gate for the batched dynamic-segment solver
(wired into CI).

Four invariants from ISSUE 10, on the small (16-host) twin of the
``benchmarks/fig_matrix.py`` churn x loss x faults grid:

1. **zero-dynamic bit-identity** — cells with no events and no faults
   never touch the segment machinery: ``batched`` and ``legacy``
   segment-solver modes must agree bit for bit on BOTH flow backends.
2. **batched == per-segment oracle** — the dynamic cells (churn and/or
   flaps) are bit-identical batched-vs-legacy on the numpy backend
   (same solver, same per-segment problems) and <= 1e-6 relative on
   the JAX backend (float64 device solves, reduction order only).
3. **device solver == numpy oracle** — ``segment_rates_many`` on the
   JAX backend matches the numpy per-segment solve + loss factor to
   <= 1e-6 relative on random padded/bucketed problems.
4. **churn x loss x faults parity** — every flow-engine cell agrees
   within 15% with the frozen multi-seed packet-engine ground truth
   (``benchmarks/ref_matrix.json``).  As in ``check_fig15.py``, verify
   runs only the deterministic fluid model (seconds); ``--update``
   re-measures the sampled packet side (64 repetitions per lossy
   cell) and rewrites the reference.

Exit code 0 = clean; 1 = divergence (details on stderr).

    PYTHONPATH=src python tools/check_matrix.py             # verify
    PYTHONPATH=src python tools/check_matrix.py --update    # re-measure
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

REF_PATH = os.path.join(REPO, "benchmarks", "ref_matrix.json")
TOL = 0.15                 # packet-vs-flow parity bound
SEG_TOL = 1e-6             # device-vs-oracle bound
GT_SEEDS = 64              # packet repetitions per lossy cell


def _key(cell):
    churn, loss, flaps = cell
    return f"c{churn:g}_l{loss:g}_f{flaps}"


def _grid(engine, mode, seeds=1, workers=None):
    from benchmarks import fig_matrix as fm
    topo = fm.build_topo(smoke=True)
    return fm.sweep_grid(
        engine, topo, fm.N_GROUPS_SMALL, fm.GROUP_SMALL,
        fm.NBYTES_SMALL, seeds=seeds, workers=workers,
        engine_kw={"segment_solver": mode} if mode else None)


def check_modes(problems):
    """Invariants 1 + 2: batched vs legacy on both flow backends."""
    for engine in ("flow-np", "flow"):
        batched = _grid(engine, "batched")
        legacy = _grid(engine, "legacy")
        exact = drift = 0
        for cell, want in legacy.items():
            got = batched[cell]
            churn, loss, flaps = cell
            if loss:
                # lossy dynamic cells differ by design: the batched
                # solver folds the loss factor into the SAME segment
                # solves, the legacy closures never did
                continue
            if engine == "flow-np" or (churn == 0 and flaps == 0):
                if got != want:
                    problems.append(
                        f"modes {engine}/{_key(cell)}: batched "
                        f"{got!r} != legacy {want!r} (bit-identity)")
                else:
                    exact += 1
            elif abs(got - want) > SEG_TOL * want:
                problems.append(
                    f"modes {engine}/{_key(cell)}: batched {got!r} vs "
                    f"legacy {want!r} exceeds {SEG_TOL:g} relative")
            else:
                drift += 1
        print(f"check_matrix: modes {engine}: {exact} cells "
              f"bit-identical, {drift} within {SEG_TOL:g}")


def check_oracle(problems):
    """Invariant 3: device ``segment_rates_many`` vs the numpy oracle
    on random duplicate-free problems (with and without loss params)."""
    from benchmarks import fig_matrix as fm
    from repro.core.flowsim import FlowSim, LossParams
    from repro.core.flowsim_jax import HAS_JAX, JaxFlowSim
    if not HAS_JAX:
        print("check_matrix: oracle: jax unavailable, skipped")
        return
    topo = fm.build_topo(smoke=True)
    np_sim, jx_sim = FlowSim(topo), JaxFlowSim(topo)
    rng = np.random.default_rng(0)
    n_links = len(np_sim.cap)
    probs = []
    for _ in range(24):
        n_flows = int(rng.integers(2, 9))
        sets = tuple(
            tuple(int(x) for x in
                  rng.choice(n_links, size=int(rng.integers(1, 7)),
                             replace=False))
            for _ in range(n_flows))
        lp = None
        if rng.random() < 0.7:
            lp = LossParams(q=float(rng.uniform(0, 0.05)),
                            wsq=float(rng.uniform(0, 1e-4)),
                            wnd=256.0, tail=0.0,
                            ecn=bool(rng.random() < 0.5))
        probs.append((sets, lp))
    want = np_sim.segment_rates_many(probs)
    got = jx_sim.segment_rates_many(probs)
    bad = [(i, g, w) for i, (g, w) in enumerate(zip(got, want))
           if abs(g - w) > SEG_TOL * w]
    for i, g, w in bad:
        problems.append(f"oracle problem {i}: device {g!r} vs "
                        f"numpy {w!r} exceeds {SEG_TOL:g} relative")
    if not bad:
        print(f"check_matrix: oracle: {len(probs)} problems within "
              f"{SEG_TOL:g}")


def check_parity(problems):
    """Invariant 4: flow cells vs the frozen packet ground truth."""
    if not os.path.exists(REF_PATH):
        problems.append(f"missing {REF_PATH} — run --update once")
        return
    with open(REF_PATH) as fh:
        ref = json.load(fh)
    flow = _grid("flow", None)
    worst = 0.0
    for cell, jf in flow.items():
        want = ref["cells"].get(_key(cell))
        if want is None:
            problems.append(f"parity {_key(cell)}: missing from ref — "
                            f"run --update")
            continue
        div = abs(jf * 1e6 - want) / want
        worst = max(worst, div)
        if div > TOL:
            problems.append(
                f"parity {_key(cell)}: flow {jf * 1e6:.2f}us vs packet "
                f"GT {want:.2f}us diverges {100 * div:.1f}% (> "
                f"{100 * TOL:.0f}%)")
    print(f"check_matrix: parity: {len(flow)} cells vs frozen GT, "
          f"worst {100 * worst:.1f}%")


def update(workers=0):
    """Re-measure the packet ground truth (sampled: 64 reps per lossy
    cell) and rewrite ``benchmarks/ref_matrix.json``."""
    from benchmarks import fig_matrix as fm
    gt = _grid("packet", None, seeds=GT_SEEDS, workers=workers)
    ref = {
        "meta": {"seeds": GT_SEEDS, "nbytes": fm.NBYTES_SMALL,
                 "groups": [fm.N_GROUPS_SMALL, fm.GROUP_SMALL],
                 "tool": "tools/check_matrix.py --update"},
        "cells": {_key(cell): j * 1e6 for cell, j in sorted(gt.items())},
    }
    with open(REF_PATH, "w") as fh:
        json.dump(ref, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"check_matrix: wrote {len(ref['cells'])} cells -> {REF_PATH}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--update", action="store_true",
                    help="re-measure the packet ground truth (slow) "
                         "and rewrite the reference file")
    ap.add_argument("--workers", type=int, default=0,
                    help="packet scenario workers for --update")
    args = ap.parse_args(argv)
    if args.update:
        update(args.workers)
        return 0
    problems: list = []
    check_modes(problems)
    check_oracle(problems)
    check_parity(problems)
    if problems:
        for p in problems:
            print(f"check_matrix: FAIL: {p}", file=sys.stderr)
        return 1
    print("check_matrix: all invariants hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
