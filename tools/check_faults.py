#!/usr/bin/env python3
"""Fault-plane smoke gate (wired into CI).

Two invariants from ISSUE 7:

1. **zero-fault bit-identity** — scenarios expressible in the PR-6
   Workload IR (no ``faults=`` field) must reproduce the frozen
   fixed-seed records in ``benchmarks/ref_faults_zero.json`` *bit for
   bit* on both engines.  The entire fault plane is opt-in: a workload
   that injects nothing must not perturb a single float.
2. **recovery-latency parity** — every fault class (link_down,
   link_flap, switch_fail, host_gone_dark, master_crash) completes on
   BOTH engines with no hang and no QP error, and the measured
   recovery latency (cqe_fault - cqe_nofault) agrees within 15%.

Exit code 0 = clean; 1 = divergence (details on stderr).

    PYTHONPATH=src python tools/check_faults.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import fattree, workload as wl          # noqa: E402
from repro.core.engine import make_engine               # noqa: E402

from freeze_fault_refs import OUT as REF_PATH, record_rows  # noqa: E402

TOL = 0.15
AT = 3e-6                      # fault lands 3us into the stream
NBYTES = 1 << 17
MEMBERS = ["h0", "h1", "h2", "h3"]


def check_zero_fault(problems):
    with open(REF_PATH) as fh:
        ref = json.load(fh)
    for engine, want in ref["engines"].items():
        # frozen JSON renders tuples as lists; normalize through a JSON
        # round trip before comparing, or the match fails on type alone
        got = json.loads(json.dumps(record_rows(engine)))
        if got != want:
            for name in want:
                if got.get(name) != want[name]:
                    problems.append(
                        f"zero-fault {engine}/{name}: records diverge "
                        f"from frozen PR-6 ref\n  want {want[name]}\n"
                        f"  got  {got.get(name)}")
        else:
            print(f"check_faults: zero-fault {engine}: "
                  f"{len(want)} scenarios bit-identical")


def _leaf_uplink(topo, host):
    """First non-host peer of the host's leaf switch."""
    leaf = topo.ports[host][0][0]
    for p in sorted(topo.ports[leaf]):
        peer = topo.ports[leaf][p][0]
        if not peer.startswith("h"):
            return leaf, peer
    raise RuntimeError(f"no uplink above {host}")


def _run(engine_name, faults):
    eng = make_engine(engine_name, fattree.fig4(),
                      **({"seed": 7} if engine_name == "packet" else {}))
    rec = eng.stage(wl.GroupOp("bcast", MEMBERS, NBYTES,
                               faults=tuple(faults)))
    eng.run(timeout=1.0)
    return rec


def check_recovery_parity(problems):
    topo = fattree.fig4()
    leaf, spine = _leaf_uplink(topo, "h2")
    cases = [
        ("link_down", [wl.FaultEvent("link_down", AT, node=leaf,
                                     peer=spine)]),
        ("link_flap", [wl.FaultEvent("link_flap", AT, node=leaf,
                                     peer=spine, duration=50e-6)]),
        ("switch_fail", [wl.FaultEvent("switch_fail", AT, node=spine)]),
        ("host_gone_dark", [wl.FaultEvent("host_gone_dark", AT,
                                          node="h3")]),
        ("master_crash", [wl.FaultEvent("master_crash", AT)]),
    ]
    base = {e: _run(e, []) for e in ("packet", "flow")}
    for name, faults in cases:
        rec = {}
        n_expect = len(wl.GroupOp("bcast", MEMBERS, NBYTES,
                                  faults=tuple(faults))
                       .surviving_receivers())
        for engine in ("packet", "flow"):
            r = _run(engine, faults)
            if r.error or r.t_sender_cqe < 0 or len(r.t_deliver) < n_expect:
                problems.append(
                    f"{name}/{engine}: incomplete (error={r.error!r}, "
                    f"cqe={r.t_sender_cqe}, "
                    f"deliver={len(r.t_deliver)}/{n_expect})")
            rec[engine] = r.t_sender_cqe - base[engine].t_sender_cqe
        p, f = rec["packet"], rec["flow"]
        div = abs(p - f) / max(p, 1e-9)
        print(f"check_faults: {name:15s} recovery packet="
              f"{p * 1e6:8.2f}us flow={f * 1e6:8.2f}us "
              f"div={100 * div:.1f}%")
        if div > TOL:
            problems.append(
                f"{name}: packet-vs-flow recovery divergence "
                f"{100 * div:.1f}% > {100 * TOL:.0f}%")


def main() -> int:
    problems: list = []
    check_zero_fault(problems)
    check_recovery_parity(problems)
    if problems:
        for p in problems:
            print(f"check_faults: {p}", file=sys.stderr)
        return 1
    print("check_faults: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
