#!/usr/bin/env python3
"""Churn-scenario smoke gate (wired into CI).

Runs one dynamic-membership sweep (join / leave / fail / master-switch
mid-stream) and asserts the membership-control-plane invariants:

1. **packet + flow** — every scenario completes on BOTH engines and
   their JCTs agree within 10% (the ISSUE-5 acceptance bound);
2. **serial == workers=2** — the packet engine's scenario-parallel path
   reproduces the serial records bit for bit with dynamic events in
   flight (quiesce/fork machinery intact).

Exit code 0 = clean; 1 = divergence (details on stderr).

    PYTHONPATH=src python tools/check_churn.py
"""
from __future__ import annotations

import sys

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import GroupOp, MemberEvent

MEMBERS = [f"h{i}" for i in range(8)]
NBYTES = 1 << 20
TOL = 0.10

SCENARIOS = [
    ("static", ()),
    ("join", (MemberEvent("join", "h8", 30e-6),)),
    ("leave", (MemberEvent("leave", "h7", 30e-6),)),
    ("fail", (MemberEvent("fail", "h7", 30e-6),)),
    ("mix", (MemberEvent("master-switch", "h1", 10e-6),
             MemberEvent("leave", "h6", 20e-6),
             MemberEvent("join", "h8", 40e-6),
             MemberEvent("fail", "h5", 60e-6))),
]


def run_engine(engine: str, workers):
    eng = make_engine(engine, fattree.testbed(n_hosts=10), **(
        {"loss_rate": 1e-5, "seed": 11} if engine == "packet" else {}))
    recs = []

    def scenario(op):
        def fn(e):
            recs.append(e.stage(op))
        return fn

    ops = [GroupOp("bcast", MEMBERS, NBYTES, events=ev)
           for _, ev in SCENARIOS]
    kw = {"workers": workers} if engine == "packet" else {}
    eng.run_many([scenario(op) for op in ops], timeout=60.0, **kw)
    return [(r.msg_id, r.t_submit, r.t_sender_cqe,
             sorted(r.t_deliver.items())) for r in recs], \
           [r.jct(len(op.surviving_receivers()))
            for r, op in zip(recs, ops)]


def main() -> int:
    problems = []
    serial, jct_p = run_engine("packet", None)
    parallel, _ = run_engine("packet", 2)
    if serial != parallel:
        problems.append("packet serial vs workers=2 records diverge")
    _, jct_f = run_engine("flow", None)
    for (name, _), jp, jf in zip(SCENARIOS, jct_p, jct_f):
        if jp == float("inf") or jf == float("inf"):
            problems.append(f"{name}: incomplete (packet={jp}, flow={jf})")
            continue
        div = abs(jp - jf) / jp
        print(f"check_churn: {name:7s} packet={jp * 1e3:.4f}ms "
              f"flow={jf * 1e3:.4f}ms div={100 * div:.1f}%")
        if div > TOL:
            problems.append(
                f"{name}: packet-vs-flow divergence {100 * div:.1f}% "
                f"> {100 * TOL:.0f}%")
    if problems:
        for p in problems:
            print(f"check_churn: {p}", file=sys.stderr)
        return 1
    print("check_churn: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
