#!/usr/bin/env python3
"""Docs consistency checker (wired into CI).

Checks, over README.md and docs/*.md:

1. **Links resolve** — every relative markdown link `[..](path)` points
   at a file or directory that exists (external http(s)/mailto links
   are skipped; intra-page `#anchors` are stripped before checking).
2. **Figure table is complete** — every `benchmarks/fig*.py` module is
   mentioned in README.md's benchmarks table, and every module the
   table names exists on disk.
3. **Backtick paths exist** — inline-code references to repo paths of
   the form `src/...`, `benchmarks/...`, `tests/...`, `tools/...`,
   `docs/...`, `examples/...` resolve (catches renames that orphan the
   docs).

Exit code 0 = clean; 1 = problems (listed on stderr).

    python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODEPATH_RE = re.compile(
    r"`((?:src|benchmarks|tests|tools|docs|examples)/[A-Za-z0-9_./*-]+)`")


def check_file(md_path: str, root: str, problems: list) -> str:
    text = open(md_path, encoding="utf-8").read()
    rel = os.path.relpath(md_path, root)
    base = os.path.dirname(md_path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:                       # pure intra-page anchor
            continue
        if not os.path.exists(os.path.join(base, path)):
            problems.append(f"{rel}: broken link -> {target}")
    for ref in CODEPATH_RE.findall(text):
        pattern = os.path.join(root, ref)
        if not (os.path.exists(pattern) or glob.glob(pattern)):
            problems.append(f"{rel}: dangling code path -> {ref}")
    return text


def check_figure_table(readme_text: str, root: str, problems: list) -> None:
    on_disk = {os.path.basename(p) for p in
               glob.glob(os.path.join(root, "benchmarks", "fig*.py"))}
    in_table = set(re.findall(r"benchmarks/(fig[A-Za-z0-9_]+\.py)",
                              readme_text))
    for missing in sorted(on_disk - in_table):
        problems.append(
            f"README.md: benchmarks/{missing} missing from figure table")
    for stale in sorted(in_table - on_disk):
        problems.append(
            f"README.md: figure table names nonexistent benchmarks/{stale}")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = os.path.abspath(argv[0] if argv else
                           os.path.join(os.path.dirname(__file__), ".."))
    readme = os.path.join(root, "README.md")
    problems: list = []
    if not os.path.exists(readme):
        problems.append("README.md: missing")
        readme_text = ""
    else:
        readme_text = check_file(readme, root, problems)
    for md in sorted(glob.glob(os.path.join(root, "docs", "*.md"))):
        check_file(md, root, problems)
    check_figure_table(readme_text, root, problems)
    if problems:
        for p in problems:
            print(f"check_docs: {p}", file=sys.stderr)
        print(f"check_docs: {len(problems)} problem(s)", file=sys.stderr)
        return 1
    print("check_docs: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
