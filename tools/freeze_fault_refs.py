"""Freeze the zero-fault reference records for tools/check_faults.py.

Run ONCE against the pre-fault-plane tree (PR-6) to capture fixed-seed
ground truth; ``check_faults.py`` then asserts that zero-fault scenarios
stay bit-identical after the fault subsystem landed.  Keep the scenarios
expressible in the PR-6 Workload IR (no ``faults=`` field) so the frozen
file never needs regenerating.

    PYTHONPATH=src python tools/freeze_fault_refs.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import fattree, workload as wl          # noqa: E402
from repro.core.engine import make_engine               # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "ref_faults_zero.json")

NBYTES = 1 << 18
SEED = 7


def scenarios():
    """(name, op) pairs — PR-6 IR only (no faults)."""
    return [
        ("static-g8", wl.GroupOp("bcast", [f"h{i}" for i in range(8)],
                                 NBYTES)),
        ("churn-g6", wl.GroupOp(
            "bcast", [f"h{i}" for i in range(6)], NBYTES,
            events=(wl.MemberEvent("join", "h7", 4e-5),
                    wl.MemberEvent("leave", "h3", 8e-5)))),
        ("ring-g6", wl.GroupOp("bcast", [f"h{i}" for i in range(6)],
                               NBYTES, transport="ring")),
    ]


def record_rows(engine_name):
    topo = fattree.testbed(n_hosts=10)
    kw = {"seed": SEED} if engine_name == "packet" else {}
    eng = make_engine(engine_name, topo, **kw)
    ops = [op for _, op in scenarios()]
    recs = []

    def scenario(op):
        def fn(e):
            recs.append(e.stage(op))
        return fn

    eng.run_many([scenario(op) for op in ops], timeout=60.0)
    rows = {}
    for (name, op), r in zip(scenarios(), recs):
        rows[name] = {
            "t_submit": repr(float(r.t_submit)),
            "t_sender_cqe": repr(float(r.t_sender_cqe)),
            "t_deliver": sorted((m, repr(float(t)))
                                for m, t in r.t_deliver.items()),
            "jct": repr(float(r.jct(len(op.surviving_receivers())))),
        }
    return rows


def main():
    ref = {"nbytes": NBYTES, "seed": SEED,
           "engines": {name: record_rows(name)
                       for name in ("packet", "flow-np")}}
    with open(OUT, "w") as fh:
        json.dump(ref, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
