#!/usr/bin/env python3
"""CI divergence gate for the flow-engine loss/DCQCN model.

The fluid engines carry an expected-value loss correction (go-back-N
replay + timeout tail + DCQCN, ``core/flowsim.py``) calibrated against
the packet engine.  This gate runs the calibration grid — gleam +
multiunicast bcasts, groups 4/8, loss 0..1e-2 on the Fig. 8 testbed —
on the FLOW engine and compares every point against the checked-in
fixed-seed packet ground truth (``benchmarks/ref_fig15_flow.json``).
A relative divergence above 15% on any point fails the build: the two
engines are maintained independently, so drift on either side of the
differential trips the gate.

Unlike ``check_fig09.py`` (flow vs frozen flow), verify and update run
DIFFERENT engines: ``--update`` re-measures the packet ground truth
(multi-seed ``run_many`` batches — minutes), while the verify path only
runs the deterministic fluid model (seconds) — cheap enough for CI.
The zero-loss points double as a bit-exactness tripwire: with loss off
the flow engine must reproduce its pre-loss-model results, so they are
held to 0.1%, not 15%.

    PYTHONPATH=src python tools/check_fig15.py             # verify
    PYTHONPATH=src python tools/check_fig15.py --update    # re-measure GT

Exit code 0 = within tolerance; 1 = divergence (listed on stderr).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

REF_PATH = os.path.join(REPO, "benchmarks", "ref_fig15_flow.json")
TOLERANCE = 0.15          # calibration bound, lossy points
ZERO_TOLERANCE = 0.001    # zero-loss points must stay bit-compatible


def _grid():
    from benchmarks.fig15_16_loss import (FID_GROUPS, FID_LOSS_RATES,
                                          FID_TRANSPORTS, _label)
    for transport in FID_TRANSPORTS:
        for group in FID_GROUPS:
            for loss in FID_LOSS_RATES:
                yield (f"g{group}_loss{_label(loss)}/{transport}",
                       group, loss, transport)


def measure(engine="flow") -> dict:
    """Flow-engine JCT (us) at every calibration-grid point."""
    from benchmarks.fig15_16_loss import flow_jct
    return {key: flow_jct(group, loss, transport, engine) * 1e6
            for key, group, loss, transport in _grid()}


def update(workers=0) -> dict:
    """Packet ground truth (us): multi-seed mean per grid point."""
    from benchmarks.fig15_16_loss import packet_gt
    gt = {}
    for key, group, loss, transport in _grid():
        gt[key] = packet_gt(group, loss, transport, workers) * 1e6
        print(f"check_fig15: GT {key}: {gt[key]:.2f}us")
    return gt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--update", action="store_true",
                    help="re-measure the packet ground truth (slow) and "
                         "rewrite the reference file")
    ap.add_argument("--engine", default="flow",
                    choices=("flow", "flow-np"),
                    help="fluid backend to verify (default: flow)")
    args = ap.parse_args(argv)
    if args.update:
        from benchmarks.fig15_16_loss import (FID_SEEDS, NBYTES,
                                              FID_GROUPS)
        gt = update()
        flow = measure(args.engine)
        with open(REF_PATH, "w", encoding="utf-8") as f:
            json.dump({"tolerance": TOLERANCE,
                       "zero_tolerance": ZERO_TOLERANCE,
                       "seed": 11, "window": 512, "nbytes": NBYTES,
                       "groups": list(FID_GROUPS),
                       "seeds_per_loss": {f"{k:g}": v
                                          for k, v in FID_SEEDS.items()},
                       "packet_us": gt,
                       "flow_us_at_update": flow},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_fig15: wrote {len(gt)} GT points -> {REF_PATH}")
        return 0
    if not os.path.exists(REF_PATH):
        print(f"check_fig15: missing reference {REF_PATH} "
              f"(run with --update)", file=sys.stderr)
        return 1
    with open(REF_PATH, encoding="utf-8") as f:
        ref = json.load(f)["packet_us"]
    got = measure(args.engine)
    problems = []
    for name, want in sorted(ref.items()):
        have = got.get(name)
        if have is None:
            problems.append(f"missing point {name}")
            continue
        tol = ZERO_TOLERANCE if "_loss0/" in name else TOLERANCE
        dev = abs(have - want) / want
        status = "FAIL" if dev > tol else "ok"
        print(f"check_fig15: {status} {name}: flow {have:.2f}us "
              f"(packet {want:.2f}us, {100 * dev:.1f}% of "
              f"{100 * tol:g}%)")
        if dev > tol:
            problems.append(f"{name}: flow {have:.2f}us vs packet "
                            f"{want:.2f}us ({100 * dev:.1f}% > "
                            f"{100 * tol:g}%)")
    for name in sorted(set(got) - set(ref)):
        problems.append(f"unexpected point {name} (run --update?)")
    if problems:
        for p in problems:
            print(f"check_fig15: {p}", file=sys.stderr)
        return 1
    print(f"check_fig15: OK ({len(ref)} points, lossy within "
          f"{100 * TOLERANCE:.0f}%, zero-loss within "
          f"{100 * ZERO_TOLERANCE:g}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
