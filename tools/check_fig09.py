#!/usr/bin/env python3
"""CI divergence gate for the Workload-IR benchmark path.

Runs the Fig. 9 Gleam-vs-multiunicast comparison through the new API
(``benchmarks.fig09_mpi_bcast.run`` with ``transport="multiunicast"``)
on the flow engine at smoke scale, and compares every row against the
checked-in reference numbers.  A relative divergence above 10% on any
row fails the build — catching regressions in the transport lowering,
the fluid solver, or the staging path.

    PYTHONPATH=src python tools/check_fig09.py             # verify
    PYTHONPATH=src python tools/check_fig09.py --update    # regenerate

Exit code 0 = within tolerance; 1 = divergence (listed on stderr).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

REF_PATH = os.path.join(REPO, "benchmarks", "ref_fig09_flow.json")
TOLERANCE = 0.10
GROUP = 8                              # smoke scale: 8-member group
SIZES = [64 << 10, 1 << 20, 8 << 20]   # KB..MB ladder, one jit bucket


def measure() -> dict:
    from benchmarks.fig09_mpi_bcast import run
    rows: list = []
    run(rows, engine="flow", transport="multiunicast", group=GROUP,
        sizes=SIZES)
    return {name: value for name, value, _ in rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--update", action="store_true",
                    help="rewrite the reference file from this run")
    args = ap.parse_args(argv)
    got = measure()
    if args.update:
        with open(REF_PATH, "w", encoding="utf-8") as f:
            json.dump({"group": GROUP, "sizes": SIZES,
                       "tolerance": TOLERANCE, "rows_us": got},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_fig09: wrote {len(got)} rows -> {REF_PATH}")
        return 0
    if not os.path.exists(REF_PATH):
        print(f"check_fig09: missing reference {REF_PATH} "
              f"(run with --update)", file=sys.stderr)
        return 1
    with open(REF_PATH, encoding="utf-8") as f:
        ref = json.load(f)["rows_us"]
    problems = []
    for name, want in sorted(ref.items()):
        have = got.get(name)
        if have is None:
            problems.append(f"missing row {name}")
            continue
        dev = abs(have - want) / want
        status = "FAIL" if dev > TOLERANCE else "ok"
        print(f"check_fig09: {status} {name}: {have:.2f}us "
              f"(ref {want:.2f}us, {100 * dev:.1f}%)")
        if dev > TOLERANCE:
            problems.append(f"{name}: {have:.2f}us vs ref {want:.2f}us "
                            f"({100 * dev:.1f}% > {100 * TOLERANCE:.0f}%)")
    for name in sorted(set(got) - set(ref)):
        problems.append(f"unexpected row {name} (run --update?)")
    if problems:
        for p in problems:
            print(f"check_fig09: {p}", file=sys.stderr)
        return 1
    print(f"check_fig09: OK ({len(ref)} rows within "
          f"{100 * TOLERANCE:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
