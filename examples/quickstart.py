"""Quickstart: both Gleam layers in 60 seconds.

1. The faithful layer — an in-fabric reliable multicast on the paper's
   4-server testbed, vs the multiple-unicasts baseline (Fig. 2a vs 2c).
2. The adapted layer — the same one-to-many/many-to-one pattern as TPU
   collectives inside a toy training step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import fattree
from repro.core.engine import make_engine
from repro.core.workload import GroupOp
from repro.configs.base import get_config
from repro.launch.mesh import single_device_mesh
from repro.launch.steps import make_train_step
from repro.models.blocks import init_params
from repro.models.model import model_defs
from repro.optim import adamw
from repro.data.pipeline import DataConfig, Pipeline


def part1_protocol():
    print("=" * 64)
    print("1) Gleam protocol: 1MB broadcast to 3 receivers @100Gbps")
    print("=" * 64)
    nbytes = 1 << 20
    members = ["h0", "h1", "h2", "h3"]

    # the same experiment on both SimEngine backends (core/engine.py):
    # per-packet reference vs vectorized fluid model.  The transport —
    # in-fabric gleam vs the §2.3 overlays — is just a field of the
    # staged GroupOp (core/workload.py), on either engine.
    jct = None
    for engine in ("packet", "flow"):
        eng = make_engine(engine, fattree.testbed())
        rec = eng.stage(GroupOp("bcast", members, nbytes))
        eng.run()
        j = rec.jct(len(members) - 1)
        jct = jct or j
        print(f"  gleam (in-fabric) [{engine:7s}] JCT: {j * 1e6:9.1f} us")

    for transport in ("multiunicast", "ring"):
        eng = make_engine("packet", fattree.testbed())
        rec = eng.stage(GroupOp("bcast", members, nbytes,
                                transport=transport))
        eng.run()
        jct_b = rec.jct(len(members) - 1)
        print(f"  {transport + ' overlay':28s} JCT: {jct_b * 1e6:9.1f} us  "
              f"({jct_b / jct:.2f}x slower)")


def part2_training():
    print("=" * 64)
    print("2) Framework: 5 train steps of the mixtral-family smoke config")
    print("=" * 64)
    cfg = get_config("mixtral_8x7b", smoke=True)
    mesh = single_device_mesh()
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    step = jax.jit(make_train_step(cfg, mesh))
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                               global_batch=4))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        with mesh:
            params, opt_state, metrics = step(params, opt_state, batch)
        print(f"  step {i}: loss {float(metrics['loss']):.4f}  "
              f"aux {float(metrics['aux_loss']):.4f}")


if __name__ == "__main__":
    part1_protocol()
    part2_training()
