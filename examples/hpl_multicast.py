"""HPL communication pattern over Gleam (§5.2.1 + Appendix B).

Models the Panel-Broadcast (PB) phase: each epoch, a different node owns
the panel and multicasts it to the group — Gleam's source switching lets
the SAME multicast group rotate sources with no re-registration, vs the
HPL `increasing-ring` overlay baseline.  Panel volume decays linearly
across epochs, as in the real workload (§2.2).

Run:  PYTHONPATH=src python examples/hpl_multicast.py --epochs 6
"""
import argparse

from repro.core import fattree
from repro.core.baselines import RingBcast
from repro.core.gleam import GleamNetwork


def gleam_pb(members, epochs, first_mb):
    net = GleamNetwork(fattree.testbed(n_hosts=len(members)))
    g = net.multicast_group(members)
    g.register()
    times = []
    for e in range(epochs):
        nbytes = max(int(first_mb * (1 << 20) * (1 - e / epochs)), 1 << 12)
        src = members[e % len(members)]
        if src != g.source:
            g.switch_source(src)           # Appendix B: no re-registration
        rec = g.bcast(nbytes)
        times.append(g.run_until_delivered(rec))
    return times


def ring_pb(members, epochs, first_mb):
    times = []
    for e in range(epochs):
        nbytes = max(int(first_mb * (1 << 20) * (1 - e / epochs)), 1 << 12)
        # the overlay must rebuild its relay chain for each new source
        net = GleamNetwork(fattree.testbed(n_hosts=len(members)))
        order = members[e % len(members):] + members[:e % len(members)]
        b = RingBcast(net, order, chunks=1)  # HPL increasing-ring: store-and-forward per hop
        b.start(nbytes)
        times.append(b.run())
    return times


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--first-mb", type=float, default=8.0)
    args = ap.parse_args()

    members = [f"h{i}" for i in range(args.nodes)]
    tg = gleam_pb(members, args.epochs, args.first_mb)
    tr = ring_pb(members, args.epochs, args.first_mb)

    print(f"{'epoch':>6} {'gleam_us':>10} {'ring_us':>10} {'speedup':>8}")
    for e, (a, b) in enumerate(zip(tg, tr)):
        print(f"{e:6d} {a * 1e6:10.1f} {b * 1e6:10.1f} {b / a:8.2f}x")
    print(f"\ntotal PB communication: gleam {sum(tg) * 1e3:.2f} ms, "
          f"ring {sum(tr) * 1e3:.2f} ms "
          f"({sum(tr) / sum(tg):.2f}x — paper reports up to 2.9x on HPL)")


if __name__ == "__main__":
    main()
