"""Storage data replication over one-to-many WRITE (§5.2.2).

A client keeps 3-copy-writing 8KB IOs to three storage servers:
  - Gleam: ONE RC connection, one-sided WRITE, in-fabric replication
    (per-request MR_UPDATE, §3.3);
  - 3-unicasts: three RC connections, the client sends every byte 3x;
  - 1-copy: the no-replication ideal bound.

Reports IOPS (Fig. 12) and single-IO latency vs IO size (Fig. 13).

Run:  PYTHONPATH=src python examples/storage_replication.py
"""
import argparse

from repro.core import fattree
from repro.core.gleam import GleamNetwork


def gleam_iops(io_bytes, n_ios):
    net = GleamNetwork(fattree.testbed())
    g = net.multicast_group(["h0", "h1", "h2", "h3"])
    g.register()
    t0 = net.sim.now
    recs = [g.write(io_bytes) for _ in range(n_ios)]
    for r in recs:
        g.run_until_delivered(r)
    dt = max(r.t_sender_cqe for r in recs) - t0
    lat = sum(r.io_latency for r in recs) / len(recs)
    return n_ios / dt, lat


def unicast_iops(io_bytes, n_ios, copies=3):
    net = GleamNetwork(fattree.testbed())
    qps = [net.unicast_qp("h0", f"h{i + 1}")[0] for i in range(copies)]
    sim = net.sim
    t0 = sim.now
    done = []
    for qp in qps:
        qp.on_complete = lambda m, now: done.append((m.msg_id, now))
    for i in range(n_ios):
        for qp in qps:
            qp.submit(io_bytes, sim.now, op="write", msg_id=i)
    sim.kick(sim.hosts["h0"], sim.now)
    sim.run(until=sim.now + 30.0)
    per_io = {}
    for mid, t in done:
        per_io.setdefault(mid, []).append(t)
    complete = {k: max(v) for k, v in per_io.items() if len(v) == copies}
    assert len(complete) == n_ios, f"only {len(complete)}/{n_ios} done"
    dt = max(complete.values()) - t0
    lat = sum(complete.values()) / n_ios - t0  # rough mean completion
    return n_ios / dt, (sum(complete.values()) - n_ios * t0) / n_ios


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ios", type=int, default=200)
    args = ap.parse_args()

    print("=== throughput, 8KB IOs (Fig. 12) ===")
    g_iops, _ = gleam_iops(8 << 10, args.ios)
    u_iops, _ = unicast_iops(8 << 10, args.ios)
    o_iops, _ = unicast_iops(8 << 10, args.ios, copies=1)
    print(f"  gleam 3-copy : {g_iops / 1e3:8.1f} K IOPS")
    print(f"  3-unicasts   : {u_iops / 1e3:8.1f} K IOPS "
          f"({g_iops / u_iops:.2f}x less than Gleam; paper: 2.7x)")
    print(f"  1-copy ideal : {o_iops / 1e3:8.1f} K IOPS "
          f"(Gleam reaches {100 * g_iops / o_iops:.0f}% of ideal)")

    print("\n=== single-IO latency vs IO size (Fig. 13) ===")
    print(f"{'size':>8} {'gleam_us':>10} {'3uni_us':>10} {'saving':>8}")
    for kb in (8, 64, 512):
        _, gl = gleam_iops(kb << 10, 20)
        _, ul = unicast_iops(kb << 10, 20)
        print(f"{kb:6d}KB {gl * 1e6:10.1f} {ul * 1e6:10.1f} "
              f"{100 * (1 - gl / ul):7.1f}%")


if __name__ == "__main__":
    main()
