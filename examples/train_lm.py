"""End-to-end training driver: train an LM with the full runtime stack
(data pipeline -> sharded step -> checkpointing -> straggler monitor).

Presets:
  small  (default) — ~7M params, runs a few hundred steps on CPU in
                     minutes; used by the checked-in example log.
  100m             — a ~100M-param llama-family model (the deliverable's
                     reference size); same code path, sized for a real
                     accelerator (on CPU run it with --steps 3 to smoke).

Examples:
  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 3
  PYTHONPATH=src python examples/train_lm.py --steps 50 --fail-at 30 \
      --ckpt-dir /tmp/ft_demo     # then re-run: it resumes from step 20

``--fabric dp4xtp2`` additionally lowers ONE step of this config onto
the network simulator (the application traffic plane, ``repro.apps``):
it prints the per-phase collective bytes and the simulated step-
communication time per transport (gleam vs the §2.3 baselines) for the
given data x model mesh, before training runs.
"""
import argparse
import re

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig
from repro.launch.mesh import single_device_mesh
from repro.runtime.train import SimulatedFailure, Trainer, TrainerConfig

PRESETS = {
    # ~7M params: d=256, 4 layers — minutes on CPU for 200 steps
    "small": dict(d_model=256, n_layers=4, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab_size=2048,
                  seq_len=128, global_batch=8),
    # ~100M params: d=768, 12 layers, GPT-2-small-ish in llama clothing
    "100m": dict(d_model=768, n_layers=12, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32000,
                 seq_len=512, global_batch=8),
}


def make_cfg(preset: dict) -> ArchConfig:
    return ArchConfig(
        name=f"train_lm_{preset['d_model']}", family="dense",
        n_layers=preset["n_layers"], d_model=preset["d_model"],
        n_heads=preset["n_heads"], n_kv_heads=preset["n_kv_heads"],
        head_dim=preset["head_dim"], d_ff=preset["d_ff"],
        vocab_size=preset["vocab_size"], q_chunk=128, kv_chunk=128,
        xent_chunk=128,
    )


def fabric_report(cfg: ArchConfig, preset: dict, spec: str) -> None:
    """Lower one training step of ``cfg`` onto the network simulator
    and print the per-transport communication step time (flow engine —
    seconds even for big meshes; see benchmarks/fig_apps.py for the
    packet-validated version of the same numbers)."""
    from repro.apps.collectives_lowering import (MeshShape,
                                                train_step_workload)
    from repro.apps.metrics import phase_stats, run_phased, step_time
    from repro.core import fattree
    from repro.core.engine import make_engine

    m = re.fullmatch(r"dp(\d+)xtp(\d+)(?:xpp(\d+))?", spec)
    if not m:
        raise SystemExit(f"--fabric wants dp<D>xtp<T>[xpp<P>], "
                         f"got {spec!r}")
    mesh = MeshShape(data=int(m.group(1)), model=int(m.group(2)),
                     pipe=int(m.group(3) or 1))
    print(f"[train_lm] fabric: one step of {cfg.name} on "
          f"{mesh.n_chips} hosts ({spec}), seq {preset['seq_len']} x "
          f"batch {preset['global_batch']}")
    for tr in ("gleam", "multiunicast", "ring", "binary-tree"):
        wl = train_step_workload(cfg, mesh, seq=preset["seq_len"],
                                 batch=preset["global_batch"],
                                 transport=tr)
        eng = make_engine("flow", fattree.testbed(n_hosts=mesh.n_chips))
        ops, recs = run_phased(eng, wl)
        phases = " ".join(
            f"{p}={s.latency * 1e6:.1f}us"
            for p, s in phase_stats(ops, recs).items())
        print(f"[train_lm] fabric {tr:>13}: step comm "
              f"{step_time(ops, recs) * 1e6:.1f}us  ({phases})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step (FT demo)")
    ap.add_argument("--grad-compression", choices=("none", "int8_ef"),
                    default="none")
    ap.add_argument("--fabric", default=None, metavar="MESH",
                    help="also lower one step onto the network "
                         "simulator on this mesh, e.g. dp4xtp2 or "
                         "dp2xtp2xpp2 (prints per-transport step-"
                         "communication time before training)")
    args = ap.parse_args()

    preset = PRESETS[args.preset]
    cfg = make_cfg(preset)
    mesh = single_device_mesh()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=preset["seq_len"],
                    global_batch=preset["global_batch"], seed=0)
    tc = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=10,
        grad_compression=args.grad_compression,
        fail_at_steps=(args.fail_at,) if args.fail_at else ())

    from repro.models.blocks import count_params
    from repro.models.model import model_defs
    n = count_params(model_defs(cfg))
    print(f"[train_lm] {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {preset['global_batch']} x "
          f"seq {preset['seq_len']}")

    if args.fabric:
        fabric_report(cfg, preset, args.fabric)

    trainer = Trainer(cfg, mesh, dc, tc)
    try:
        out = trainer.run()
    except SimulatedFailure as e:
        print(f"[train_lm] {e} — re-run the same command to resume "
              f"from the latest checkpoint")
        return
    first = out["history"][0]["loss"]
    print(f"[train_lm] done: loss {first:.4f} -> "
          f"{out['final_loss']:.4f} over {len(out['history'])} steps; "
          f"{len(out['stragglers'])} straggler steps flagged")


if __name__ == "__main__":
    main()
