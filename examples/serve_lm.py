"""Serving driver: continuous-batching server over a smoke-size model.

Submits a Poisson-ish trickle of requests with ragged prompt lengths and
drains them through the shared decode pool, printing throughput and the
batching efficiency (steps used vs sequential lower bound).

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --pool 4

``--fabric 4x2`` (replicas x tensor-parallel) additionally drives the
network simulator with the same arch under an open-loop Poisson load
(``repro.apps.traffic``) and prints offered vs achieved QPS with
p50/p99/p999 request latency per transport, before the real server
runs.  ``--rate`` sets the offered load for that projection.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import single_device_mesh
from repro.models.blocks import init_params
from repro.models.model import model_defs
from repro.runtime.serve import Server


def fabric_report(cfg, spec: str, rate: float, n: int,
                  max_new: int) -> None:
    """Project serving tails on the network simulator: open-loop
    Poisson arrivals onto ``replicas x tp`` fabric hosts, per
    transport (flow engine; benchmarks/fig_apps.py packet-validates
    the same generator)."""
    from repro.apps.traffic import ArrivalSpec, ServingGenerator
    from repro.core import fattree
    from repro.core.engine import make_engine

    try:
        n_replicas, tp = (int(x) for x in spec.split("x"))
    except ValueError:
        raise SystemExit(f"--fabric wants <replicas>x<tp>, got {spec!r}")
    print(f"[serve_lm] fabric: {n_replicas} replicas x tp{tp}, "
          f"Poisson {rate:.0f} req/s, {n} requests")
    arr = ArrivalSpec(rate=rate, n=n, seed=0)
    for tr in ("gleam", "multiunicast", "ring", "binary-tree"):
        gen = ServingGenerator(cfg, n_replicas, tp, prompt_len=64,
                               decode_len=max_new,
                               kv_replicas=min(2, n_replicas - 1),
                               transport=tr)
        eng = make_engine("flow",
                          fattree.testbed(n_hosts=n_replicas * tp))
        rep = gen.run(eng, arr)
        q = rep.quantiles
        print(f"[serve_lm] fabric {tr:>13}: achieved "
              f"{rep.achieved_qps:.0f}/{rep.offered_qps:.0f} qps, "
              f"p50 {q['p50'] * 1e6:.1f}us p99 {q['p99'] * 1e6:.1f}us "
              f"p999 {q['p999'] * 1e6:.1f}us")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--pool", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fabric", default=None, metavar="RxTP",
                    help="also project serving QPS/tails on the network "
                         "simulator with this layout, e.g. 4x2 "
                         "(replicas x tensor-parallel)")
    ap.add_argument("--rate", type=float, default=2e4,
                    help="offered load (req/s) for the --fabric "
                         "projection")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if args.fabric:
        fabric_report(cfg, args.fabric, args.rate,
                      max(args.requests, 32), args.max_new)
    mesh = single_device_mesh()
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, params, mesh, pool=args.pool, max_seq=128)

    rng = np.random.default_rng(0)
    reqs = []
    total_prompt = 0
    for i in range(args.requests):
        plen = int(rng.integers(2, 24))
        total_prompt += plen
        prompt = rng.integers(0, cfg.vocab_size, size=plen)
        reqs.append(srv.submit(prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    stats = srv.run_until_drained()
    dt = time.time() - t0

    seq_lower = total_prompt + args.requests * args.max_new
    print(f"[serve_lm] {stats.completed}/{args.requests} requests done; "
          f"{stats.tokens_generated} tokens in {dt:.1f}s "
          f"({stats.tokens_generated / dt:.1f} tok/s)")
    print(f"[serve_lm] pool steps {stats.steps} vs sequential lower "
          f"bound {seq_lower} -> batching gain "
          f"{seq_lower / stats.steps:.2f}x")
    sample = reqs[0]
    print(f"[serve_lm] request 0 continuation: {sample.out_tokens}")


if __name__ == "__main__":
    main()
