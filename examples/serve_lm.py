"""Serving driver: continuous-batching server over a smoke-size model.

Submits a Poisson-ish trickle of requests with ragged prompt lengths and
drains them through the shared decode pool, printing throughput and the
batching efficiency (steps used vs sequential lower bound).

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --pool 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import single_device_mesh
from repro.models.blocks import init_params
from repro.models.model import model_defs
from repro.runtime.serve import Server


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--pool", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    mesh = single_device_mesh()
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, params, mesh, pool=args.pool, max_seq=128)

    rng = np.random.default_rng(0)
    reqs = []
    total_prompt = 0
    for i in range(args.requests):
        plen = int(rng.integers(2, 24))
        total_prompt += plen
        prompt = rng.integers(0, cfg.vocab_size, size=plen)
        reqs.append(srv.submit(prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    stats = srv.run_until_drained()
    dt = time.time() - t0

    seq_lower = total_prompt + args.requests * args.max_new
    print(f"[serve_lm] {stats.completed}/{args.requests} requests done; "
          f"{stats.tokens_generated} tokens in {dt:.1f}s "
          f"({stats.tokens_generated / dt:.1f} tok/s)")
    print(f"[serve_lm] pool steps {stats.steps} vs sequential lower "
          f"bound {seq_lower} -> batching gain "
          f"{seq_lower / stats.steps:.2f}x")
    sample = reqs[0]
    print(f"[serve_lm] request 0 continuation: {sample.out_tokens}")


if __name__ == "__main__":
    main()
