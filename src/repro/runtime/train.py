"""Fault-tolerant training runtime.

Production-shape loop (DESIGN.md §2.4):
- **checkpoint/restart** — periodic async sharded snapshots (params + opt
  + data step); ``Trainer.run`` resumes from the latest committed
  checkpoint after any crash, replaying the data stream deterministically.
- **failure injection** — ``FailureInjector`` raises ``SimulatedFailure``
  at configured steps; the integration test kills and restarts training
  mid-run and asserts bit-exact convergence with an uninterrupted run.
- **straggler mitigation** — per-step wall-time EWMA + deviation detector
  (the CNP-filtering analogue: pace by the most congested participant);
  flagged steps are logged and surfaced in metrics.  On a real pod this
  feeds the re-mesh decision (drop/replace the slow host).
- **gradient compression** — optional int8 quantization with error
  feedback around the DP gradient reduce (1-bit/8-bit Adam family);
  the residual buffer keeps the quantization error, making compression
  lossless in expectation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.sharded import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch import steps as steps_mod
from repro.models import model as mdl
from repro.models.blocks import init_params, param_shardings
from repro.optim import adamw
from repro.parallel.sharding import ShardingPlan


class SimulatedFailure(RuntimeError):
    """Injected node failure (testing the restart path)."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class StragglerDetector:
    """EWMA step-time monitor: a step slower than mean + k*dev is a
    straggler signal (the §3.5 'most congested path' filter, applied to
    participants instead of links)."""

    def __init__(self, alpha: float = 0.2, k: float = 3.0, warmup: int = 5):
        self.alpha = alpha
        self.k = k
        self.warmup = warmup
        self.mean = 0.0
        self.dev = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            self.dev = max(self.dev, abs(dt - self.mean))
            return False
        is_straggler = dt > self.mean + self.k * max(self.dev, 1e-9)
        self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        self.dev = (1 - self.alpha) * self.dev + self.alpha * abs(
            dt - self.mean)
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


# ------------------------------------------------- gradient compression

def int8_compress(g, scale_dtype=jnp.float32):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = (amax / 127.0).astype(scale_dtype)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_grads(grads, error):
    """Error-feedback int8 round trip: returns (g_hat, new_error).

    On the wire, `q` (1 byte/param) is what the DP reduce moves — 4x less
    than f32 — at the cost of the quantization noise, which the error
    buffer re-injects next step (EF-SGD / 1-bit Adam)."""
    def one(g, e):
        target = g + e
        q, s = int8_compress(target)
        g_hat = int8_decompress(q, s)
        return g_hat, target - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


# ------------------------------------------------------------- trainer

@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    accum_steps: int = 1
    grad_compression: str = "none"        # none | int8_ef
    log_every: int = 10
    seed: int = 0
    fail_at_steps: tuple = ()


class Trainer:
    def __init__(self, cfg: ArchConfig, mesh, data_cfg: DataConfig,
                 tcfg: TrainerConfig, opt_cfg: adamw.AdamWConfig | None = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.mesh = mesh
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.pipeline = Pipeline(data_cfg)
        self.log = log
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.injector = FailureInjector(tcfg.fail_at_steps)
        self.straggler = StragglerDetector()
        self.defs = mdl.model_defs(cfg)
        plan = ShardingPlan(mesh)
        self.shardings = param_shardings(self.defs, plan)
        self._build_step()
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: list[dict] = []

    # ------------------------------------------------------------ build

    def _build_step(self):
        base = steps_mod.make_train_step(
            self.cfg, self.mesh, self.opt_cfg,
            accum_steps=self.tcfg.accum_steps)
        if self.tcfg.grad_compression == "none":
            def step_fn(params, opt_state, err, batch):
                p, o, m = base(params, opt_state, batch)
                return p, o, err, m
        else:
            opt_cfg, cfg, mesh = self.opt_cfg, self.cfg, self.mesh
            accum = self.tcfg.accum_steps

            def step_fn(params, opt_state, err, batch):
                (_, metrics), grads = jax.value_and_grad(
                    mdl.loss_fn, has_aux=True)(params, batch, cfg, mesh)
                grads, err = compressed_grads(grads, err)
                params, opt_state, om = adamw.apply(
                    opt_cfg, params, opt_state, grads)
                return params, opt_state, err, {**metrics, **om}
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------ state

    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        with self.mesh:
            self.params = init_params(self.defs, key)
            self.opt_state = adamw.init(self.params)
            self.err = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), self.params) \
                if self.tcfg.grad_compression != "none" else {}
        self.step = 0

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "err": self.err}

    def maybe_restore(self) -> bool:
        """Restore the latest committed checkpoint if one exists."""
        if self.ckpt.latest_step() is None:
            return False
        if self.params is None:
            self.init_state()
        tree, step, meta = self.ckpt.restore(self._state_tree())
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.err = tree["err"]
        self.step = step
        self.log(f"[trainer] restored step {step} "
                 f"(loss was {meta.get('loss'):.4f})")
        return True

    # ------------------------------------------------------------- run

    def run(self, *, resume: bool = True) -> dict:
        if not (resume and self.maybe_restore()):
            if self.params is None:
                self.init_state()
        t = self.tcfg
        while self.step < t.total_steps:
            self.injector.check(self.step)
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch_at(self.step).items()}
            t0 = time.time()
            with self.mesh:
                self.params, self.opt_state, self.err, metrics = \
                    self.step_fn(self.params, self.opt_state, self.err,
                                 batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            slow = self.straggler.observe(self.step, dt)
            self.history.append({"step": self.step, "loss": loss,
                                 "dt": dt, "straggler": slow})
            if self.step % t.log_every == 0:
                self.log(f"[trainer] step {self.step} loss {loss:.4f} "
                         f"({dt * 1e3:.0f} ms{' STRAGGLER' if slow else ''})")
            self.step += 1
            if self.step % t.ckpt_every == 0 or self.step == t.total_steps:
                self.ckpt.save(self.step, self._state_tree(),
                               meta={"loss": loss})
        self.ckpt.wait()
        return {"final_loss": self.history[-1]["loss"],
                "history": self.history,
                "stragglers": self.straggler.flagged}
