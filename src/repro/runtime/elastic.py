"""Elastic scaling: re-mesh a running job onto a different device set.

The Gleam mapping (DESIGN.md §2.2): group membership change = envelope
re-registration (Appendix A).  Losing a pod (N -> N-1) or gaining one is
a control-plane event; the data plane (the jitted step) is rebuilt against
the new mesh while the *logical* state is untouched:

    1. snapshot logical state (full arrays — CheckpointManager layout);
    2. build the new mesh + sharding plan (re-registration);
    3. device_put every leaf with its new NamedSharding;
    4. re-jit the step functions for the new mesh.

``remesh_tree`` is the core primitive; ``ElasticGroup`` wraps the
registry bookkeeping (who is in the group, which registration epoch).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.models.blocks import param_shardings
from repro.parallel.sharding import ShardingPlan


def remesh_tree(tree, defs, new_mesh):
    """Reshard a param-shaped pytree onto `new_mesh` (elastic restore)."""
    plan = ShardingPlan(new_mesh)
    shardings = param_shardings(defs, plan)
    return jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s), tree, shardings)


@dataclasses.dataclass
class Member:
    name: str
    healthy: bool = True


class ElasticGroup:
    """Membership registry for one logical training/serving group.

    Mirrors the paper's centralized registration: a master (this object)
    collects member states, assigns the epoch, and every re-registration
    bumps it — stale members (old epoch) are fenced out, the analogue of
    PSN resync on source switching (Appendix B)."""

    def __init__(self, members):
        self.members = {m: Member(m) for m in members}
        self.epoch = 0
        self.log: list = []

    def active(self):
        return [m.name for m in self.members.values() if m.healthy]

    def fail(self, name: str):
        self.members[name].healthy = False
        self.epoch += 1
        self.log.append(("fail", name, self.epoch))

    def join(self, name: str):
        self.members[name] = Member(name)
        self.epoch += 1
        self.log.append(("join", name, self.epoch))

    def is_current(self, epoch: int) -> bool:
        """Fencing: actions from older epochs are rejected."""
        return epoch == self.epoch
