"""Batched serving runtime with continuous batching.

The server owns a fixed pool of B cache slots (the decode batch).  Each
request occupies one slot; prefill feeds prompt tokens through the decode
path at the slot's own position (per-row positions — cache_insert /
decode_attn_core accept a (B,) step vector on single-shard-KV meshes).
Slots complete independently (EOS or max_new_tokens) and are immediately
recycled for queued requests — iteration-level (continuous) batching.

This is the storage-replication analogue's serving side: one shared
jitted step serves the whole pool; admission is the only Python-side
logic.  On multi-device meshes with sharded KV the pool decodes with a
synchronized position (documented limitation — per-row insert into a
sequence-sharded cache needs a scatter collective the Gleam layer does
not model).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as mdl


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServerStats:
    admitted: int = 0
    completed: int = 0
    steps: int = 0
    tokens_generated: int = 0


class Server:
    def __init__(self, cfg: ArchConfig, params, mesh, *, pool: int = 4,
                 max_seq: int = 256,
                 sampler: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.pool = pool
        self.max_seq = max_seq
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self.caches = mdl.init_caches(cfg, pool, max_seq)
        self.pos = np.zeros(pool, np.int32)          # next cache slot/row
        self.active: list[Optional[Request]] = [None] * pool
        self.queue: deque[Request] = deque()
        self.stats = ServerStats()
        self._rid = 0
        self._pending: list[list[int]] = [[] for _ in range(pool)]

        def step_fn(params, caches, tokens, pos):
            return mdl.decode_forward(params, caches, tokens, pos, cfg,
                                      mesh, batch_shardable=False)

        self._step = jax.jit(step_fn, donate_argnums=(1,))

    # ---------------------------------------------------------- admission

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: int = -1) -> Request:
        r = Request(self._rid, np.asarray(prompt, np.int32),
                    max_new_tokens, eos_id)
        self._rid += 1
        self.queue.append(r)
        return r

    def _admit(self):
        for slot in range(self.pool):
            if self.active[slot] is None and self.queue:
                r = self.queue.popleft()
                self.active[slot] = r
                self.pos[slot] = 0
                self._pending[slot] = list(r.prompt)
                self.stats.admitted += 1

    # ------------------------------------------------------------- step

    def step(self) -> bool:
        """One pool-wide decode step. Returns True if any work was done."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        tokens = np.zeros((self.pool, 1), np.int32)
        for slot, r in enumerate(self.active):
            if r is None:
                continue
            if self._pending[slot]:
                tokens[slot, 0] = self._pending[slot][0]
            else:
                tokens[slot, 0] = r.out_tokens[-1]
        with self.mesh:
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(self.pos))
        nxt = np.asarray(self.sampler(logits[:, 0, :]))
        self.stats.steps += 1
        for slot, r in enumerate(self.active):
            if r is None:
                continue
            self.pos[slot] += 1
            if self._pending[slot]:
                self._pending[slot].pop(0)
                if self._pending[slot]:
                    continue                      # still prefilling
            # generating: the model's next-token prediction
            r.out_tokens.append(int(nxt[slot]))
            self.stats.tokens_generated += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or r.out_tokens[-1] == r.eos_id
                    or self.pos[slot] >= self.max_seq - 1):
                r.done = True
                self.stats.completed += 1
                self.active[slot] = None          # recycle the slot
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> ServerStats:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.stats
