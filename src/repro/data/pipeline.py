"""Tokenized data pipeline: synthetic + file-backed, shard-aware,
deterministically resumable.

Design constraints from the runtime (DESIGN.md §2.4):
- **shard-aware** — every data-parallel replica draws a disjoint slice of
  each global batch; slicing is by (replica_id, n_replicas) so the same
  code runs 1-host CPU tests and 512-chip pods.
- **resumable** — batch t is a pure function of (seed, t): restarting from
  a checkpoint at step t replays the exact stream with no state file.
- **loss-masked LM format** — each item is (tokens, targets, loss_mask)
  with targets = tokens shifted left (next-token prediction).
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: str | None = None         # None -> synthetic stream
    n_replicas: int = 1
    replica_id: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_replicas == 0, (
            self.global_batch, self.n_replicas)
        return self.global_batch // self.n_replicas


class TokenSource:
    """Source of raw token rows (global_batch, seq_len + 1)."""

    def global_rows(self, step: int, cfg: DataConfig) -> np.ndarray:
        raise NotImplementedError


class SyntheticSource(TokenSource):
    """Deterministic synthetic LM stream: Zipf-ish unigram draw mixed with
    a copy pattern so models have something learnable."""

    def global_rows(self, step: int, cfg: DataConfig) -> np.ndarray:
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len + 1
        # Zipf-like unigram distribution (heavy head, long tail)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(cfg.vocab_size, size=(b, s), p=probs)
        # learnable structure: every 2nd half-row copies the 1st half
        half = s // 2
        toks[:, half:2 * half] = toks[:, :half]
        return toks.astype(np.int32)


class FileSource(TokenSource):
    """Memory-mapped flat int32 token file; rows are strided windows.

    The file is one long token stream (np.int32).  Batch t takes rows at
    deterministic offsets derived from (seed, t) — random access keeps
    resume O(1) regardless of corpus position.
    """

    def __init__(self, path: str | pathlib.Path):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")

    def global_rows(self, step: int, cfg: DataConfig) -> np.ndarray:
        n = len(self.tokens)
        s = cfg.seq_len + 1
        assert n >= s, f"corpus ({n} tokens) shorter than seq_len+1 ({s})"
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, n - s, size=cfg.global_batch)
        return np.stack([self.tokens[st:st + s] for st in starts]) \
            .astype(np.int32)


def write_token_file(path, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)


class Pipeline:
    """Shard-aware iterator of LM batches."""

    def __init__(self, cfg: DataConfig, source: TokenSource | None = None):
        self.cfg = cfg
        self.source = source or (
            FileSource(cfg.path) if cfg.path else SyntheticSource())

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rows = self.source.global_rows(step, cfg)      # (B, S+1)
        lo = cfg.replica_id * cfg.local_batch
        rows = rows[lo:lo + cfg.local_batch]
        return {
            "tokens": rows[:, :-1],
            "targets": rows[:, 1:].astype(np.int32),
            "loss_mask": np.ones((cfg.local_batch, cfg.seq_len),
                                 np.float32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
