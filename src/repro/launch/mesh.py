"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the single real CPU device and build
small meshes via ``make_mesh`` below.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh over however many devices are available (tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def single_device_mesh() -> Mesh:
    """1x1 (data, model) mesh on the first device, for smoke tests.

    All sharding rules resolve to no-op specs; the same model / step code
    paths run unchanged.
    """
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))
