"""Training launcher: config-driven entry point over the FT runtime.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --smoke --steps 50 --ckpt-dir /tmp/run1

Re-running the same command resumes from the latest checkpoint.
"""
from __future__ import annotations

import argparse

from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import single_device_mesh
from repro.runtime.train import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8_ef"))
    ap.add_argument("--data", default=None,
                    help="token file (int32); default synthetic")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = single_device_mesh()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, path=args.data)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, accum_steps=args.accum,
                       grad_compression=args.grad_compression)
    out = Trainer(cfg, mesh, dc, tc).run()
    print(f"[launch.train] final loss {out['final_loss']:.4f}; "
          f"{len(out['stragglers'])} stragglers flagged")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
