"""Step functions + abstract input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for everything a step consumes; the
dry-run lowers against them.  ``train_4k``/``prefill_32k`` lower
``train_step``/``prefill_step``; ``decode_32k``/``long_500k`` lower
``serve_step`` (one new token against a seq_len KV cache).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as mdl
from repro.models.blocks import param_shardings, param_structs, count_params
from repro.optim import adamw
from repro.parallel.sharding import ShardingPlan

SHAPE_TABLE = {
    "train_4k": dict(seq=4096, batch=256, kind="train", accum=8),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def shape_runnable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §3)."""
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: long_500k skipped "
                       "(DESIGN.md §3)")
    return True, ""


def _bspec(mesh):
    bs = tuple(a for a in mdl.BATCH_AXES if a in mesh.axis_names
               and mesh.shape[a] > 1)
    return bs if len(bs) > 1 else (bs[0] if bs else None)


def _batch_shardable(mesh, batch):
    n = 1
    for a in mdl.BATCH_AXES:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return batch % n == 0 and n > 1


def batch_structs(cfg: ArchConfig, seq: int, batch: int, *, train: bool):
    """Token batch (+ modality stubs) as ShapeDtypeStructs."""
    s_text = seq - cfg.vision_prefix if cfg.vision_prefix else seq
    out: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, s_text), jnp.int32)}
    if train:
        out["targets"] = jax.ShapeDtypeStruct((batch, s_text), jnp.int32)
        out["loss_mask"] = jax.ShapeDtypeStruct((batch, s_text),
                                                jnp.float32)
    if cfg.vision_prefix:
        out["vision_embed"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_prefix, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers > 0:
        enc_len = max(seq // max(cfg.audio_stride, 1), 8)
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, enc_len, cfg.d_model), jnp.bfloat16)
    return out


def batch_shardings(cfg, structs, mesh):
    bspec = _bspec(mesh)
    out = {}
    for k, v in structs.items():
        spec = P(bspec, *([None] * (len(v.shape) - 1)))
        out[k] = NamedSharding(mesh, spec)
    return out


# ---------------------------------------------------------------- steps

def make_train_step(cfg: ArchConfig, mesh, opt_cfg=None, accum_steps=1):
    """Train step with gradient-accumulation microbatching.

    accum_steps > 1 scans over microbatches accumulating f32 grads; peak
    activation memory scales 1/accum (the §Perf memory lever for the 1M-
    token train_4k shape).
    """
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def grad_fn(params, mb):
        return jax.value_and_grad(mdl.loss_fn, has_aux=True)(
            params, mb, cfg, mesh)

    def train_step(params, opt_state, batch):
        if accum_steps <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps)
                                    + a.shape[1:]), batch)

            def body(carry, mb):
                gsum, lsum, asum = carry
                (_, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + m["loss"], asum + m["aux_loss"]), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum, asum), _ = jax.lax.scan(
                body, (gzero, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = lsum / accum_steps
            metrics = {"loss": loss, "aux_loss": asum / accum_steps,
                       "perplexity": jnp.exp(jnp.clip(loss, max=20.0))}
        params, opt_state, opt_metrics = adamw.apply(
            opt_cfg, params, opt_state, grads)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh):
    """Prefill returns ONLY the last position's logits (what serving needs
    to start decoding) — materializing (B, 32k, 150k-vocab) logits would
    be a pointless multi-GB buffer (§Perf, iteration 1)."""

    def prefill_step(params, batch):
        x, _ = mdl.forward_hidden(params, batch, cfg, mesh)
        cd = jnp.dtype(cfg.compute_dtype)
        last = x[:, -1:]
        logits = jnp.einsum("bsd,dv->bsv", last.astype(cd),
                            params["lm_head"].astype(cd))
        return logits.astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh, batch_shardable: bool):
    def serve_step(params, caches, tokens, step):
        logits, caches = mdl.decode_forward(
            params, caches, tokens, step, cfg, mesh,
            batch_shardable=batch_shardable)
        return logits, caches

    return serve_step


# ---------------------------------------------------------------- dry-run

@dataclasses.dataclass
class LoweringSpec:
    """Everything jit().lower() needs for one (arch x shape x mesh) cell."""
    fn: Any
    args: tuple                  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    n_params: int
    kind: str


def lowering_spec(cfg: ArchConfig, shape_name: str, mesh,
                  include_opt: bool = True) -> LoweringSpec:
    info = SHAPE_TABLE[shape_name]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    plan = ShardingPlan(mesh)
    defs = mdl.model_defs(cfg)
    p_structs = param_structs(defs)
    p_shard = param_shardings(defs, plan)
    n_params = count_params(defs)

    if kind == "train":
        bs = batch_structs(cfg, seq, batch, train=True)
        bshard = batch_shardings(cfg, bs, mesh)
        opt_structs = {"m": p_structs, "v": p_structs,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": NamedSharding(mesh, P())}
        accum = cfg.accum_steps or info.get("accum", 1)
        # microbatches must still shard over the batch axes (pod x data)
        ways = 1
        for a in mdl.BATCH_AXES:
            if a in mesh.axis_names:
                ways *= mesh.shape[a]
        max_accum = max(batch // ways, 1) if batch % ways == 0 else batch
        accum = min(accum, max_accum, batch)
        while batch % accum:
            accum -= 1
        fn = make_train_step(cfg, mesh, accum_steps=accum)
        return LoweringSpec(
            fn=fn, args=(p_structs, opt_structs, bs),
            in_shardings=(p_shard, opt_shard, bshard),
            out_shardings=(p_shard, opt_shard,
                           jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                        _metric_tree())),
            donate_argnums=(0, 1), n_params=n_params, kind=kind)

    if kind == "prefill":
        bs = batch_structs(cfg, seq, batch, train=False)
        bshard = batch_shardings(cfg, bs, mesh)
        fn = make_prefill_step(cfg, mesh)
        bspec = _bspec(mesh)
        out_sh = NamedSharding(mesh, P(bspec, None, None))
        return LoweringSpec(
            fn=fn, args=(p_structs, bs), in_shardings=(p_shard, bshard),
            out_shardings=out_sh, donate_argnums=(), n_params=n_params,
            kind=kind)

    # decode — inference sharding (§Perf, decode iteration 1):
    # bf16 weights; when bf16-params / TP-degree fit the HBM budget,
    # drop the FSDP axes entirely (pure TP) so NO weight gathers happen
    # per decoded token.  Archs too large for that (qwen3-235b,
    # qwen1.5-110b) keep ZeRO sharding + per-step gathers (the honest
    # cost; production answer is pipeline stages, see DESIGN.md).
    from repro.parallel.sharding import INFERENCE_RULES
    tp = mesh.shape["model"]
    fits_tp = n_params * 2 / tp <= 8e9
    if fits_tp:
        cfg = cfg.replace(fsdp_weights=False)
        plan = ShardingPlan(mesh, rules=INFERENCE_RULES)
    p_structs = param_structs(defs, dtype=jnp.bfloat16)
    p_shard = param_shardings(defs, plan)
    shardable = _batch_shardable(mesh, batch)
    cache_structs = mdl.init_caches(cfg, batch, seq, abstract=True)
    cspec = mdl.cache_specs(cfg, batch, seq, mesh, shardable)
    cshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cspec,
                          is_leaf=lambda x: isinstance(x, P))
    bspec = _bspec(mesh) if shardable else None
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, P(bspec, None))
    step_struct = jax.ShapeDtypeStruct((), jnp.int32)
    step_shard = NamedSharding(mesh, P())
    fn = make_serve_step(cfg, mesh, shardable)
    logits_shard = NamedSharding(mesh, P(bspec, None, None))
    return LoweringSpec(
        fn=fn, args=(p_structs, cache_structs, tok, step_struct),
        in_shardings=(p_shard, cshard, tok_shard, step_shard),
        out_shardings=(logits_shard, cshard),
        donate_argnums=(1,), n_params=n_params, kind=kind)


def _metric_tree():
    return {"loss": 0.0, "aux_loss": 0.0, "perplexity": 0.0,
            "grad_norm": 0.0, "lr": 0.0}


def lower_cell(cfg: ArchConfig, shape_name: str, mesh):
    spec = lowering_spec(cfg, shape_name, mesh)
    jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                     out_shardings=spec.out_shardings,
                     donate_argnums=spec.donate_argnums)
    with mesh:
        lowered = jitted.lower(*spec.args)
    return lowered, spec


def input_specs(cfg: ArchConfig, shape_name: str, mesh=None):
    """ShapeDtypeStruct stand-ins for every input of the cell's step —
    weak-type-correct, shardable, no device allocation (the multi-pod
    dry-run contract).  Returns the positional arg tuple for the step
    returned by ``lowering_spec(...).fn``."""
    from repro.launch.mesh import single_device_mesh
    mesh = mesh or single_device_mesh()
    return lowering_spec(cfg, shape_name, mesh).args
