"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s        (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw             (819 GB/s)
    collective = collective_bytes_per_device / link_bw     (~50 GB/s/link)

``cost_analysis()`` supplies flops / bytes for the per-device module.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO
(compiled.as_text()) and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per training step
(3x fwd matmul flops for fwd+bwd), divided by chips for the per-device
comparison with HLO_FLOPs (catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12          # bf16 per chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array shape in an HLO type string
    (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # pattern:  %name = TYPE all-gather(...)  /  ... all-gather-start(
        m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)",
                     line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        out[base] += _shape_bytes(type_str)
        counts[base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_detail: dict
    model_flops_per_device: float
    memory_stats: dict

    @property
    def t_compute(self):
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes_per_device / LINK_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        if self.flops_per_device == 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self):
        """Useful-compute time over the dominant term: how close the step
        is to the compute roofline if the bottleneck were removed."""
        t_star = self.model_flops_per_device / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / t_bound if t_bound > 0 else 0.0

    def to_dict(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_detail": self.coll_detail,
            "model_flops_per_device": self.model_flops_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_stats": self.memory_stats,
        }


def model_flops(cfg, shape_info, n_params_total: int, n_chips: int) -> float:
    """6*N*D for train, 2*N*D for prefill, 2*N*1 token for decode —
    active-params for MoE."""
    n = n_params_total
    if cfg.n_experts and cfg.top_k:
        # experts contribute top_k/n_experts of their params per token
        from repro.models import moe as moe_mod
        from repro.models.blocks import count_params
        e_params = count_params(moe_mod.moe_defs(cfg)) - (
            cfg.d_model * cfg.n_experts)  # router excluded
        moe_layers = sum(1 for _, f in cfg.pattern if f == "moe")
        e_total = e_params * cfg.n_blocks * moe_layers / max(
            sum(1 for _ in cfg.pattern), 1) * len(cfg.pattern)
        # count_params(moe_defs) is per layer; total expert params:
        e_total = e_params * cfg.n_blocks * sum(
            1 for _, f in cfg.pattern if f == "moe")
        n = n - e_total + e_total * cfg.top_k / cfg.n_experts
    seq, batch, kind = (shape_info["seq"], shape_info["batch"],
                        shape_info["kind"])
    if kind == "train":
        d = seq * batch
        f = 6.0 * n * d
    elif kind == "prefill":
        d = seq * batch
        f = 2.0 * n * d
    else:  # decode: one token per sequence
        f = 2.0 * n * batch
    return f / n_chips


def summarize(compiled, lowered_text_or_none, cfg, shape_name, shape_info,
              mesh_name, n_chips, n_params) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        memory_stats = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        memory_stats = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return Roofline(
        arch=cfg.name, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=float(coll["total_bytes"]),
        coll_detail=coll,
        model_flops_per_device=model_flops(cfg, shape_info, n_params,
                                           n_chips),
        memory_stats=memory_stats)
