import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell, ``jit(step).lower()``
against ShapeDtypeStruct stand-ins and ``.compile()`` on the production
mesh — 16x16 (single pod, 256 chips) and 2x16x16 (two pods, 512 chips).
No arrays are allocated: success proves the sharding rules, collective
schedule, and memory plan are consistent; ``memory_analysis()`` proves the
model fits; ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
    python -m repro.launch.dryrun --arch mixtral_8x7b --shape train_4k \
        --mesh pod --out results/dryrun
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Each cell writes one JSON file; failures are recorded with the exception
text so the sweep is restartable and auditable (EXPERIMENTS.md §Dry-run).
"""
import argparse
import json
import pathlib
import sys
import time
import traceback


def _probe_cfg(cfg, k: int, seq: int):
    """k-block unrolled probe config for scan-aware cost extrapolation.

    XLA's cost_analysis counts a while-loop (lax.scan) body ONCE, not
    x trip-count (verified experimentally — see EXPERIMENTS.md §Roofline
    methodology).  We therefore lower two UNROLLED probes (1 and 2 blocks,
    every internal scan disabled: xent in one chunk, dense attention,
    accum=1) and extrapolate linearly:

        term(n_blocks) = probe1 + (n_blocks - 1) * (probe2 - probe1)

    Memory analysis still comes from the real scanned module.
    """
    per_block_enc = cfg.enc_layers // cfg.n_blocks if cfg.enc_layers else 0
    return cfg.replace(
        n_layers=k * len(cfg.pattern),
        enc_layers=k * per_block_enc,
        scan_layers=False,
        xent_chunk=seq,
        kv_chunk=max(seq, cfg.kv_chunk),
        accum_steps=1,
    )


def probe_terms(cfg, shape: str, mesh) -> dict:
    """(flops, bytes, collective bytes) extrapolated from 2 probes."""
    from repro.launch import steps
    from repro.launch.roofline import collective_bytes

    seq = steps.SHAPE_TABLE[shape]["seq"]
    vals = []
    for k in (1, 2):
        pcfg = _probe_cfg(cfg, k, seq)
        lowered, _ = steps.lower_cell(pcfg, shape, mesh)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else (cost or {})
        coll = collective_bytes(compiled.as_text())
        vals.append({"flops": float(cost.get("flops", 0.0)),
                     "bytes": float(cost.get("bytes accessed", 0.0)),
                     "coll": float(coll["total_bytes"]),
                     "coll_detail": coll})
    nb = cfg.n_blocks
    out = {}
    for key in ("flops", "bytes", "coll"):
        p1, p2 = vals[0][key], vals[1][key]
        out[key] = p1 + (nb - 1) * (p2 - p1)
    out["probe1"] = vals[0]
    out["probe2"] = vals[1]
    # per-kind collective bytes, same linear fit
    d1 = vals[0]["coll_detail"]["bytes"]
    d2 = vals[1]["coll_detail"]["bytes"]
    out["coll_by_kind"] = {
        k: d1[k] + (nb - 1) * (d2[k] - d1[k]) for k in d1}
    return out


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: pathlib.Path,
             *, schedule: str | None = None, overrides: dict | None = None,
             tag: str = "") -> dict:
    # imports deferred: XLA_FLAGS must be set before jax initializes
    from repro.configs.base import get_config
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import summarize

    cfg = get_config(arch)
    if schedule:
        cfg = cfg.replace(collective_schedule=schedule)
    if overrides:
        cfg = cfg.replace(**overrides)
    suffix = f"-{tag}" if tag else ""
    cell_id = f"{arch}-{shape}-{mesh_name}{suffix}"
    out_path = out_dir / f"{cell_id}.json"
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "tag": tag, "status": "running"}

    ok, why = steps.shape_runnable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] {cell_id}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_chips = mesh.devices.size
    try:
        t0 = time.time()
        lowered, spec = steps.lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        print(f"[dryrun] {cell_id}: memory_analysis: {mem}")
        cost = compiled.cost_analysis()
        cost_d = cost[0] if isinstance(cost, list) else (cost or {})
        print(f"[dryrun] {cell_id}: cost_analysis flops="
              f"{cost_d.get('flops', 0):.3e} bytes="
              f"{cost_d.get('bytes accessed', 0):.3e}")
        rl = summarize(compiled, None, cfg, shape,
                       steps.SHAPE_TABLE[shape], mesh_name, n_chips,
                       spec.n_params)
        t0 = time.time()
        probes = probe_terms(cfg, shape, mesh)
        t_probe = time.time() - t0
        rl.flops_per_device = probes["flops"]
        rl.bytes_per_device = probes["bytes"]
        rl.coll_bytes_per_device = probes["coll"]
        rl.coll_detail = {"bytes": probes["coll_by_kind"],
                          "fit": {"probe1": probes["probe1"],
                                  "probe2": probes["probe2"]}}
        rec.update(status="ok", t_lower_s=t_lower, t_compile_s=t_compile,
                   t_probe_s=t_probe, n_params=spec.n_params,
                   kind=spec.kind, roofline=rl.to_dict())
        print(f"[dryrun] {cell_id}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s bottleneck={rl.bottleneck} "
              f"frac={rl.roofline_fraction:.3f}")
    except Exception as e:  # noqa: BLE001 — sweep must survive any cell
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {cell_id}: FAIL {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    from repro.configs.base import ARCH_IDS
    from repro.launch.steps import SHAPE_TABLE

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPE_TABLE))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape)")
    ap.add_argument("--schedule", default=None,
                    help="override cfg.collective_schedule")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="", help="suffix for output files")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells whose JSON already says ok/skipped")
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPE_TABLE]
             if args.all else [(args.arch, args.shape)])
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    n_fail = 0
    for arch, shape in cells:
        if arch is None or shape is None:
            ap.error("--arch/--shape required unless --all")
        for m in meshes:
            suffix = f"-{args.tag}" if args.tag else ""
            f = out_dir / f"{arch}-{shape}-{m}{suffix}.json"
            if args.skip_done and f.exists():
                try:
                    if json.loads(f.read_text())["status"] in (
                            "ok", "skipped"):
                        continue
                except (json.JSONDecodeError, KeyError):
                    pass
            rec = run_cell(arch, shape, m, out_dir,
                           schedule=args.schedule, overrides=overrides,
                           tag=args.tag)
            n_fail += rec["status"] == "error"
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
