"""Serving launcher: continuous-batching server over a config.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b \
        --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.mesh import single_device_mesh
from repro.models.blocks import init_params
from repro.models.model import model_defs
from repro.runtime.serve import Server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pool", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = single_device_mesh()
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    srv = Server(cfg, params, mesh, pool=args.pool, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        plen = int(rng.integers(2, args.max_seq // 4))
        srv.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    stats = srv.run_until_drained()
    dt = time.time() - t0
    print(f"[launch.serve] {stats.completed} done, "
          f"{stats.tokens_generated} tokens, "
          f"{stats.tokens_generated / dt:.1f} tok/s, "
          f"{stats.steps} pool steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
