"""Sharded AdamW + cosine schedule + global-norm clipping.

Optimizer state mirrors the parameter pytree, so the parameter shardings
apply verbatim (ZeRO: the FSDP axes shard the moments too).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * jnp.clip(t, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params):
    zeros = lambda p: jnp.zeros_like(p)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, opt_state, grads):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(treedef, [n[0] for n in new])
    m = jax.tree.unflatten(treedef, [n[1] for n in new])
    v = jax.tree.unflatten(treedef, [n[2] for n in new])
    return params, {"m": m, "v": v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
