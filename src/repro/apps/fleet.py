"""Multi-tenant fleet sweep: N tenants share one fabric.

The fleet-scale sweep plane's workload half: a seeded builder that
declares N tenants' multicast groups (overlapping member sets — the
whole point of fabric sharing is that trees collide on links and MFT
slots) plus background unicast mesh / incast traffic, all as ONE
contended ``Workload``.  Per-tenant SLO metrics come from the op
records (the tenants' ops carry ``phase="tenant-XX"`` tags, background
flows ``"bg-*"``), and connection-state accounting reports what the
sharing costs in fabric state:

- **QP census** (per NIC): the packet engine counts live QPs on every
  host; the flow engines mirror the packet engine's connection reuse
  rules analytically (one multicast QP per member per DISTINCT member
  tuple, one RC pair per DISTINCT unicast (src, dst) channel) — the
  two censuses must agree exactly (tests/test_fleet.py).
- **MFT census** (per switch): the packet engine reads the real
  forwarding tables (occupancy, byte size, LRU evictions/salvages —
  ``core/ftable.py``); the flow engines derive occupancy from their
  staged multicast trees.  Exact per-switch equality is NOT promised:
  the packet control plane floods MFT state along simulated envelope
  paths, the fluid engine derives trees geometrically — the aggregate
  entry counts are comparable, the per-switch split can differ.

``run_fleet`` drives either engine and returns one plain-dict report
(benchmarks/fig_fleet.py and tools/check_fleet.py consume it).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence

from repro.apps import metrics as appm
from repro.core import ftable
from repro.core.engine import FlowEngine, make_engine
from repro.core.workload import Workload, get_transport

__all__ = ["FleetSpec", "fleet_workload", "tenant_quantiles",
           "connection_census", "mft_pressure_report", "run_fleet"]


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Seeded description of one multi-tenant scenario (plain data, so
    a sweep point is replayable from its spec alone)."""

    n_tenants: int = 4
    groups_per_tenant: int = 4
    group_size: int = 8
    nbytes: int = 1 << 20               # per multicast message
    transport: str = "gleam"
    bg_unicasts: int = 12               # background mesh RC flows
    bg_incasts: int = 2                 # background fan-ins
    bg_fan_in: int = 4                  # senders per incast
    bg_nbytes: int = 2 << 20
    seed: int = 0

    def __post_init__(self):
        if self.n_tenants < 1 or self.groups_per_tenant < 1:
            raise ValueError("need >= 1 tenant and >= 1 group each")
        if self.group_size < 2:
            raise ValueError("multicast groups need >= 2 members")

    def tenant_phase(self, t: int) -> str:
        return f"tenant-{t:02d}"


def fleet_workload(hosts: Sequence[str], spec: FleetSpec) -> Workload:
    """The N-tenant contended scenario as one Workload.

    Member sets are drawn per group from ``random.Random(spec.seed)``
    (platform-stable), so tenants' trees overlap by construction once
    ``n_tenants * groups_per_tenant * group_size`` approaches the host
    count.  Every op is static — the scenario stays cacheable by the
    staging plane, and repeated sweep passes hit."""
    hosts = list(hosts)
    if len(hosts) < max(spec.group_size, 2 + spec.bg_fan_in):
        raise ValueError(f"fleet spec needs more hosts than {len(hosts)}")
    rng = random.Random(spec.seed)
    wl = Workload(f"fleet/{spec.n_tenants}x{spec.groups_per_tenant}"
                  f"/{spec.transport}")
    for t in range(spec.n_tenants):
        phase = spec.tenant_phase(t)
        for g in range(spec.groups_per_tenant):
            members = rng.sample(hosts, spec.group_size)
            wl.bcast(members, spec.nbytes, transport=spec.transport,
                     key=t * spec.groups_per_tenant + g, phase=phase)
    for i in range(spec.bg_unicasts):
        a, b = rng.sample(hosts, 2)
        wl.unicast(a, b, spec.bg_nbytes, key=i, phase="bg-mesh")
    for i in range(spec.bg_incasts):
        picks = rng.sample(hosts, 1 + spec.bg_fan_in)
        sink, senders = picks[0], picks[1:]
        for s in senders:
            wl.unicast(s, sink, spec.bg_nbytes, key=i, phase="bg-incast")
    return wl


# ------------------------------------------------------------ SLO metrics

def tenant_quantiles(wl: Workload, recs) -> Dict[str, Dict[str, float]]:
    """Per-phase JCT quantiles: one entry per tenant + the bg phases.

    Quantiles are nearest-rank (``apps.metrics.quantile``) over the
    phase's op JCTs; ``latency`` is the phase barrier (max JCT)."""
    by_phase: Dict[str, List[float]] = {}
    for op, rec in zip(wl.ops, recs):
        by_phase.setdefault(op.phase, []).append(appm.jct(rec))
    out = {}
    for phase, lats in by_phase.items():
        q = appm.request_quantiles(lats)
        q["n_ops"] = len(lats)
        q["latency"] = q.pop("max")
        out[phase] = q
    return out


# ------------------------------------------------------ connection census

def _native_groups(wl: Workload):
    """Distinct member tuples the packet engine would register one
    multicast group for (its per-member-set group memo)."""
    seen, groups = set(), []
    for op in wl.ops:
        if op.op in ("bcast", "write") and not op.events \
                and not op.faults and get_transport(op.transport).native:
            key = tuple(op.members)
            if key not in seen:
                seen.add(key)
                groups.append(op)
    return groups


def _unicast_pairs(wl: Workload):
    """Distinct (src, dst) channels the packet engine would wire one RC
    QP pair for (its per-pair channel memo)."""
    pairs = []
    seen = set()
    for op in wl.ops:
        if op.op == "unicast":
            p = (op.members[0], op.members[1])
            if p not in seen:
                seen.add(p)
                pairs.append(p)
    return pairs


def connection_census(eng, wl: Optional[Workload] = None) -> dict:
    """Fabric connection state after a run: QPs per NIC + MFT per
    switch.

    Packet engine: measured (live ``Host.qps`` and
    ``GleamSwitch.tables``).  Flow engines: analytic from the workload
    (mirrors the packet engine's reuse rules; MFT occupancy from the
    staged multicast trees)."""
    if hasattr(eng, "net"):                       # packet: measured
        sim = eng.net.sim
        qp = {n: len(h.qps) for n, h in sim.hosts.items() if h.qps}
        switches = {}
        for name, sw in sim.switches.items():
            t = sw.tables
            if t.tables or t.evictions or t.salvages:
                switches[name] = {
                    "occupancy": len(t.tables),
                    "capacity": t.capacity,
                    "evictions": t.evictions,
                    "salvages": t.salvages,
                    "bytes": t.total_bytes(),
                    "port_peak": max(sw.port_util.values(), default=0),
                }
        return _census_report(qp, switches, measured=True)

    if wl is None:
        raise ValueError("flow-engine census needs the workload")
    assert isinstance(eng, FlowEngine)
    sim = eng._sim
    topo = eng.topo
    rev: Dict[int, tuple] = {}                 # link id -> (node, port)
    for hop, i in sim.link_id.items():
        rev[i] = hop
    switch_set = set(topo.switches)
    qp: Dict[str, int] = {}
    occ: Dict[str, int] = {}
    ebytes: Dict[str, int] = {}
    for op in _native_groups(wl):
        members = list(op.members)
        for m in members:
            qp[m] = qp.get(m, 0) + 1
        source = op.source or members[0]
        links = sim.multicast_tree_links(source, members, op.key)
        per_sw: Dict[str, int] = {}
        for i in links:
            node, port = rev[i]
            if node not in switch_set:
                continue
            # ftable model: a host-facing tree port holds a connected
            # entry, a transit port a forwarded one (+4 LRU bytes each)
            peer = topo.ports[node][port][0]
            kind = ftable.FORWARDED if peer in switch_set \
                else ftable.CONNECTED
            per_sw[node] = per_sw.get(node, 0) + \
                ftable.ENTRY_BYTES[kind] + 4
        for s, nb in per_sw.items():
            occ[s] = occ.get(s, 0) + 1
            ebytes[s] = ebytes.get(s, 0) + ftable.GROUP_BYTES + nb
    for a, b in _unicast_pairs(wl):
        qp[a] = qp.get(a, 0) + 1
        qp[b] = qp.get(b, 0) + 1
    switches = {}
    for s in sorted(occ):
        switches[s] = {"occupancy": occ[s], "capacity": None,
                       "evictions": 0, "salvages": 0,
                       "bytes": ebytes[s], "port_peak": 0}
    return _census_report(qp, switches, measured=False)


def _census_report(qp: Dict[str, int], switches: dict,
                   measured: bool) -> dict:
    return {
        "measured": measured,
        "qp_per_host": dict(sorted(qp.items())),
        "qp_total": sum(qp.values()),
        "nic_qp_peak": max(qp.values(), default=0),
        "switches": switches,
        "mft_groups_total": sum(s["occupancy"]
                                for s in switches.values()),
        "mft_bytes_total": sum(s["bytes"] for s in switches.values()),
        "mft_evictions": sum(s["evictions"] for s in switches.values()),
    }


# ------------------------------------------------------- LRU pressure

def mft_pressure_report(topo, *, n_groups: int, group_size: int,
                        capacity: int, nbytes: int = 256 << 10,
                        seed: int = 0) -> dict:
    """Registration churn against capacity-bounded switch tables.

    The LRU-pressure experiment: register ``n_groups`` multicast groups
    through the packet control plane with every switch pinned to
    ``capacity`` table slots — the deployment shape where group
    registrations outlive their tenants (``core/ftable.py``).  Old
    groups' entries get LRU-evicted as new tenants register; the most
    recent group must still be installed end to end, which the report
    proves by running one broadcast on it.  (Deliberately NOT concurrent
    traffic: evicting a group mid-stream wedges it on go-back-N retries
    until an explicit repair re-flood — tests/test_ftable.py covers that
    recovery path in isolation.)"""
    from repro.core.gleam import GleamNetwork

    rng = random.Random(seed)
    net = GleamNetwork(topo)
    for sw in net.sim.switches.values():
        sw.tables.capacity = capacity
    last = None
    for _ in range(n_groups):
        last = net.multicast_group(rng.sample(topo.hosts, group_size))
        last.register()
    t0 = net.sim.now
    rec = last.bcast(nbytes, now=t0)
    net.sim.run(until=t0 + 1.0)
    switches = {}
    for name, sw in net.sim.switches.items():
        t = sw.tables
        if t.tables or t.evictions:
            switches[name] = {"occupancy": len(t.tables),
                              "capacity": t.capacity,
                              "evictions": t.evictions,
                              "salvages": t.salvages,
                              "bytes": t.total_bytes()}
    return {
        "capacity": capacity,
        "n_groups": n_groups,
        "switches": switches,
        "evictions": sum(s["evictions"] for s in switches.values()),
        "salvages": sum(s["salvages"] for s in switches.values()),
        "occupancy_peak": max((s["occupancy"]
                               for s in switches.values()), default=0),
        "last_group_ok": bool(rec.t_sender_cqe > 0 and not rec.error
                              and len(rec.t_deliver) == group_size - 1),
        "last_group_jct": appm.jct(rec),
    }


# -------------------------------------------------------------- driver

def run_fleet(engine_name: str, topo, spec: FleetSpec,
              timeout: float = 60.0, **engine_kw) -> dict:
    """One fleet scenario end to end on the named engine.

    Returns a plain-dict report: per-tenant quantiles, connection
    census, staging-cache telemetry (flow engines), and the scenario
    makespan."""
    eng = make_engine(engine_name, topo, **engine_kw)
    wl = fleet_workload(topo.hosts, spec)
    recs = eng.run_workloads([wl], timeout=timeout)[0]
    tenants = tenant_quantiles(wl, recs)
    census = connection_census(eng, wl) if isinstance(eng, FlowEngine) \
        else connection_census(eng)
    report = {
        "engine": engine_name,
        "spec": dataclasses.asdict(spec),
        "tenants": tenants,
        "census": census,
        "makespan_s": max((r.t_sender_cqe for r in recs), default=0.0),
        "errors": sum(1 for r in recs if r.error),
    }
    if isinstance(eng, FlowEngine):
        report["staging"] = eng.staging_stats()
    return report
