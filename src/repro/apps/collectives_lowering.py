"""Lower LM training/serving steps to the Workload IR.

Everything here is *analytic* config math — no jax, no compiled HLO:
collective sizes are derived from the ``ArchConfig`` tensor shapes
(the same shapes ``models.model.model_defs`` declares; the parameter
count is cross-checked against ``blocks.count_params`` in
``tests/test_apps.py``) and a ``MeshShape``.  The sizing rules, per
phase (bf16 activations = 2 B/elem, f32 grads = 4 B/elem):

- **tp-allreduce** — every mixer (attn / mamba) and every dense FFN
  sublayer ends in a row-parallel projection whose partial sums are
  all-reduced over the ``model`` axis: one ``(batch, seq, d_model)``
  activation per sublayer unit, doubled for the backward pass in
  training.  MoE FFN sublayers count here only in *etp* mode (experts
  not divisible by the model axis — ``models.moe.expert_mode``);
- **moe-alltoall** — in *ep* mode each MoE sublayer dispatches
  ``top_k`` routed copies of every token and combines them back: an
  all-to-all, lowered as a **unicast fan-mesh** (one GroupOp per
  ordered rank pair — all pairs contend concurrently, which is what an
  a2a does to the fabric).  Per pair per a2a:
  ``tokens/ep * top_k * d_model * 2 / ep`` bytes;
- **pp-boundary** — each microbatch crosses a pipeline cut twice
  (activations fwd, activation-grads bwd): ``micro * seq * d_model *
  2`` bytes per crossing, sharded over the model axis;
- **dp-gradsync** — the optimizer all-reduces f32 gradients of this
  rank's parameter shard across the ``data`` axis:
  ``4 * n_params / (model * pipe)`` bytes;
- **weights** — replica scale-out broadcasts each rank's bf16
  parameter shard: ``2 * n_params / model`` bytes (a *bcast*, Gleam's
  native op);
- **kv-replicate / ckpt-write** — storage-style ``write`` ops sized by
  ``kv_cache_bytes`` / the f32 parameter shard.

Chip placement is linear: chip ``(pipe p, data d, model m)`` maps to
``hosts[(p*data + d)*model + m]`` — model-axis neighbours are adjacent
hosts (the bandwidth-hungriest axis gets the topologically closest
peers, the standard TPU/GPU placement).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.workload import GroupOp, Workload

__all__ = [
    "MeshShape", "default_hosts", "param_count", "kv_cache_bytes",
    "tp_allreduce_bytes", "moe_a2a_pair_bytes", "pp_boundary_bytes",
    "moe_uses_ep", "train_step_workload", "weight_bcast_workload",
    "prefill_comm_bytes", "decode_comm_bytes",
]

BF16 = 2                     # activation / weight bytes per element
F32 = 4                      # gradient / optimizer bytes per element


@dataclasses.dataclass(frozen=True)
class MeshShape:
    """Logical chip grid: ``pipe`` stages x ``data`` replicas x
    ``model`` (tensor-parallel) ranks.  Plain data — serializes into
    ``Workload.meta`` so a staged app workload is replayable."""

    data: int = 1
    model: int = 1
    pipe: int = 1

    def __post_init__(self):
        if min(self.data, self.model, self.pipe) < 1:
            raise ValueError(f"mesh axes must be >= 1, got {self}")

    @property
    def n_chips(self) -> int:
        return self.data * self.model * self.pipe

    def host(self, hosts: Sequence[str], p: int, d: int, m: int) -> str:
        return hosts[(p * self.data + d) * self.model + m]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MeshShape":
        return cls(**d)


def default_hosts(n: int) -> List[str]:
    """The flat ``h0..h{n-1}`` naming of ``fattree.testbed``."""
    return [f"h{i}" for i in range(n)]


# ------------------------------------------------------ parameter math

def _attn_params(cfg: ArchConfig) -> int:
    """Mirror of ``model._attn_defs`` (+ the sublayer norm)."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n = d + d * h * hd + 2 * d * kv * hd + h * hd * d
    if cfg.qkv_bias:
        n += h * hd + 2 * kv * hd
    return n


def _ssm_params(cfg: ArchConfig) -> int:
    """Mirror of ``ssm.ssm_defs`` (+ the sublayer norm)."""
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    h = d_in // cfg.ssm_headdim
    n, k = cfg.ssm_state, cfg.ssm_conv
    return (d                               # norm
            + 2 * d * d_in                  # wz, wx
            + 2 * d * n                     # wB, wC
            + d * h + 3 * h                 # wdt, dt_bias, A_log, D
            + k * d_in + 2 * k * n          # conv_x, conv_B, conv_C
            + d_in + d_in * d)              # gnorm, wo


def _ffn_params(cfg: ArchConfig, kind: Optional[str]) -> int:
    """Mirror of ``model._ffn_defs`` / ``moe.moe_defs``."""
    d = cfg.d_model
    if kind is None:
        return 0
    if kind == "mlp":
        return d + 3 * d * cfg.d_ff
    if kind == "moe":
        e, f = cfg.n_experts, cfg.moe_d_ff
        return d + d * e + 3 * e * d * f
    raise ValueError(kind)


def param_count(cfg: ArchConfig) -> int:
    """Total parameters, matching ``count_params(model_defs(cfg))``
    exactly for decoder-only archs (the traffic plane's scope)."""
    if cfg.enc_layers > 0 or cfg.vision_prefix > 0:
        raise ValueError(
            f"{cfg.name}: encoder/vision frontends are outside the "
            "traffic-plane lowering (decoder-only archs only)")
    per_block = 0
    for mixer, ffn in cfg.pattern:
        if mixer == "attn":
            per_block += _attn_params(cfg)
        elif mixer == "mamba":
            per_block += _ssm_params(cfg)
        else:
            raise ValueError(mixer)
        per_block += _ffn_params(cfg, ffn)
    d, v = cfg.d_model, cfg.vocab_size
    return v * d + per_block * cfg.n_blocks + d + d * v


def kv_cache_bytes(cfg: ArchConfig, seq: int) -> int:
    """Decode-state bytes of ONE sequence: bf16 K+V per attention
    sublayer, f32 SSD recurrent state + conv tail per mamba sublayer
    (sequence-length-free — the hybrid archs' point)."""
    attn = mamba = 0
    for mixer, _ in cfg.pattern:
        if mixer == "attn":
            attn += 1
        elif mixer == "mamba":
            mamba += 1
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // max(cfg.ssm_headdim, 1)
    per_attn = 2 * seq * cfg.n_kv_heads * cfg.hd * BF16
    per_mamba = (h * cfg.ssm_headdim * cfg.ssm_state
                 + (cfg.ssm_conv - 1) * d_in) * F32
    return (attn * per_attn + mamba * per_mamba) * cfg.n_blocks


# ------------------------------------------------------ collective math

def moe_uses_ep(cfg: ArchConfig, tp: int) -> bool:
    """Expert-parallel iff experts divide the model axis — the planner
    rule (``models.moe.expert_mode``, reimplemented to stay jax-free)."""
    return bool(cfg.n_experts) and tp > 1 and cfg.n_experts % tp == 0


def _sublayer_units(cfg: ArchConfig, tp: int) -> int:
    """Row-parallel reductions per block: one per mixer, one per dense
    FFN; MoE FFNs reduce via the a2a combine in ep mode."""
    ep = moe_uses_ep(cfg, tp)
    units = 0
    for _, ffn in cfg.pattern:
        units += 1                                  # the mixer
        if ffn is not None and not (ffn == "moe" and ep):
            units += 1
    return units


def _moe_sublayers(cfg: ArchConfig) -> int:
    return sum(1 for _, f in cfg.pattern if f == "moe")


def tp_allreduce_bytes(cfg: ArchConfig, seq: int, batch: int, tp: int,
                       kind: str = "train") -> int:
    """Total activation all-reduce bytes per TP group per step (the
    whole model; divide by ``pipe`` for a stage's share)."""
    act = batch * seq * cfg.d_model * BF16
    passes = 2 if kind == "train" else 1            # bwd grad allreduce
    return _sublayer_units(cfg, tp) * cfg.n_blocks * act * passes


def moe_a2a_pair_bytes(cfg: ArchConfig, seq: int, batch: int, ep: int,
                       kind: str = "train") -> int:
    """Total bytes one ordered rank pair carries per step across every
    MoE sublayer's dispatch+combine (x2 again for the backward)."""
    tokens = batch * seq
    per_a2a = tokens * cfg.top_k * cfg.d_model * BF16 // (ep * ep)
    n_a2a = _moe_sublayers(cfg) * cfg.n_blocks * 2  # dispatch + combine
    if kind == "train":
        n_a2a *= 2
    return per_a2a * n_a2a


def pp_boundary_bytes(cfg: ArchConfig, seq: int, micro_batch: int) -> int:
    """One microbatch's activation tensor at one pipeline cut (one
    direction, full hidden — divide by ``model`` for a rank's shard)."""
    return micro_batch * seq * cfg.d_model * BF16


def prefill_comm_bytes(cfg: ArchConfig, prompt_len: int, tp: int) -> int:
    """TP all-reduce bytes to prefill one request's prompt."""
    return tp_allreduce_bytes(cfg, prompt_len, 1, tp, kind="prefill")


def decode_comm_bytes(cfg: ArchConfig, n_tokens: int, tp: int) -> int:
    """TP all-reduce bytes to decode ``n_tokens`` (one token = one
    seq-1 activation; aggregated so a request is one GroupOp)."""
    return tp_allreduce_bytes(cfg, 1, n_tokens, tp, kind="decode")


# ----------------------------------------------------------- workloads

def train_step_workload(cfg: ArchConfig, mesh: MeshShape,
                        hosts: Optional[Sequence[str]] = None, *,
                        seq: int, batch: int, accum: int = 1,
                        transport: str = "gleam", chunks: int = 8,
                        include_ckpt: bool = False) -> Workload:
    """One training step as a phased ``Workload``.

    Phase order (each phase is barrier-separated in the application;
    ``apps.metrics.step_time`` sums phase maxima): tp-allreduce,
    moe-alltoall, pp-boundary, dp-gradsync[, ckpt-write].
    """
    if hosts is None:
        hosts = default_hosts(mesh.n_chips)
    if len(hosts) < mesh.n_chips:
        raise ValueError(f"need {mesh.n_chips} hosts, got {len(hosts)}")
    if batch % (mesh.data * max(accum, 1)) != 0:
        raise ValueError(
            f"batch {batch} not divisible by data {mesh.data} x "
            f"accum {accum}")
    if mesh.pipe > 1 and cfg.n_blocks % mesh.pipe != 0:
        raise ValueError(
            f"{cfg.name}: n_blocks {cfg.n_blocks} not divisible by "
            f"pipe {mesh.pipe}")
    b_shard = batch // mesh.data
    micro = b_shard // max(accum, 1)
    tp, dp, pp = mesh.model, mesh.data, mesh.pipe
    n_params = param_count(cfg)
    wl = Workload(
        f"{cfg.name}/train/{transport}",
        meta={"model": cfg.name, "mesh": mesh.to_dict(), "seq": seq,
              "batch": batch, "accum": accum, "kind": "train",
              "transport": transport})
    kw = dict(transport=transport, chunks=chunks)

    if tp > 1:
        nb = tp_allreduce_bytes(cfg, seq, b_shard, tp) // pp
        for p in range(pp):
            for d in range(dp):
                group = [mesh.host(hosts, p, d, m) for m in range(tp)]
                wl.allreduce(group, nb, phase="tp-allreduce", **kw)

    if moe_uses_ep(cfg, tp):
        nb = moe_a2a_pair_bytes(cfg, seq, b_shard, tp) // pp
        for p in range(pp):
            for d in range(dp):
                group = [mesh.host(hosts, p, d, m) for m in range(tp)]
                for src in group:
                    for dst in group:
                        if src != dst:
                            wl.unicast(src, dst, nb,
                                       phase="moe-alltoall")

    if pp > 1:
        # accum microbatches cross each cut fwd + bwd, per TP shard
        nb = pp_boundary_bytes(cfg, seq, micro) * accum * 2 // tp
        for p in range(pp - 1):
            for d in range(dp):
                for m in range(tp):
                    wl.unicast(mesh.host(hosts, p, d, m),
                               mesh.host(hosts, p + 1, d, m), nb,
                               phase="pp-boundary")

    if dp > 1:
        nb = F32 * n_params // (tp * pp)
        for p in range(pp):
            for m in range(tp):
                group = [mesh.host(hosts, p, d, m) for d in range(dp)]
                wl.allreduce(group, nb, phase="dp-gradsync", **kw)

    if include_ckpt and dp > 1:
        # rank (0, 0, m) snapshots its f32 shard to its data peers
        nb = F32 * n_params // (tp * pp)
        for m in range(tp):
            group = [mesh.host(hosts, 0, d, m) for d in range(dp)]
            wl.write(group, nb, phase="ckpt-write", **kw)

    if not wl.ops:
        raise ValueError(
            f"mesh {mesh} has a single chip: no fabric traffic to lower")
    return wl


def weight_bcast_workload(cfg: ArchConfig, n_replicas: int, tp: int,
                          hosts: Optional[Sequence[str]] = None, *,
                          transport: str = "gleam",
                          chunks: int = 8) -> Workload:
    """Replica scale-out: each TP rank's bf16 weight shard broadcasts
    from replica 0 to every other replica (Gleam's native one-to-many;
    serving layout ``hosts[replica * tp + rank]``)."""
    if n_replicas < 2:
        raise ValueError("weight broadcast needs >= 2 replicas")
    if hosts is None:
        hosts = default_hosts(n_replicas * tp)
    nb = BF16 * param_count(cfg) // tp
    wl = Workload(
        f"{cfg.name}/weights/{transport}",
        meta={"model": cfg.name, "replicas": n_replicas, "tp": tp,
              "kind": "weights", "transport": transport})
    for m in range(tp):
        members = [hosts[r * tp + m] for r in range(n_replicas)]
        wl.bcast(members, nb, phase="weights", transport=transport,
                 chunks=chunks)
    return wl
