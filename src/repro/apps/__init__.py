"""Application traffic plane — lower the LM stack onto the fabric.

The repo's model stack (``repro.models``, ``repro.launch``,
``repro.runtime``) and its network simulators (``repro.core``) meet
here:

- ``collectives_lowering`` — derive TP/PP/MoE collective sizes from an
  ``ArchConfig`` and a mesh shape, emitting per-step ``Workload``s
  whose ops carry a ``phase`` label (tp-allreduce, moe-alltoall,
  pp-boundary, dp-gradsync, weights, prefill, decode, kv-replicate,
  ckpt-write).
- ``traffic`` — open-loop serving generator (seeded Poisson or
  deterministic-trace arrivals, MLPerf-offline style) mapping request
  arrivals to prefill/decode/replication ops across replicas and
  reporting offered-load vs achieved QPS.
- ``metrics`` — per-phase and per-request JCT aggregation with
  p50/p99/p999 quantiles on top of ``MsgRecord``s.

See ``docs/ARCHITECTURE.md`` §"Application traffic plane" and
``benchmarks/fig_apps.py`` for the end-to-end comparison (train-step
time and serve-QPS per transport, both engines).
"""
from repro.apps.collectives_lowering import (MeshShape, param_count,
                                             kv_cache_bytes,
                                             tp_allreduce_bytes,
                                             moe_a2a_pair_bytes,
                                             pp_boundary_bytes,
                                             train_step_workload,
                                             weight_bcast_workload)
from repro.apps.metrics import (PhaseStats, jct, phase_stats, quantile,
                                request_quantiles, step_time)
from repro.apps.traffic import ArrivalSpec, ServeReport, ServingGenerator

__all__ = [
    "MeshShape", "param_count", "kv_cache_bytes", "tp_allreduce_bytes",
    "moe_a2a_pair_bytes", "pp_boundary_bytes", "train_step_workload",
    "weight_bcast_workload", "PhaseStats", "jct", "phase_stats",
    "quantile", "request_quantiles", "step_time", "ArrivalSpec",
    "ServeReport", "ServingGenerator",
]
