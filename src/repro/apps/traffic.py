"""Open-loop serving traffic: arrivals -> fabric ops -> tail latency.

MLPerf-offline style: requests arrive on a clock the server does NOT
control (seeded Poisson or a deterministic trace), each request costs
prefill + decode TP collectives on its replica plus a KV-replication
write, and the report compares **offered load vs achieved QPS** with
p50/p99/p999 request latency.

The engines stage a scenario's ops concurrently from t=0, so open-loop
time is modeled with an **arrival-window round schedule**: arrivals are
bucketed into windows of ``window_s`` seconds, each window's requests
form one contended scenario (its round time = the slowest op's JCT,
with every other request in the window contending for the fabric), and
rounds execute back to back:

    start_w = max(end of window w, finish of round w-1)
    finish_w = start_w + round_time_w
    latency(request in w) = finish_w - t_arrive

Past the saturation rate rounds outlast their windows, the backlog
term compounds, and the p999 hockey-stick appears — the queueing
behaviour an open-loop harness exists to expose.  Because a round's
time does not depend on its start, ALL windows run as one
``run_many`` batch (serial == ``workers=N`` bit-identical on the
packet engine) and the chaining is applied analytically afterwards.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.apps import metrics as appm
from repro.apps.collectives_lowering import (decode_comm_bytes,
                                             default_hosts,
                                             kv_cache_bytes,
                                             prefill_comm_bytes)
from repro.configs.base import ArchConfig
from repro.core.metrics import MsgRecord
from repro.core.workload import Workload

__all__ = ["ArrivalSpec", "ServeReport", "ServingGenerator"]


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Request arrival process — plain data, serialized into
    ``Workload.meta`` so a staged serving sweep is replayable.

    ``poisson``: ``n`` arrivals with Exp(rate) gaps from
    ``random.Random(seed)`` (deterministic across platforms — Python's
    Mersenne Twister is part of the language spec).  ``trace``: the
    given arrival times verbatim (rate is then only the offered-load
    label)."""

    kind: str = "poisson"               # poisson | trace
    rate: float = 1e4                   # offered requests / second
    n: int = 64
    seed: int = 0
    trace: Tuple[float, ...] = ()

    def __post_init__(self):
        if self.kind not in ("poisson", "trace"):
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.kind == "poisson" and (self.rate <= 0 or self.n < 1):
            raise ValueError("poisson arrivals need rate > 0 and n >= 1")
        if self.kind == "trace" and not self.trace:
            raise ValueError("trace arrivals need a non-empty trace")
        object.__setattr__(self, "trace", tuple(self.trace))

    def arrivals(self) -> List[float]:
        if self.kind == "trace":
            return sorted(self.trace)
        rng = random.Random(self.seed)
        t, out = 0.0, []
        for _ in range(self.n):
            t += rng.expovariate(self.rate)
            out.append(t)
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ArrivalSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ArrivalSpec fields: "
                             f"{sorted(unknown)}")
        d = dict(d)
        if "trace" in d:
            d["trace"] = tuple(d["trace"])
        return cls(**d)


@dataclasses.dataclass
class ServeReport:
    """Offered vs achieved throughput + request-latency tail."""

    transport: str
    offered_qps: float
    achieved_qps: float
    n_requests: int
    latencies: List[float]
    quantiles: Dict[str, float]
    phase_latency: Dict[str, float]     # phase -> max JCT observed

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ServingGenerator:
    """Map request arrivals to fabric ops across ``n_replicas`` TP
    groups (serving layout ``hosts[replica * tp + rank]``).

    Per request, on its round-robin replica: one ``prefill`` TP
    all-reduce (prompt_len tokens), one aggregated ``decode`` TP
    all-reduce (decode_len tokens), and one ``kv-replicate`` write of
    the finished KV cache from the replica's rank-0 host to the next
    ``kv_replicas`` replicas' rank-0 hosts (prefix-cache / failover
    sharing — a one-to-many storage write, so the transport choice
    shows).  With ``tp == 1`` the collectives vanish and only
    replication traffic remains.
    """

    def __init__(self, cfg: ArchConfig, n_replicas: int, tp: int,
                 hosts: Optional[Sequence[str]] = None, *,
                 prompt_len: int = 512, decode_len: int = 64,
                 kv_replicas: int = 1,
                 transport: str = "gleam", chunks: int = 8,
                 window_s: Optional[float] = None):
        if n_replicas < 2:
            raise ValueError("serving traffic needs >= 2 replicas "
                             "(KV replication has nowhere to go)")
        if not 1 <= kv_replicas < n_replicas:
            raise ValueError(
                f"kv_replicas must be in [1, {n_replicas - 1}], got "
                f"{kv_replicas}")
        self.cfg = cfg
        self.n_replicas = n_replicas
        self.tp = tp
        self.hosts = list(hosts) if hosts is not None else \
            default_hosts(n_replicas * tp)
        if len(self.hosts) < n_replicas * tp:
            raise ValueError(f"need {n_replicas * tp} hosts, got "
                             f"{len(self.hosts)}")
        self.prompt_len = prompt_len
        self.decode_len = decode_len
        self.kv_replicas = kv_replicas
        self.transport = transport
        self.chunks = chunks
        self.window_s = window_s

    def _replica_hosts(self, r: int) -> List[str]:
        return [self.hosts[r * self.tp + m] for m in range(self.tp)]

    def _request_ops(self, wl: Workload, idx: int) -> None:
        r = idx % self.n_replicas
        group = self._replica_hosts(r)
        kw = dict(transport=self.transport, chunks=self.chunks)
        if self.tp > 1:
            wl.allreduce(group, prefill_comm_bytes(
                self.cfg, self.prompt_len, self.tp),
                phase="prefill", **kw)
            wl.allreduce(group, decode_comm_bytes(
                self.cfg, self.decode_len, self.tp),
                phase="decode", **kw)
        kv = kv_cache_bytes(self.cfg, self.prompt_len + self.decode_len)
        dsts = [self._replica_hosts((r + 1 + i) % self.n_replicas)[0]
                for i in range(self.kv_replicas)]
        wl.write([group[0]] + dsts, kv, phase="kv-replicate", **kw)

    def workloads(self, spec: ArrivalSpec) -> List[Workload]:
        """One phased ``Workload`` per arrival window (meta carries the
        spec, the window bounds, and the member request indices)."""
        arrivals = spec.arrivals()
        w = self.window_s
        if w is None:
            # ~8 requests per window at the offered rate: enough
            # contention per round to matter, enough rounds for a tail
            span = arrivals[-1] if arrivals[-1] > 0 else 1.0
            w = max(span / max(len(arrivals) // 8, 1), 1e-9)
        windows: Dict[int, List[int]] = {}
        for i, t in enumerate(arrivals):
            windows.setdefault(int(t / w), []).append(i)
        out = []
        for k in sorted(windows):
            wl = Workload(
                f"{self.cfg.name}/serve/{self.transport}/w{k}",
                meta={"model": self.cfg.name, "kind": "serve",
                      "transport": self.transport, "window": k,
                      "window_s": w, "requests": windows[k],
                      "arrivals": [arrivals[i] for i in windows[k]],
                      "spec": spec.to_dict()})
            for i in windows[k]:
                self._request_ops(wl, i)
            out.append(wl)
        return out

    def report(self, spec: ArrivalSpec, workloads: Sequence[Workload],
               results: Sequence[Sequence[MsgRecord]]) -> ServeReport:
        """Chain the window rounds and fold per-request latencies.
        ``results[w]`` must align with ``workloads[w].ops``; a window's
        round time is its ``step_time`` (prefill, decode, and
        replication are barrier-separated batch phases)."""
        latencies: List[float] = []
        phase_lat: Dict[str, float] = {}
        finish = 0.0
        for wl, recs in zip(workloads, results):
            w = wl.meta["window_s"]
            round_t = appm.step_time(wl.ops, recs)
            for phase, st in appm.phase_stats(wl.ops, recs).items():
                phase_lat[phase] = max(phase_lat.get(phase, 0.0),
                                       st.latency)
            start = max((wl.meta["window"] + 1) * w, finish)
            finish = start + round_t
            latencies.extend(finish - t for t in wl.meta["arrivals"])
        n = len(latencies)
        achieved = n / finish if finish > 0 else 0.0
        return ServeReport(
            transport=self.transport, offered_qps=spec.rate,
            achieved_qps=achieved, n_requests=n, latencies=latencies,
            quantiles=appm.request_quantiles(latencies),
            phase_latency=phase_lat)

    def run(self, eng, spec: ArrivalSpec, *, timeout: float = 120.0,
            workers: Optional[int] = None) -> ServeReport:
        """Run every window phase by phase — one flat ``run_many``
        batch (each window's prefill / decode / kv-replicate phase is
        an independent scenario; requests inside a phase contend) —
        then fold the report."""
        wls = self.workloads(spec)
        parts = [appm.split_phases(wl) for wl in wls]
        flat = [p for ps in parts for p in ps]
        flat_res = iter(eng.run_workloads(flat, timeout=timeout,
                                          workers=workers))
        results = []
        for wl, ps in zip(wls, parts):
            by_op = {}
            for p in ps:
                for op, r in zip(p.ops, next(flat_res)):
                    by_op[id(op)] = r
            results.append([by_op[id(op)] for op in wl.ops])
        return self.report(spec, wls, results)
