"""Per-phase / per-request JCT aggregation over ``MsgRecord``s.

The app lowerings (``apps.collectives_lowering``, ``apps.traffic``)
tag every ``GroupOp`` with a ``phase`` label; the engines stage a
phase's ops concurrently (they contend for the fabric) while distinct
phases of a step are barrier-separated in the application (an optimizer
cannot sync gradients it has not computed).  So:

- a phase's **latency** is the MAX op JCT inside it (the barrier waits
  for the slowest collective);
- a step's **time** is the SUM of its phase latencies, in first-
  appearance order;
- request/tail statistics use **nearest-rank** quantiles (p50 / p99 /
  p999) — deterministic, no interpolation, exact on small samples.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.metrics import MsgRecord
from repro.core.workload import GroupOp

__all__ = ["jct", "quantile", "request_quantiles", "PhaseStats",
           "phase_stats", "step_time", "split_phases", "run_phased"]


def jct(rec: MsgRecord) -> float:
    """Job completion time of one op: last delivery (falling back to
    the sender CQE for ops with no receivers' deliveries recorded)."""
    if rec.t_deliver:
        return max(rec.t_deliver.values()) - rec.t_submit
    return rec.t_sender_cqe - rec.t_submit


def quantile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank quantile (q in [0, 1]); 0.0 on an empty sample."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = max(1, math.ceil(q * len(s)))
    return s[min(rank, len(s)) - 1]


def request_quantiles(latencies: Sequence[float]) -> Dict[str, float]:
    """The serving-tail dict every report carries: p50/p99/p999/max."""
    return {
        "p50": quantile(latencies, 0.50),
        "p99": quantile(latencies, 0.99),
        "p999": quantile(latencies, 0.999),
        "max": max(latencies) if latencies else 0.0,
    }


@dataclasses.dataclass
class PhaseStats:
    """Aggregate of one phase's op JCTs within a scenario."""

    phase: str
    n_ops: int
    total_bytes: int
    latency: float              # max JCT: what the barrier waits for
    sum_jct: float
    p50: float
    p99: float
    p999: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def phase_stats(ops: Sequence[GroupOp], recs: Sequence[MsgRecord]
                ) -> Dict[str, PhaseStats]:
    """Group op records by their ``phase`` tag (first-appearance order;
    untagged ops fall under ``""``)."""
    groups: Dict[str, List[int]] = {}
    for i, op in enumerate(ops):
        groups.setdefault(op.phase, []).append(i)
    out: Dict[str, PhaseStats] = {}
    for phase, idxs in groups.items():
        js = [jct(recs[i]) for i in idxs]
        out[phase] = PhaseStats(
            phase=phase, n_ops=len(idxs),
            total_bytes=sum(ops[i].nbytes for i in idxs),
            latency=max(js), sum_jct=sum(js),
            p50=quantile(js, 0.50), p99=quantile(js, 0.99),
            p999=quantile(js, 0.999))
    return out


def step_time(ops: Sequence[GroupOp], recs: Sequence[MsgRecord],
              compute_floor: Optional[Dict[str, float]] = None) -> float:
    """Step time = sum over phases of max(phase latency, optional
    per-phase compute floor).  ``compute_floor`` maps phase -> seconds
    of overlappable compute (e.g. a roofline term); a phase present
    only in the floor dict still contributes (pure-compute phase)."""
    stats = phase_stats(ops, recs)
    floor = dict(compute_floor or {})
    total = 0.0
    for phase, st in stats.items():
        total += max(st.latency, floor.pop(phase, 0.0))
    return total + sum(floor.values())


def split_phases(wl) -> List["object"]:
    """One sub-``Workload`` per phase (first-appearance order), sharing
    the parent's meta and op objects.

    This is how a phased step SHOULD be executed: the engines stage one
    scenario's ops concurrently, so staging a whole step as one
    scenario makes the tp-allreduce contend with the dp-gradsync it is
    barrier-separated from — only stage them together when full-step
    contention is the thing under study."""
    from repro.core.workload import Workload
    groups: Dict[str, List[GroupOp]] = {}
    for op in wl.ops:
        groups.setdefault(op.phase, []).append(op)
    return [Workload(f"{wl.name}#{phase or 'untagged'}", ops,
                     meta=dict(wl.meta))
            for phase, ops in groups.items()]


def run_phased(eng, wl, *, timeout: float = 120.0,
               workers: Optional[int] = None):
    """Run ``wl`` phase by phase (each phase one independent scenario,
    all phases one ``run_many`` batch) and return ``(ops, recs)``
    aligned — feed them to ``step_time`` / ``phase_stats``."""
    phases = split_phases(wl)
    results = eng.run_workloads(phases, timeout=timeout, workers=workers)
    ops = [op for p in phases for op in p.ops]
    recs = [r for rs in results for r in rs]
    return ops, recs
