"""Sharded checkpointing: manifest + per-leaf npz shards, async writes,
keep-k retention, and elastic restore onto a DIFFERENT mesh.

Layout on disk (one directory per step):

    ckpt_dir/step_000123/
        MANIFEST.json        # tree structure, shapes, dtypes, step, meta
        leaf_00000.npy ...   # one file per pytree leaf (full logical value)
        COMMITTED            # written last: crash-consistent marker

Leaves are written as full logical arrays (gathered from the mesh), which
is what makes restore onto any other mesh (elastic re-registration,
DESIGN.md §2.4) trivial: load, then device_put with the NEW sharding.
On a real multi-host pod the gather is a per-host all-gather via
jax.device_get of addressable shards; the API is identical.

The Gleam mapping: a checkpoint-restore onto a new mesh is exactly the
control-plane re-registration of Appendix A — the data plane (training
step) is untouched; only the forwarding tables (shardings) are rebuilt.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import pathlib
import shutil
import time

import jax
import numpy as np


def _leaf_paths(tree, prefix=""):
    """Stable depth-first leaf ordering with path strings."""
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1) \
            if async_write else None
        self._pending: cf.Future | None = None

    # ----------------------------------------------------------- write

    def save(self, step: int, tree, *, meta: dict | None = None) -> None:
        """Snapshot `tree` at `step`.  With async_write the device->host
        transfer happens now, the disk write in the background (the train
        loop keeps stepping — compute/IO overlap)."""
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        if self._pool is None:
            self._write(step, host_tree, meta or {})
            return
        self.wait()
        self._pending = self._pool.submit(self._write, step, host_tree,
                                          meta or {})

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree, meta: dict) -> None:
        d = self.dir / f"step_{step:09d}"
        tmp = self.dir / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        leaves, treedef = jax.tree.flatten(host_tree)
        manifest = {
            "step": step,
            "time": time.time(),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in leaves],
            "meta": meta,
        }
        for i, leaf in enumerate(leaves):
            np.save(tmp / f"leaf_{i:05d}.npy", leaf)
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "COMMITTED").write_text("ok")
        if d.exists():
            shutil.rmtree(d)
        tmp.rename(d)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ----------------------------------------------------------- read

    def all_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, example_tree, *, step: int | None = None,
                shardings=None):
        """Restore into the structure of `example_tree`.

        shardings: matching pytree of NamedShardings for the TARGET mesh
        (elastic restore: the saved mesh is irrelevant — full logical
        leaves are resharded on load).  Returns (tree, step, meta).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        leaves, treedef = jax.tree.flatten(example_tree)
        assert manifest["n_leaves"] == len(leaves), (
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {len(leaves)}")
        loaded = []
        for i, ex in enumerate(leaves):
            arr = np.load(d / f"leaf_{i:05d}.npy")
            want = tuple(ex.shape)
            assert tuple(arr.shape) == want, (
                f"leaf {i}: checkpoint {arr.shape} != model {want}")
            loaded.append(arr)
        tree = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step, manifest["meta"]
