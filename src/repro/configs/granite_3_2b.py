"""Granite 3.0 2B [hf:ibm-granite/granite-3.0-2b-base; hf] — dense GQA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_3_2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49155, rope_theta=1e4,
    pattern=(("attn", "mlp"),),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=257, q_chunk=32, kv_chunk=32,
)
