"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT (STUB) + InternLM2 backbone.

The vision tower is a stub: input_specs() provides vision_prefix=256
precomputed patch embeddings concatenated ahead of the text tokens."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, rope_theta=1e6,
    pattern=(("attn", "mlp"),),
    vision_prefix=256,
    remat="full",           # fit HBM: dots policy saves gathered weights
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, vision_prefix=8, q_chunk=32, kv_chunk=32,
)
