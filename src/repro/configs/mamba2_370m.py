"""Mamba2-370m [arXiv:2405.21060; unverified] — attention-free SSD.
d_inner = 2*1024 = 2048, headdim 64 -> 32 SSD heads, d_state 128."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_370m", family="ssm",
    n_layers=48, d_model=1024, vocab_size=50280,
    pattern=(("mamba", None),),
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1, ssm_conv=4,
    remat="full",           # fit HBM: dots policy saves gathered weights
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab_size=256, ssm_state=16, ssm_headdim=16,
    q_chunk=32, kv_chunk=32,
)
