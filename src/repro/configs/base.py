"""Architecture config schema + registry.

Each assigned architecture gets one module in this package defining
``CONFIG`` (the exact published config) and ``SMOKE`` (a reduced config of
the same family for CPU smoke tests).  ``get_config(name, smoke=False)``
resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

Sublayer = Tuple[str, str | None]  # (mixer, ffn) kinds


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int                   # total decoder sublayers
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    qkv_bias: bool = False
    window: int = 0                 # sliding-window size; 0 = full attention
    rope_theta: float = 1e4
    use_rope: bool = True
    # repeating sublayer pattern; n_layers must be len(pattern) * n_blocks
    pattern: Tuple[Sublayer, ...] = (("attn", "mlp"),)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    # encoder (enc-dec archs); encoder uses bidirectional attention
    enc_layers: int = 0
    # modality frontends (STUBS: input_specs provides embeddings directly)
    vision_prefix: int = 0          # of patch-embedding positions
    audio_stride: int = 0           # encoder frames = seq_len // stride
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # runtime knobs (hillclimb levers)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    remat: str = "dots"             # none | dots | full
    scan_layers: bool = True
    xent_chunk: int = 512           # tokens per chunked-xent scan step
    accum_steps: int = 0            # 0 = use the shape table's default
    moe_impl: str = "bucket"        # bucket (capacity GEMM) | ragged
    fsdp_weights: bool = True       # False: inference plan (no ZeRO gather)
    moe_barrier: bool = False       # pin MoE boundary dtype (qwen3 perf)
    embed_impl: str = "gather"      # gather | psum (shard_map mask+psum;
                                    # tried in llama §Perf iter 3: refuted)
    # collective schedule for the Gleam-adapted layer
    collective_schedule: str = "xla"   # xla | gleam_tree | ring | unicast

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern length {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if long_500k is runnable: SSM/hybrid or sliding-window."""
        kinds = {m for m, _ in self.pattern}
        return ("mamba" in kinds) or (self.window > 0)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


ARCH_IDS = (
    "mixtral_8x7b",
    "qwen3_moe_235b_a22b",
    "granite_3_2b",
    "llama3_2_3b",
    "h2o_danube_3_4b",
    "qwen1_5_110b",
    "whisper_medium",
    "mamba2_370m",
    "internvl2_26b",
    "jamba_v0_1_52b",
)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.SMOKE if smoke else mod.CONFIG
