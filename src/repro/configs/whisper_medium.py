"""Whisper-medium [arXiv:2212.04356; unverified] — enc-dec, conv frontend STUB.

The audio frontend is a stub: input_specs() provides precomputed frame
embeddings of length seq_len // audio_stride (DESIGN.md §8). Encoder is
bidirectional; decoder is causal + cross-attention. MHA (kv == heads)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865, qkv_bias=True, use_rope=False,
    pattern=(("attn", "mlp"),),
    enc_layers=24, audio_stride=4,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, enc_layers=2, q_chunk=32, kv_chunk=32,
)
