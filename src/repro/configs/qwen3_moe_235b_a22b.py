"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B; hf] — 128-expert top-8 MoE, GQA kv=4."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b_a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, rope_theta=1e6,
    pattern=(("attn", "moe"),),
    n_experts=128, top_k=8, moe_d_ff=1536,
    remat="full",           # fit HBM: dots policy saves gathered weights
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, moe_d_ff=96, vocab_size=256, n_experts=8, top_k=2,
    q_chunk=32, kv_chunk=32,
)
