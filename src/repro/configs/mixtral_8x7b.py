"""Mixtral 8x7B [arXiv:2401.04088; hf] — 8-expert top-2 MoE, GQA kv=8, SWA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral_8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, window=4096, rope_theta=1e6,
    pattern=(("attn", "moe"),),
    n_experts=8, top_k=2, moe_d_ff=14336,
    remat="full",           # fit HBM: dots policy saves gathered weights
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, vocab_size=256, n_experts=4, top_k=2,
    window=32, q_chunk=32, kv_chunk=32,
)
