"""Llama-3.2 3B [hf:meta-llama/Llama-3.2-1B; unverified] — dense GQA, 24 heads
(NOT divisible by the 16-way model axis: exercises the head_dim sharding
fallback in the planner)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_2_3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256, rope_theta=5e5,
    pattern=(("attn", "mlp"),),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, q_chunk=32, kv_chunk=32,
)
