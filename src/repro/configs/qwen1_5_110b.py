"""Qwen1.5 110B [hf:Qwen/Qwen1.5-0.5B; hf] — dense GQA with QKV bias (largest dense)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1_5_110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    pattern=(("attn", "mlp"),),
    remat="full", accum_steps=16,  # 82.9GB temp at accum=8 + dots
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, q_chunk=32, kv_chunk=32,
)
