"""Jamba v0.1 52B [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave, MoE 16e top-2.

Period-8 superblock: attention at position 3, Mamba elsewhere; MoE FFN at
odd positions (every other layer), dense MLP at even. 32 layers = 4 blocks.
Attention layers are full-attention, but the hybrid is sub-quadratic overall
(4 attention layers; KV for long_500k sharded over the data axis)."""
from repro.configs.base import ArchConfig

_PATTERN = tuple(
    ("attn" if i == 3 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba_v0_1_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536, use_rope=False,
    pattern=_PATTERN,
    n_experts=16, top_k=2, moe_d_ff=14336,
    ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_groups=1, ssm_conv=4,
    remat="full",           # fit HBM: dots policy saves gathered weights
)

SMOKE = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, vocab_size=256, n_experts=4, top_k=2,
    ssm_state=16, ssm_headdim=16, q_chunk=32, kv_chunk=32,
)
