"""H2O-Danube3 4B [arXiv:2401.16818; unverified] — llama+mistral mix, SWA.
head_dim = 3840/32 = 120 (non-128-aligned: kernel path pads, XLA path exact)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o_danube_3_4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000, window=4096, rope_theta=1e4,
    pattern=(("attn", "mlp"),),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=32, q_chunk=32, kv_chunk=32,
)
