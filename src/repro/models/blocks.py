"""Parameter machinery + elementwise blocks (norms, MLP, embeddings, RoPE).

Parameters are described abstractly by ``ParamDef(shape, axes)`` pytrees;
``init_params`` materializes them, ``param_shardings`` resolves them against
a ``ShardingPlan``, ``param_structs`` produces ShapeDtypeStructs for
allocation-free lowering (the multi-pod dry-run path).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardingPlan


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple                    # logical axis names, len == len(shape)
    init: str = "normal"           # normal | zeros | ones | small
    scale: float | None = None     # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
            out.append((jax.random.normal(k, d.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def param_structs(defs, dtype=jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def param_shardings(defs, plan: ShardingPlan):
    return jax.tree.map(
        lambda d: plan.sharding(d.axes, d.shape), defs, is_leaf=is_def)


def param_specs(defs, plan: ShardingPlan):
    return jax.tree.map(
        lambda d: plan.spec(d.axes, d.shape), defs, is_leaf=is_def)


def count_params(defs) -> int:
    return sum(math.prod(d.shape)
               for d in jax.tree.leaves(defs, is_leaf=is_def))


# ---------------------------------------------------------------- blocks

def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def swiglu(x, wi, wg, wo, compute_dtype):
    """SwiGLU MLP: silu(x@wg) * (x@wi) @ wo."""
    cd = compute_dtype
    h = jax.nn.silu(x.astype(cd) @ wg.astype(cd)) * (x.astype(cd) @ wi.astype(cd))
    return h @ wo.astype(cd)


def mlp_defs(d_model, d_ff):
    return {
        "wi": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "wg": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "wo": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def stack_defs(defs, n: int):
    """Prepend a (n, "layers") scan dimension to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes,
                           d.init, d.scale),
        defs, is_leaf=is_def)


def rope(x, positions, theta):
    """Rotary embedding over the last dim (rotate-half convention).

    x: (..., seq, heads..., head_dim); positions: (..., seq) int32.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    # broadcast over head dims between seq and head_dim
    extra = x.ndim - positions.ndim - 1
    ang = ang.reshape(ang.shape[:-1] + (1,) * extra + (half,))
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if hd > 2 * half:  # odd head_dim (danube's 120 stays even; guard anyway)
        rot = jnp.concatenate([rot, x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


def sinusoidal_at(positions, d_model):
    """Sinusoidal absolute position encoding at arbitrary positions.

    positions: (...,) int -> (..., d_model) float32.
    """
    pos = positions.astype(jnp.float32)[..., None]
    div = jnp.exp(jnp.arange(0, d_model, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d_model))
    pe = jnp.zeros(positions.shape + (d_model,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(pos * div))
    pe = pe.at[..., 1::2].set(jnp.cos(pos * div[: (d_model + 1) // 2]))
    return pe


def sinusoidal_positions(seq_len, d_model):
    return sinusoidal_at(jnp.arange(seq_len), d_model)
