"""Model assembly: decoder-only / enc-dec / VLM / SSM / hybrid from one
generic repeating-pattern machine, with scan-over-layers and explicit
sharding (shard_map for the attention core and MoE; GSPMD elsewhere).

Decode-path attention uses split-KV: the cache is sharded over sequence,
each shard computes partial softmax statistics (m, l, acc), and a
many-to-one combine merges them — structurally the Gleam ACK-aggregation
tree (DESIGN.md §2.2/2.3).  The combine schedule is selectable
(psum | gleam_tree) via cfg.collective_schedule.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.blocks import (ParamDef, mlp_defs, rms_norm, rope,
                                 sinusoidal_positions, stack_defs, swiglu)

BATCH_AXES = ("pod", "data")


# ================================================================ defs

def _attn_defs(cfg: ArchConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "norm": ParamDef((d,), ("norm",), init="ones"),
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((kv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = ParamDef((kv, hd), ("kv_heads", None), init="zeros")
    if cross:
        defs["xnorm"] = ParamDef((d,), ("norm",), init="ones")
        defs["xwq"] = ParamDef((d, h, hd), ("embed", "heads", None))
        defs["xwk"] = ParamDef((d, kv, hd), ("embed", "kv_heads", None))
        defs["xwv"] = ParamDef((d, kv, hd), ("embed", "kv_heads", None))
        defs["xwo"] = ParamDef((h, hd, d), ("heads", None, "embed"))
    return defs


def _ffn_defs(cfg: ArchConfig, kind):
    d = cfg.d_model
    if kind is None:
        return {}
    norm = {"norm": ParamDef((d,), ("norm",), init="ones")}
    if kind == "mlp":
        return {**norm, **mlp_defs(d, cfg.d_ff)}
    if kind == "moe":
        return {**norm, **moe_mod.moe_defs(cfg)}
    raise ValueError(kind)


def _sublayer_defs(cfg: ArchConfig, mixer, ffn, cross=False):
    if mixer == "attn":
        mdefs = _attn_defs(cfg, cross=cross)
    elif mixer == "mamba":
        mdefs = {"norm": ParamDef((cfg.d_model,), ("norm",), init="ones"),
                 **ssm_mod.ssm_defs(cfg)}
    else:
        raise ValueError(mixer)
    return {"mixer": mdefs, "ffn": _ffn_defs(cfg, ffn)}


def model_defs(cfg: ArchConfig):
    d, v = cfg.d_model, cfg.vocab_size
    block = {f"sub{i}": _sublayer_defs(cfg, m, f,
                                       cross=(cfg.enc_layers > 0))
             for i, (m, f) in enumerate(cfg.pattern)}
    defs: dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab_table", "embed_table"),
                          scale=0.02),
        "blocks": stack_defs(block, cfg.n_blocks),
        "final_norm": ParamDef((d,), ("norm",), init="ones"),
        "lm_head": ParamDef((d, v), ("embed", "vocab")),
    }
    if cfg.enc_layers > 0:  # encoder stack (bidirectional, no cross)
        eblock = {"sub0": _sublayer_defs(cfg, "attn", "mlp")}
        defs["enc_blocks"] = stack_defs(eblock, cfg.enc_layers)
        defs["enc_in"] = ParamDef((d, d), ("embed", None))
        defs["enc_norm"] = ParamDef((d,), ("norm",), init="ones")
    if cfg.vision_prefix > 0:
        defs["vis_proj"] = ParamDef((d, d), ("embed", None))
    return defs


# ================================================================ attention

def _project_qkv(p, x, cfg, cd, prefix=""):
    q = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wv"].astype(cd))
    if cfg.qkv_bias and prefix == "":
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _bspec(mesh):
    bs = tuple(a for a in BATCH_AXES if a in mesh.axis_names
               and mesh.shape[a] > 1)
    return bs if len(bs) > 1 else (bs[0] if bs else None)


def _heads_sharded(cfg, mesh):
    return cfg.n_heads % mesh.shape["model"] == 0


def _sp_attention(q, k, v, cfg, mesh, *, causal, window):
    """Sequence-parallel attention: q sharded over "model" on the seq
    dim, k/v replicated across it; each shard computes its q rows against
    the full KV with global positions (q_offset).  Activation memory for
    scores and (m, l, acc) shrinks by the model-axis size."""
    m = mesh.shape["model"]
    bspec = _bspec(mesh)
    qspec = P(bspec, "model", None, None)
    kvspec = P(bspec, None, None, None)
    s_local = q.shape[1] // m

    def body(ql, kl, vl):
        off = jax.lax.axis_index("model") * s_local
        return attn.attention(ql, kl, vl, causal=causal, window=window,
                              kv_chunk=cfg.kv_chunk, q_offset=off)

    return shard_map(body, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
                     out_specs=qspec, check_vma=False)(q, k, v)


def attn_core(q, k, v, cfg, mesh, *, causal, window):
    """Train/prefill attention core; shard_map over heads when divisible.

    GQA head layout on an m-way model axis (h_l = H/m local q heads,
    rep = H/KV):
      - KV % m == 0: kv heads shard too (each shard keeps its own groups);
      - m % KV == 0 (kv heads fewer than shards, e.g. kv=8 on m=16): kv
        stays replicated and each shard slices the single kv head its
        local q heads belong to (MaxText-style kv replication).
    """
    m = mesh.shape["model"]
    if m == 1:
        return attn.attention(q, k, v, causal=causal, window=window,
                              kv_chunk=cfg.kv_chunk)
    if not _heads_sharded(cfg, mesh):
        # SP fallback (llama3.2's 24 heads on a 16-way axis): shard the
        # QUERY SEQUENCE over "model" instead of heads.  Without this the
        # whole attention runs replicated per model shard — 280GB HBM
        # peak on train_4k (EXPERIMENTS.md §Perf, llama iteration 1).
        if q.shape[1] % m == 0:
            return _sp_attention(q, k, v, cfg, mesh, causal=causal,
                                 window=window)
        return attn.attention(q, k, v, causal=causal, window=window,
                              kv_chunk=cfg.kv_chunk)
    h, kv = cfg.n_heads, cfg.n_kv_heads
    h_l, rep = h // m, h // kv
    kv_sharded = kv % m == 0
    if not kv_sharded and (m % kv != 0 or rep % h_l != 0):
        return attn.attention(q, k, v, causal=causal, window=window,
                              kv_chunk=cfg.kv_chunk)
    bspec = _bspec(mesh)
    qspec = P(bspec, None, "model", None)
    kvspec = P(bspec, None, "model" if kv_sharded else None, None)

    def body(ql, kl, vl):
        if not kv_sharded:
            idx = jax.lax.axis_index("model")
            start = (idx * h_l) // rep
            kl = jax.lax.dynamic_slice_in_dim(kl, start, 1, axis=2)
            vl = jax.lax.dynamic_slice_in_dim(vl, start, 1, axis=2)
        return attn.attention(ql, kl, vl, causal=causal, window=window,
                              kv_chunk=cfg.kv_chunk)

    return shard_map(body, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
                     out_specs=qspec, check_vma=False)(q, k, v)


def attn_apply(p, x, cfg, mesh, positions, *, causal=True, window=0,
               memory=None):
    """Self-attention sublayer (+ optional cross-attention when memory)."""
    cd = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(cd)
    q, k, v = _project_qkv(p, h, cfg, cd)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = attn_core(q, k, v, cfg, mesh, causal=causal, window=window)
    x = x + jnp.einsum("bshk,hkd->bsd", o.astype(cd), p["wo"].astype(cd))
    if memory is not None:
        hx = rms_norm(x, p["xnorm"], cfg.norm_eps).astype(cd)
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["xwq"].astype(cd))
        kx = jnp.einsum("bsd,dhk->bshk", memory.astype(cd),
                        p["xwk"].astype(cd))
        vx = jnp.einsum("bsd,dhk->bshk", memory.astype(cd),
                        p["xwv"].astype(cd))
        ox = attn_core(qx, kx, vx, cfg, mesh, causal=False, window=0)
        x = x + jnp.einsum("bshk,hkd->bsd", ox.astype(cd),
                           p["xwo"].astype(cd))
    return x


# ---------------------------------------------------------------- decode

def _seq_axes(mesh, batch_shardable):
    """Mesh axes available to shard the KV-cache sequence dim."""
    axes = []
    for a in mesh.axis_names:
        if mesh.shape[a] <= 1:
            continue
        if a == "model":
            axes.append(a)
        elif a in BATCH_AXES and not batch_shardable:
            axes.append(a)
    return tuple(axes)


def kv_cache_spec(mesh, batch_shardable: bool):
    bspec = _bspec(mesh) if batch_shardable else None
    seq = _seq_axes(mesh, batch_shardable)
    seq = seq if len(seq) > 1 else (seq[0] if seq else None)
    return P(bspec, seq, None, None)


def decode_attn_core(q, kc, vc, step, cfg, mesh, *, window,
                     batch_shardable=True):
    """Split-KV decode attention.  kc/vc sharded over sequence; each shard
    computes partial (m, l, acc); many-to-one combine merges (Gleam
    feedback aggregation).  q: (B,1,H,hd) -> out (B,1,H,hd) replicated
    over the seq axes.

    step: scalar, or (B,) for continuous batching (single-shard KV)."""
    from repro.core import collectives as coll
    seq_axes = _seq_axes(mesh, batch_shardable)
    if jnp.ndim(step) == 1:
        assert not seq_axes, (
            "per-row decode positions require unsharded KV")
        return attn.decode_attention(q, kc, vc, kv_len=step + 1,
                                     window=window)
    if not seq_axes:
        kv_len = jnp.broadcast_to(step + 1, (q.shape[0],))
        return attn.decode_attention(q, kc, vc, kv_len=kv_len, window=window)
    bspec = _bspec(mesh) if batch_shardable else None
    q_in = P(bspec, None, "model", None) if _heads_sharded(cfg, mesh) \
        else P(bspec, None, None, None)
    kv_in = kv_cache_spec(mesh, batch_shardable)
    s_total = kc.shape[1]
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    s_local = s_total // n_shards

    def body(ql, kl, vl, stp):
        if _heads_sharded(cfg, mesh) and mesh.shape["model"] > 1:
            ql = jax.lax.all_gather(ql, "model", axis=2, tiled=True)
        # linear shard index over seq axes
        idx = jnp.int32(0)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        base = idx * s_local
        kpos = base + jnp.arange(s_local)
        if window:
            valid = kpos < jnp.minimum(stp + 1, window)   # rolling buffer
        else:
            valid = kpos <= stp
        b, _, hq, hd = ql.shape
        n_kv = kl.shape[2]
        qg = ql.reshape(b, 1, n_kv, hq // n_kv, hd).astype(jnp.float32)
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qg,
                            kl.astype(jnp.float32)) / jnp.sqrt(hd)
        logits = jnp.where(valid[None, None, None, None, :], logits,
                           attn.NEG_INF)
        m = logits.max(axis=-1)
        pexp = jnp.exp(logits - m[..., None])
        l = pexp.sum(axis=-1)
        acc = jnp.einsum("bkrqs,bskd->bkrqd", pexp, vl.astype(jnp.float32))
        m, l, acc = coll.softmax_combine(
            (m, l, acc), seq_axes, schedule=cfg.collective_schedule)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, 1, hq, hd).astype(ql.dtype)

    out_spec = P(bspec, None, None, None)
    return shard_map(body, mesh=mesh,
                     in_specs=(q_in, kv_in, kv_in, P()),
                     out_specs=out_spec, check_vma=False)(q, kc, vc, step)


def cache_insert(kc, vc, k_new, v_new, pos, mesh, batch_shardable=True):
    """Insert (B,1,KV,hd) into the seq-sharded cache at global slot pos.

    pos: scalar (synchronized decode) or (B,) int32 (continuous batching,
    single-shard KV only — the serve runtime's per-row positions)."""
    if jnp.ndim(pos) == 1:
        def upd(c, n, p):
            return jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), p, 0)
        return (jax.vmap(upd)(kc, k_new, pos),
                jax.vmap(upd)(vc, v_new, pos))
    seq_axes = _seq_axes(mesh, batch_shardable)
    if not seq_axes:
        return (jax.lax.dynamic_update_slice_in_dim(kc, k_new, pos, 1),
                jax.lax.dynamic_update_slice_in_dim(vc, v_new, pos, 1))
    bspec = _bspec(mesh) if batch_shardable else None
    kv_in = kv_cache_spec(mesh, batch_shardable)
    new_in = P(bspec, None, None, None)
    s_total = kc.shape[1]
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    s_local = s_total // n_shards

    def body(kl, vl, kn, vn, p_):
        idx = jnp.int32(0)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        local_pos = jnp.clip(p_ - idx * s_local, 0, s_local - 1)
        mine = (p_ >= idx * s_local) & (p_ < (idx + 1) * s_local)
        kn = jnp.where(mine, kn, kl[:, local_pos][:, None]
                       .astype(kn.dtype))
        vn = jnp.where(mine, vn, vl[:, local_pos][:, None]
                       .astype(vn.dtype))
        kl = jax.lax.dynamic_update_slice_in_dim(
            kl, kn.astype(kl.dtype), local_pos, 1)
        vl = jax.lax.dynamic_update_slice_in_dim(
            vl, vn.astype(vl.dtype), local_pos, 1)
        return kl, vl

    return shard_map(body, mesh=mesh,
                     in_specs=(kv_in, kv_in, new_in, new_in, P()),
                     out_specs=(kv_in, kv_in), check_vma=False)(
                         kc, vc, k_new, v_new, pos)


def attn_decode_apply(p, x, cache, step, cfg, mesh, *, window=0, memory=None,
                      batch_shardable=True):
    cd = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(cd)
    q, k, v = _project_qkv(p, h, cfg, cd)
    pos = (step[:, None] if jnp.ndim(step) == 1
           else jnp.broadcast_to(step, (x.shape[0], 1)))
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    slot = jnp.mod(step, cache["k"].shape[1]) if window else step
    kc, vc = cache_insert(cache["k"], cache["v"], k, v, slot, mesh,
                          batch_shardable)
    o = decode_attn_core(q, kc, vc, step, cfg, mesh, window=window,
                         batch_shardable=batch_shardable)
    x = x + jnp.einsum("bshk,hkd->bsd", o.astype(cd), p["wo"].astype(cd))
    if memory is not None:
        hx = rms_norm(x, p["xnorm"], cfg.norm_eps).astype(cd)
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["xwq"].astype(cd))
        kx = jnp.einsum("bsd,dhk->bshk", memory.astype(cd),
                        p["xwk"].astype(cd))
        vx = jnp.einsum("bsd,dhk->bshk", memory.astype(cd),
                        p["xwv"].astype(cd))
        ox = attn.cross_attention(qx, kx, vx)
        x = x + jnp.einsum("bshk,hkd->bsd", ox.astype(cd),
                           p["xwo"].astype(cd))
    return x, {"k": kc, "v": vc}


# ================================================================ sublayers

def ffn_apply(p, x, kind, cfg, mesh, decode=False):
    if kind is None:
        return x, 0.0
    cd = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, p["norm"], cfg.norm_eps).astype(cd)
    if kind == "mlp":
        return x + swiglu(h, p["wi"], p["wg"], p["wo"], cd), 0.0
    y, aux = moe_mod.moe_apply(p, h, cfg, mesh, BATCH_AXES, decode=decode)
    if cfg.moe_barrier:
        # pin the shard_map boundary to bf16: stops XLA hoisting the next
        # block's f32 convert above the (B,S,D) boundary all-gather
        # (qwen3 §Perf iteration 3/4)
        y = jax.lax.optimization_barrier(y)
    return x + y, aux


def sublayer_apply(sub, x, mixer, ffn, cfg, mesh, positions, *,
                   causal=True, memory=None):
    if mixer == "attn":
        x = attn_apply(sub["mixer"], x, cfg, mesh, positions, causal=causal,
                       window=cfg.window, memory=memory)
    else:
        hm = rms_norm(x, sub["mixer"]["norm"], cfg.norm_eps)
        y, _ = ssm_mod.ssm_apply(
            {k: v for k, v in sub["mixer"].items() if k != "norm"},
            hm, cfg)
        x = x + y
    x, aux = ffn_apply(sub["ffn"], x, ffn, cfg, mesh)
    return x, aux


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def run_blocks(blocks, x, cfg, mesh, positions, *, pattern=None, causal=True,
               memory=None):
    """Scan the stacked block params over the sequence of sublayers."""
    pattern = pattern if pattern is not None else cfg.pattern

    def body(carry, bp):
        x, aux = carry
        for i, (m, f) in enumerate(pattern):
            x, a = sublayer_apply(bp[f"sub{i}"], x, m, f, cfg, mesh,
                                  positions, causal=causal, memory=memory)
            aux = aux + a
        return (x, aux), None

    body = _remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), blocks)
    else:
        n = jax.tree.leaves(blocks)[0].shape[0]
        aux = 0.0
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], blocks)
            (x, aux), _ = body((x, aux), bp)
    return x, aux


# ---------------------------------------------------------------- caches

def cache_len(cfg, seq_len):
    return min(seq_len, cfg.window) if cfg.window else seq_len


def init_caches(cfg, batch, seq_len, mesh=None, abstract=False,
                dtype=jnp.bfloat16):
    """Per-layer decode caches stacked over n_blocks (+ encoder memory)."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    sub = {}
    for i, (m, f) in enumerate(cfg.pattern):
        if m == "attn":
            shape = (cfg.n_blocks, batch, cache_len(cfg, seq_len), kv, hd)
            sub[f"sub{i}"] = {
                "k": jax.ShapeDtypeStruct(shape, dtype),
                "v": jax.ShapeDtypeStruct(shape, dtype),
            }
        else:
            d_in, h, p, n, k = ssm_mod.ssm_dims(cfg)
            sub[f"sub{i}"] = {
                "conv": jax.ShapeDtypeStruct(
                    (cfg.n_blocks, batch, k - 1, d_in + 2 * n), dtype),
                "state": jax.ShapeDtypeStruct(
                    (cfg.n_blocks, batch, h, n, p), jnp.float32),
            }
    caches = {"layers": sub}
    if cfg.enc_layers > 0:
        enc_len = max(seq_len // max(cfg.audio_stride, 1), 8)
        caches["memory"] = jax.ShapeDtypeStruct(
            (batch, enc_len, cfg.d_model), dtype)
    if abstract:
        return caches
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), caches,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_specs(cfg, batch, seq_len, mesh, batch_shardable):
    """PartitionSpec tree matching init_caches."""
    kvspec = kv_cache_spec(mesh, batch_shardable)
    bspec = _bspec(mesh) if batch_shardable else None
    model_ok = lambda n: "model" if (  # noqa: E731
        mesh.shape["model"] > 1 and n % mesh.shape["model"] == 0) else None
    sub = {}
    for i, (m, f) in enumerate(cfg.pattern):
        if m == "attn":
            sp = P(None, *kvspec)
            sub[f"sub{i}"] = {"k": sp, "v": sp}
        else:
            d_in, h, p, n, k = ssm_mod.ssm_dims(cfg)
            sub[f"sub{i}"] = {
                "conv": P(None, bspec, None, None),
                "state": P(None, bspec, model_ok(h), None, None),
            }
    specs = {"layers": sub}
    if cfg.enc_layers > 0:
        specs["memory"] = P(bspec, None, None)
    return specs


def run_blocks_decode(blocks, caches, x, step, cfg, mesh, *, memory=None,
                      batch_shardable=True):
    """One decode step through the stacked blocks, updating caches."""

    def body(carry, inp):
        x = carry
        bp, cache = inp
        new_cache = {}
        for i, (m, f) in enumerate(cfg.pattern):
            sub = bp[f"sub{i}"]
            c = cache[f"sub{i}"]
            if m == "attn":
                x, nc = attn_decode_apply(
                    sub["mixer"], x, c, step, cfg, mesh,
                    window=cfg.window, memory=memory,
                    batch_shardable=batch_shardable)
            else:
                hm = rms_norm(x, sub["mixer"]["norm"], cfg.norm_eps)
                y, nc = ssm_mod.ssm_decode_step(
                    {k: v for k, v in sub["mixer"].items() if k != "norm"},
                    hm, c, cfg)
                x = x + y
            x, _ = ffn_apply(sub["ffn"], x, f, cfg, mesh, decode=True)
            new_cache[f"sub{i}"] = nc
        return x, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (blocks, caches["layers"]))
    else:
        n = jax.tree.leaves(blocks)[0].shape[0]
        outs = []
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], blocks)
            cc = jax.tree.map(lambda a: a[i], caches["layers"])
            x, nc = body(x, (bp, cc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    out = {"layers": new_caches}
    if "memory" in caches:
        out["memory"] = caches["memory"]
    return x, out


def decode_forward(params, caches, tokens, step, cfg, mesh, *,
                   batch_shardable=True):
    """Single-token serve forward: (B,1) tokens -> (B,1,V) logits."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params, tokens, cfg, cd, mesh)
    memory = caches.get("memory")
    if not cfg.use_rope and cfg.enc_layers > 0:
        from repro.models.blocks import sinusoidal_at
        pe = sinusoidal_at(jnp.broadcast_to(step, (1, 1)), cfg.d_model)
        x = x + pe.astype(cd)
    x, new_caches = run_blocks_decode(
        params["blocks"], caches, x, step, cfg, mesh, memory=memory,
        batch_shardable=batch_shardable)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cd),
                        params["lm_head"].astype(cd))
    return logits.astype(jnp.float32), new_caches


# ================================================================ forward

def embed_tokens(params, tokens, cfg, cd, mesh=None):
    """Token embedding lookup.

    When the table's vocab dim is sharded over "model" (vocab % m == 0),
    the lookup runs in shard_map: device (d, m) holds batch-shard d and
    vocab-shard m, computes vocab-shard-m's contribution to its own batch
    rows, and a psum over "model" assembles the rows — a mask+reduce
    instead of GSPMD's involuntary full-table rematerialization, and the
    table GRADIENT stays vocab-sharded (llama §Perf iteration 3: the
    f32 full-table all-gather/all-reduce pair was ~3.4GB/step).
    """
    table = params["embed"]
    v = table.shape[0]
    if (cfg.embed_impl != "psum" or mesh is None
            or "model" not in mesh.axis_names):
        return table.astype(cd)[tokens]
    m = mesh.shape["model"]
    if m <= 1 or v % m != 0:
        return table.astype(cd)[tokens]
    v_local = v // m
    bspec = _bspec(mesh)

    def body(tbl, toks):
        base = jax.lax.axis_index("model") * v_local
        loc = toks - base
        ok = (loc >= 0) & (loc < v_local)
        rows = tbl.astype(cd)[jnp.clip(loc, 0, v_local - 1)]
        rows = jnp.where(ok[..., None], rows, 0)
        return jax.lax.psum(rows, "model")

    return shard_map(body, mesh=mesh,
                     in_specs=(P("model", None), P(bspec, None)),
                     out_specs=P(bspec, None, None),
                     check_vma=False)(table, tokens)


def build_inputs(params, batch, cfg, mesh=None):
    """Assemble the decoder input sequence from tokens + modality stubs."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params, batch["tokens"], cfg, cd, mesh)
    if cfg.vision_prefix > 0:
        vis = batch["vision_embed"].astype(cd) @ params["vis_proj"].astype(cd)
        x = jnp.concatenate([vis, x], axis=1)
    if not cfg.use_rope:  # sinusoidal absolute positions (whisper/jamba-attn)
        pe = sinusoidal_positions(x.shape[1], cfg.d_model).astype(cd)
        if cfg.enc_layers > 0:   # whisper decoder gets positions; jamba not
            x = x + pe[None]
    return x


def encode(params, batch, cfg, mesh):
    """Encoder forward for enc-dec archs (audio frontend STUB: batch
    provides precomputed frame embeddings)."""
    cd = jnp.dtype(cfg.compute_dtype)
    frames = batch["frames"].astype(cd)
    x = frames @ params["enc_in"].astype(cd)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cd)[None]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _ = run_blocks(params["enc_blocks"], x, cfg, mesh, pos,
                      pattern=(("attn", "mlp"),), causal=False)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, batch, cfg: ArchConfig, mesh):
    """Teacher-forced forward -> logits (B, S, V) in f32."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = build_inputs(params, batch, cfg, mesh)
    memory = encode(params, batch, cfg, mesh) if cfg.enc_layers > 0 else None
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, aux = run_blocks(params["blocks"], x, cfg, mesh, pos, causal=True,
                        memory=memory)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.vision_prefix > 0:
        x = x[:, cfg.vision_prefix:]
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cd),
                        params["lm_head"].astype(cd))
    return logits.astype(jnp.float32), aux


def forward_hidden(params, batch, cfg: ArchConfig, mesh):
    """Forward up to the final norm; returns hidden states, not logits."""
    x = build_inputs(params, batch, cfg, mesh)
    memory = encode(params, batch, cfg, mesh) if cfg.enc_layers > 0 else None
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, aux = run_blocks(params["blocks"], x, cfg, mesh, pos, causal=True,
                        memory=memory)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.vision_prefix > 0:
        x = x[:, cfg.vision_prefix:]
    return x, aux


def chunked_xent(x, lm_head, targets, mask, cfg, mesh=None):
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits live only inside the
    (rematerialized) scan body, so peak memory is O(B * chunk * V / shards)
    instead of O(B * S * V).  This is what makes the 150k-vocab archs fit
    HBM on the production mesh (EXPERIMENTS.md §Perf, iteration 1).
    """
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    chunk = min(cfg.xent_chunk, s)
    if s % chunk != 0:
        chunk = s
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)
    ts = targets.reshape(b, n, chunk).swapaxes(0, 1)
    ms = (mask if mask is not None
          else jnp.ones(targets.shape, jnp.float32))
    ms = ms.reshape(b, n, chunk).swapaxes(0, 1)
    w = lm_head.astype(cd)
    v_ax = ("model" if mesh is not None and "model" in mesh.axis_names
            and lm_head.shape[1] % mesh.shape["model"] == 0 else None)
    bspec = _bspec(mesh) if mesh is not None else None

    @jax.checkpoint
    def body(carry, inp):
        xc, tc, mc = inp
        logits = jnp.einsum("bcd,dv->bcv", xc.astype(cd), w)
        if mesh is not None:
            # keep the chunk logits vocab-sharded over "model": local
            # logsumexp partials + a tiny cross-shard reduce, instead of
            # GSPMD's involuntary full-logits rematerialization.
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(mesh, P(bspec, None, v_ax)))
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot reduce (shards over the vocab axis;
        # take_along_axis would force a cross-shard gather)
        hot = jax.nn.one_hot(tc, logits.shape[-1], dtype=logits.dtype)
        gold = (logits * hot).sum(-1)
        return carry + ((logz - gold) * mc).sum(), None

    nll_sum, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                              (xs, ts, ms))
    denom = jnp.maximum(ms.sum(), 1.0)
    return nll_sum / denom


def loss_fn(params, batch, cfg, mesh):
    x, aux = forward_hidden(params, batch, cfg, mesh)
    loss = chunked_xent(x, params["lm_head"], batch["targets"],
                        batch.get("loss_mask"), cfg, mesh)
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "perplexity": jnp.exp(jnp.clip(loss, max=20.0))}
