"""Mixture-of-Experts with explicit expert-parallel dispatch/combine.

This layer IS the Gleam pattern inside the model (DESIGN.md §2.3): token
dispatch to top-k experts is a one-to-many multicast over the "model" mesh
axis; the weighted combine is a many-to-one feedback aggregation.  Both are
implemented with shard_map + all_to_all so the collective structure is
explicit in the HLO (and countable by the roofline pass).

Two paths:
- ``moe_train``  — tokens resharded seq-wise over "model" (sequence
  parallelism into the block), capacity-bucketed all_to_all to expert
  owners, local grouped GEMM via ``jax.lax.ragged_dot``, reverse all_to_all,
  weighted scatter-add combine at the source.
- ``moe_decode`` — single/few-token step: tokens are small, experts stay
  put; every expert shard computes its local experts' contributions and a
  psum over "model" performs the many-to-one combine.

Expert placement (matches the sharding planner's divisibility fallback):
- "ep"  — n_experts divides the model axis: experts sharded over "model".
- "etp" — (mixtral: 8 experts on a 16-way axis): experts replicated,
  expert d_ff sharded over "model" (tensor parallelism inside experts).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from repro.models.blocks import ParamDef


def expert_mode(cfg, model_axis_size: int) -> str:
    return "ep" if cfg.n_experts % model_axis_size == 0 else "etp"


def moe_defs(cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    # planner resolves: experts->model when divisible (ep), else mlp->model
    # (etp); embed always takes the FSDP axes.  These axes MUST stay in sync
    # with _specs() below.
    return {
        "router": ParamDef((d, e), (None, None), scale=0.02),
        "we_i": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "we_g": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "we_o": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }


def _fsdp_axes(mesh, enabled: bool = True):
    if not enabled:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names
                 and mesh.shape[a] > 1)


def _specs(cfg, mesh):
    """shard_map in_specs for (router, we_i, we_g, we_o)."""
    fs = _fsdp_axes(mesh, cfg.fsdp_weights)
    fspec = fs if len(fs) > 1 else (fs[0] if fs else None)
    mode = expert_mode(cfg, mesh.shape["model"])
    if mode == "ep":
        ig = P("model", fspec, None)
        o = P("model", None, fspec)
    else:
        ig = P(None, fspec, "model")
        o = P(None, "model", fspec)
    return mode, P(None, None), ig, o


def _gather(w, mesh, dim, enabled: bool = True):
    """FSDP all-gather of weight dim `dim` inside shard_map (ZeRO-3 fwd)."""
    for a in _fsdp_axes(mesh, enabled):
        w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
    return w


def _router(x2, wr, top_k):
    """x2: (T, D) -> (gates (T,k), ids (T,k), aux_loss scalar)."""
    logits = x2.astype(jnp.float32) @ wr.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (T, E)
    gates, ids = jax.lax.top_k(probs, top_k)             # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), 0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_proxy)
    return gates, ids, aux


def _grouped_ffn(xs, gs, we_i, we_g, we_o, cd):
    """Grouped GEMM over expert-sorted rows. xs (M, D), gs (groups,).

    BASELINE implementation (cfg.moe_impl == "ragged"): ragged_dot lowers
    to a DENSE masked dot on this backend — real compute and the counted
    flops inflate by ~n_experts_local / top_k (§Perf, MoE iteration 1)."""
    h = (jax.nn.silu(jax.lax.ragged_dot(xs, we_g.astype(cd), gs))
         * jax.lax.ragged_dot(xs, we_i.astype(cd), gs))
    return jax.lax.ragged_dot(h, we_o.astype(cd), gs)


def _bucket_ffn(rows, eids, n_exp, cap_e, we_i, we_g, we_o, cd,
                weights=None):
    """Capacity-bucketed expert FFN — the TPU-native grouped GEMM.

    rows (M, D); eids (M,) in [0, n_exp] (n_exp = sentinel/dropped).
    Rows scatter into a dense (n_exp, cap_e, D) buffer; the FFN is a
    batched einsum (MXU-shaped; XLA counts exactly n_exp*cap_e*D*F
    flops).  Pays only the capacity-factor padding instead of the
    ragged_dot dense-lowering blowup.  Returns y (M, D), zero for
    dropped rows, scaled by `weights` if given.
    """
    m, d = rows.shape
    order = jnp.argsort(eids)                    # stable; sentinel last
    sorted_e = eids[order]
    counts = jnp.bincount(eids, length=n_exp + 1)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(m) - offsets[sorted_e]
    valid = (sorted_e < n_exp) & (rank < cap_e)
    slot = jnp.where(valid, sorted_e * cap_e + rank, n_exp * cap_e)
    buf = jnp.zeros((n_exp * cap_e + 1, d), cd).at[slot].set(
        rows[order].astype(cd))[:-1]
    xb = buf.reshape(n_exp, cap_e, d)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, we_g.astype(cd)))
         * jnp.einsum("ecd,edf->ecf", xb, we_i.astype(cd)))
    yb = jnp.einsum("ecf,efd->ecd", h, we_o.astype(cd))
    yb = jnp.concatenate([yb.reshape(n_exp * cap_e, d),
                          jnp.zeros((1, d), h.dtype)])
    y_sorted = jnp.where(valid[:, None], yb[slot], 0)
    y = jnp.zeros((m, d), yb.dtype).at[order].set(y_sorted)
    if weights is not None:
        y = y * weights[:, None].astype(y.dtype)
    return y


def _cap(n_tokens, n_exp, cf, floor=8):
    return max(floor, int(math.ceil(cf * n_tokens / n_exp / floor)) * floor)


def _batch_spec(mesh, batch_axes, batch: int | None = None):
    """Batch PartitionSpec; replicated when `batch` doesn't divide the
    batch-axes product (e.g. long_500k's global_batch=1)."""
    bs = tuple(a for a in batch_axes if a in mesh.axis_names
               and mesh.shape[a] > 1)
    if batch is not None:
        n = 1
        for a in bs:
            n *= mesh.shape[a]
        if n == 0 or batch % max(n, 1) != 0:
            return None
    return bs if len(bs) > 1 else (bs[0] if bs else None)


def moe_train(params, x, cfg, mesh, batch_axes):
    """x: (B, S, D), batch sharded over batch_axes. Returns (y, aux)."""
    cd = jnp.dtype(cfg.compute_dtype)
    ep = mesh.shape["model"]
    mode, r_spec, ig_spec, o_spec = _specs(cfg, mesh)
    e = cfg.n_experts
    e_local = e // ep if mode == "ep" else e
    # ep: tokens seq-split over "model" (sequence parallelism into the
    # block).  etp: tokens replicated over "model" — the psum over the
    # f-slice partials must reduce identical token sets.
    if mode == "ep":
        x_spec = P(_batch_spec(mesh, batch_axes, x.shape[0]), "model", None)
    else:
        x_spec = P(_batch_spec(mesh, batch_axes, x.shape[0]), None, None)

    def body(wr, we_i, we_g, we_o, xl):
        b_l, s_l, d = xl.shape
        t_l = b_l * s_l
        x2 = xl.reshape(t_l, d)
        gates, ids, aux = _router(x2, wr, cfg.top_k)
        aux = jax.lax.pmean(aux, "model")
        for a in _fsdp_axes(mesh):
            aux = jax.lax.pmean(aux, a)
        we_i = _gather(we_i, mesh, 1, cfg.fsdp_weights)
        we_g = _gather(we_g, mesh, 1, cfg.fsdp_weights)
        we_o = _gather(we_o, mesh, 2, cfg.fsdp_weights)

        if mode == "etp":
            # experts replicated, d_ff sharded: expert FFN on the local
            # f-slice for every (token, expert) pair; psum over model
            # reduces the partial wo contraction.
            n = t_l * cfg.top_k
            flat_ids = ids.reshape(-1)
            tok = jnp.arange(n) // cfg.top_k
            if cfg.moe_impl == "ragged":
                order = jnp.argsort(flat_ids)
                xs = x2[order // cfg.top_k].astype(cd)
                gs = jnp.bincount(flat_ids, length=e)
                y = _grouped_ffn(xs, gs, we_i, we_g, we_o, cd)
                y = jax.lax.psum(y, "model")
                w = gates.reshape(-1)[order].astype(y.dtype)
                out = jnp.zeros((t_l, d), y.dtype) \
                    .at[order // cfg.top_k].add(y * w[:, None])
                return out.reshape(b_l, s_l, d).astype(xl.dtype), aux
            cap_e = _cap(n, e, cfg.capacity_factor)
            y = _bucket_ffn(x2[tok], flat_ids, e, cap_e,
                            we_i, we_g, we_o, cd,
                            weights=gates.reshape(-1))
            y = jax.lax.psum(y, "model")
            out = jnp.zeros((t_l, d), y.dtype).at[tok].add(y)
            return out.reshape(b_l, s_l, d).astype(xl.dtype), aux

        # ---------------- expert-parallel dispatch (the Gleam multicast)
        n = t_l * cfg.top_k
        cap = max(8, int(math.ceil(cfg.capacity_factor * n / ep / 8)) * 8)
        flat_e = ids.reshape(-1)                       # (N,) global expert id
        dest = flat_e // e_local                       # owner shard
        order = jnp.argsort(dest)                      # stable groups by dest
        sorted_dest = dest[order]
        counts = jnp.bincount(dest, length=ep)
        offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(n) - offsets[sorted_dest]
        valid = rank < cap
        slot = jnp.where(valid, sorted_dest * cap + rank, ep * cap)
        buf_tok = jnp.full((ep * cap + 1,), -1, jnp.int32).at[slot].set(
            (order // cfg.top_k).astype(jnp.int32))[:-1]
        buf_eid = jnp.full((ep * cap + 1,), e_local, jnp.int32).at[slot].set(
            (flat_e[order] % e_local).astype(jnp.int32))[:-1]
        buf_gate = jnp.zeros((ep * cap + 1,), jnp.float32).at[slot].set(
            gates.reshape(-1)[order])[:-1]
        send_x = jnp.where((buf_tok >= 0)[:, None],
                           x2[jnp.maximum(buf_tok, 0)], 0).astype(cd)
        send_x = send_x.reshape(ep, cap, d)
        send_eid = buf_eid.reshape(ep, cap)
        # one-to-many: tokens travel to their expert owners
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0)
        recv_eid = jax.lax.all_to_all(send_eid, "model", 0, 0)
        m = ep * cap
        flat_rx = recv_x.reshape(m, d)
        flat_eid = recv_eid.reshape(m)
        if cfg.moe_impl == "ragged":
            lorder = jnp.argsort(flat_eid)             # sentinel last
            xs = flat_rx[lorder]
            gs = jnp.bincount(flat_eid, length=e_local + 1)[:e_local]
            y = _grouped_ffn(xs, gs, we_i, we_g, we_o, cd)
            y_un = jnp.zeros((m, d), y.dtype).at[lorder].set(y)
        else:
            cap_e = _cap(m, e_local, 1.0)              # cf already in cap
            y_un = _bucket_ffn(flat_rx, flat_eid, e_local, cap_e,
                               we_i, we_g, we_o, cd)
        # many-to-one: expert outputs travel home (feedback aggregation)
        back = jax.lax.all_to_all(y_un.reshape(ep, cap, d), "model", 0, 0)
        flat_back = back.reshape(ep * cap, d)
        w = buf_gate.astype(flat_back.dtype)[:, None]
        out = jnp.zeros((t_l, d), flat_back.dtype).at[
            jnp.maximum(buf_tok, 0)].add(
                jnp.where((buf_tok >= 0)[:, None], flat_back * w, 0))
        return out.reshape(b_l, s_l, d).astype(xl.dtype), aux

    fn = shard_map(body, mesh=mesh,
                   in_specs=(r_spec, ig_spec, ig_spec, o_spec, x_spec),
                   out_specs=(x_spec, P()), check_vma=False)
    return fn(params["router"], params["we_i"], params["we_g"],
              params["we_o"], x)


def moe_decode(params, x, cfg, mesh, batch_axes):
    """Few-token MoE step: local experts compute, psum over model combines."""
    cd = jnp.dtype(cfg.compute_dtype)
    ep = mesh.shape["model"]
    mode, r_spec, ig_spec, o_spec = _specs(cfg, mesh)
    e = cfg.n_experts
    e_local = e // ep if mode == "ep" else e
    x_spec = P(_batch_spec(mesh, batch_axes, x.shape[0]), None, None)

    def body(wr, we_i, we_g, we_o, xl):
        b_l, s_l, d = xl.shape
        x2 = xl.reshape(-1, d)
        gates, ids, aux = _router(x2, wr, cfg.top_k)
        we_i = _gather(we_i, mesh, 1, cfg.fsdp_weights)
        we_g = _gather(we_g, mesh, 1, cfg.fsdp_weights)
        we_o = _gather(we_o, mesh, 2, cfg.fsdp_weights)
        if mode == "ep":
            base = jax.lax.axis_index("model") * e_local
            lids = ids - base
        else:
            lids = ids
        flat = jnp.where((lids >= 0) & (lids < e_local),
                         lids, e_local).reshape(-1)
        n = flat.shape[0]
        if cfg.moe_impl == "ragged":
            order = jnp.argsort(flat)
            xs = x2[order // cfg.top_k].astype(cd)
            gs = jnp.bincount(flat, length=e_local + 1)[:e_local]
            y = _grouped_ffn(xs, gs, we_i, we_g, we_o, cd)
            w = gates.reshape(-1)[order].astype(y.dtype)
            out = jnp.zeros((x2.shape[0], d), y.dtype) \
                .at[order // cfg.top_k].add(y * w[:, None])
        else:
            tok = jnp.arange(n) // cfg.top_k
            cap_e = _cap(n, e_local, cfg.capacity_factor * 2)
            y = _bucket_ffn(x2[tok], flat, e_local, cap_e,
                            we_i, we_g, we_o, cd,
                            weights=gates.reshape(-1))
            out = jnp.zeros((x2.shape[0], d), y.dtype).at[tok].add(y)
        out = jax.lax.psum(out, "model")   # many-to-one combine (both modes)
        aux = jax.lax.pmean(aux, "model")
        for a in _fsdp_axes(mesh):
            aux = jax.lax.pmean(aux, a)
        return out.reshape(b_l, s_l, d).astype(xl.dtype), aux

    fn = shard_map(body, mesh=mesh,
                   in_specs=(r_spec, ig_spec, ig_spec, o_spec, x_spec),
                   out_specs=(x_spec, P()), check_vma=False)
    return fn(params["router"], params["we_i"], params["we_g"],
              params["we_o"], x)


def moe_apply(params, x, cfg, mesh, batch_axes, decode=False):
    s = x.shape[1]
    if decode or s % mesh.shape["model"] != 0:
        return moe_decode(params, x, cfg, mesh, batch_axes)
    return moe_train(params, x, cfg, mesh, batch_axes)
