"""Attention: GQA with causal / bidirectional / sliding-window variants.

All paths are memory-safe under GSPMD (no full S x S score tensor for long
sequences):

- ``chunked_attention``  — online-softmax scan over KV chunks (flash-style
  in XLA); used for full-attention train/prefill.  Upper-triangle blocks
  are masked, not skipped (XLA counts their FLOPs — the Pallas kernel in
  kernels/flash_attention.py skips them on real hardware; the roofline
  table reports the MODEL_FLOPS/HLO_FLOPs ratio this costs).
- ``swa_attention``      — banded 2-chunk gather for sliding-window; FLOPs
  ~= 2*W per query instead of S.
- ``decode_attention``   — single-query dense attention against a KV cache
  (optionally length-masked); the distributed split-KV variant lives in
  core/collectives.py (Gleam many-to-one combine).

Shapes: q (B, Sq, H, hd); k, v (B, Skv, KVH, hd); H = KVH * rep (GQA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def dense_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Reference O(S^2)-memory attention. Small seqs / oracle only."""
    b, sq, h, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    qg = _split_gqa(q, n_kv)                              # b sq kv rep d
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def chunked_attention(q, k, v, *, causal=True, kv_chunk=1024, q_offset=0):
    """Online-softmax scan over KV chunks; full (or causal) attention.
    q_offset: global position of q[0] (sequence-parallel shards)."""
    b, sq, h, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    if skv % kv_chunk != 0:
        kv_chunk = skv  # degenerate: single chunk
    n_chunks = skv // kv_chunk
    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kc = k.reshape(b, n_chunks, kv_chunk, n_kv, d)
    vc = v.reshape(b, n_chunks, kv_chunk, n_kv, d)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, kb.astype(jnp.float32))
        logits = logits * scale
        if causal:
            kpos = ci * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] <= qpos[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkrqs,bskd->bkrqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    rep = h // n_kv
    m0 = jnp.full((b, n_kv, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, n_kv, rep, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def swa_attention(q, k, v, *, window):
    """Sliding-window attention via banded 2-chunk gather (chunk == window).

    Each query chunk i attends exactly chunks [i-1, i] of KV, masked to the
    causal window.  FLOPs ~ 2*W per query (vs S for full attention).
    """
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    assert s % window == 0, (s, window)
    nc = s // window
    qg = _split_gqa(q, n_kv).reshape(b, nc, window, n_kv, h // n_kv, d)
    kc = k.reshape(b, nc, window, n_kv, d)
    vc = v.reshape(b, nc, window, n_kv, d)
    # previous chunk (zeros before chunk 0)
    kp = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vp = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    kband = jnp.concatenate([kp, kc], axis=2)             # b nc 2W kv d
    vband = jnp.concatenate([vp, vc], axis=2)
    logits = jnp.einsum("bcqkrd,bcskd->bckrqs", qg.astype(jnp.float32),
                        kband.astype(jnp.float32)) / jnp.sqrt(d)
    tq = jnp.arange(window)                               # in-chunk q pos
    ts = jnp.arange(2 * window) - window                  # band pos rel. chunk
    mask = (ts[None, :] <= tq[:, None]) & (ts[None, :] > tq[:, None] - window)
    first = jnp.arange(2 * window) >= window              # chunk 0: no prev
    mask0 = mask & first[None, :]
    ci = jnp.arange(nc)
    m = jnp.where((ci == 0)[:, None, None], mask0[None], mask[None])
    logits = jnp.where(m[None, :, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bckrqs,bcskd->bcqkrd", w, vband.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def decode_attention(q, k, v, *, kv_len=None, window=0):
    """Single-query attention against a (possibly partially filled) cache.

    q: (B, 1, H, hd); k, v: (B, S_cache, KVH, hd).
    kv_len: (B,) int32 — number of valid cache entries (<= S_cache).
    """
    b, sq, h, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, k.astype(jnp.float32))
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    kpos = jnp.arange(skv)
    if kv_len is not None:
        valid = kpos[None, :] < kv_len[:, None]           # (B, S)
        if window:
            valid &= kpos[None, :] >= kv_len[:, None] - window
        logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def cross_attention(q, mem_k, mem_v):
    """Dense bidirectional cross-attention (decoder -> encoder memory)."""
    b, sq, h, d = q.shape
    n_kv = mem_k.shape[2]
    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg,
                        mem_k.astype(jnp.float32)) / jnp.sqrt(d)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, mem_v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, kv_chunk=1024,
              q_offset=None):
    """Dispatch to the right implementation for train/prefill shapes.
    q_offset not None forces the chunked path with global q positions
    (the sequence-parallel fallback)."""
    s = q.shape[1]
    if q_offset is not None:
        if window:
            return dense_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
        return chunked_attention(q, k, v, causal=causal,
                                 kv_chunk=kv_chunk, q_offset=q_offset)
    if window and causal and s > window and s % window == 0:
        return swa_attention(q, k, v, window=window)
    if s <= 2 * kv_chunk:
        return dense_attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk)
