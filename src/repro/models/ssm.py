"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD: intra-chunk quadratic attention-like term + inter-chunk state
recurrence carried by lax.scan.  Tensor-parallel friendly: heads/d_inner
shard over "model", B/C projections are per-group (G=1) and replicated, so
the whole scan is collective-free; only the out-projection psums.

The pure-jnp oracle for the Pallas ssd_scan kernel reuses ``ssd_chunked``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import ParamDef, rms_norm


def ssm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_headdim
    return d_in, n_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv


def ssm_defs(cfg):
    d = cfg.d_model
    d_in, h, p, n, k = ssm_dims(cfg)
    return {
        "wz": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "wx": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "wB": ParamDef((d, n), ("embed", None)),
        "wC": ParamDef((d, n), ("embed", None)),
        "wdt": ParamDef((d, h), ("embed", "ssm_heads")),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "A_log": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "D": ParamDef((h,), ("ssm_heads",), init="ones"),
        "conv_x": ParamDef((k, d_in), ("conv_k", "ssm_inner"), scale=0.5),
        "conv_B": ParamDef((k, n), ("conv_k", None), scale=0.5),
        "conv_C": ParamDef((k, n), ("conv_k", None), scale=0.5),
        "gnorm": ParamDef((d_in,), ("ssm_inner",), init="ones"),
        "wo": ParamDef((d_in, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def ssd_chunked(x, dt, a, B_, C_, chunk):
    """SSD scan. x (B,S,H,P); dt,a (B,S,H); B_,C_ (B,S,N). Returns y, final
    state (B,H,N,P).  All f32 internally."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    ac = a.reshape(b, nc, chunk, h).astype(f32)
    Bc = B_.reshape(b, nc, chunk, n).astype(f32)
    Cc = C_.reshape(b, nc, chunk, n).astype(f32)
    xdt = xc * dtc[..., None]
    cum = jnp.cumsum(ac, axis=2)                          # (b,nc,q,h)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j), i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,q,k,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, L, xdt)
    # per-chunk final states
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,q,h)
    chunk_states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, decay_states * dtc,
                              xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (b,nc,h)

    def step(S, inp):
        cs, cd = inp                                       # (b,h,n,p),(b,h)
        S_new = S * cd[..., None, None] + cs
        return S_new, S                                    # emit state BEFORE

    S0 = jnp.zeros((b, h, n, p), f32)
    S_final, S_prevs = jax.lax.scan(
        step, S0, (chunk_states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    S_prevs = S_prevs.swapaxes(0, 1)                       # (b,nc,h,n,p)
    y_off = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, S_prevs, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, S_final


def ssm_apply(p, x, cfg, *, chunk=256):
    """Full-sequence Mamba-2 block. x (B,S,D) -> (y (B,S,D), state)."""
    cd = jnp.dtype(cfg.compute_dtype)
    d_in, h, hp, n, k = ssm_dims(cfg)
    xc = x.astype(cd)
    z = xc @ p["wz"].astype(cd)
    xin = xc @ p["wx"].astype(cd)
    B_ = xc @ p["wB"].astype(cd)
    C_ = xc @ p["wC"].astype(cd)
    dt_raw = xc @ p["wdt"].astype(cd)
    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"].astype(cd)))
    B_ = jax.nn.silu(_causal_conv(B_, p["conv_B"].astype(cd)))
    C_ = jax.nn.silu(_causal_conv(C_, p["conv_C"].astype(cd)))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt     # (B,S,H)
    xh = xin.reshape(*xin.shape[:2], h, hp)
    y, state = ssd_chunked(xh, dt, a, B_, C_, chunk)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_in)
    y = rms_norm(y.astype(cd) * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    return y @ p["wo"].astype(cd), state


def ssm_decode_init(cfg, batch, dtype=jnp.float32):
    d_in, h, p, n, k = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, k - 1, d_in + 2 * n), dtype),
        "state": jnp.zeros((batch, h, n, p), jnp.float32),
    }


def ssm_decode_step(p, x, cache, cfg):
    """Single-token step. x (B,1,D) -> (y (B,1,D), new cache)."""
    cd = jnp.dtype(cfg.compute_dtype)
    d_in, h, hp, n, k = ssm_dims(cfg)
    xt = x[:, 0].astype(cd)                               # (B,D)
    z = xt @ p["wz"].astype(cd)
    xin = xt @ p["wx"].astype(cd)
    B_ = xt @ p["wB"].astype(cd)
    C_ = xt @ p["wC"].astype(cd)
    dt_raw = xt @ p["wdt"].astype(cd)
    xbc = jnp.concatenate([xin, B_, C_], axis=-1)          # (B, d_in+2n)
    conv_w = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=1).astype(cd)  # (K, ..)
    window = jnp.concatenate([cache["conv"].astype(cd), xbc[:, None]], 1)
    conv_out = jnp.einsum("bkc,kc->bc", window, conv_w)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[:, :d_in]
    B_ = conv_out[:, d_in:d_in + n]
    C_ = conv_out[:, d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt
    xh = xin.reshape(-1, h, hp).astype(jnp.float32)
    S = cache["state"] * jnp.exp(a)[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", B_.astype(jnp.float32), dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", C_.astype(jnp.float32), S)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(-1, d_in)
    y = rms_norm(y.astype(cd) * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = (y @ p["wo"].astype(cd))[:, None]
    new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype),
                 "state": S}
    return out, new_cache
