"""Version-tolerant JAX API shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check kwarg was renamed
(``check_rep`` -> ``check_vma``) along the way.  Import it from here so
every module, test, and benchmark works on any JAX the container ships:

    from repro.compat import shard_map

The wrapper translates whichever check kwarg the caller used into the
one the installed JAX understands; everything else passes through.
"""
from __future__ import annotations

import inspect

try:                                    # jax >= 0.4.35 exports it top-level
    from jax import shard_map as _shard_map
except ImportError:                     # older: the experimental home
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *args, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg normalized."""
    for ours, theirs in (("check_vma", "check_rep"),
                         ("check_rep", "check_vma")):
        if ours in kwargs and ours not in _PARAMS and theirs in _PARAMS:
            kwargs[theirs] = kwargs.pop(ours)
    return _shard_map(f, *args, **kwargs)


def axis_size(axis_name) -> int:
    """Size of a named mesh axis, inside shard_map (static python int).

    ``jax.lax.axis_size`` is recent; older releases expose the bound
    axis frame through ``jax.core.axis_frame`` (which returns either the
    size itself or a frame object, depending on version).
    """
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


__all__ = ["shard_map", "axis_size"]
