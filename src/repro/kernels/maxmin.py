"""Fused max-min progressive-filling round as a Pallas kernel.

One round of water-filling over the padded (F, H) flow->link matrix
(see ``core/flowsim_jax.py``) needs four logical passes:

1. per-link demand  — scatter-add every unfrozen flow onto its links;
2. fair share       — ``cap_remaining / demand`` per link;
3. tightest share   — per-flow min-gather over its link list, global
   bottleneck ``b`` = min over unfrozen flows;
4. freeze mask      — flows at the bottleneck freeze at rate ``b`` and
   their bandwidth is subtracted from every link they cross.

The reference solver (``kernels/ref.py:maxmin_round_reference``) builds
each intermediate — the (L+1,) demand/share/used vectors and the (F,)
tightest vector — as a separate device array per round.  This kernel
fuses the whole round into a single ``pallas_call``: a (phase, tile)
grid makes one tiled pass over the (F, H) matrix per phase while the
demand counts, fair shares, per-flow tightest shares, subtracted
bandwidth, and the bottleneck scalar all live in VMEM/SMEM scratch and
never round-trip through HBM.

Mode selection (``_resolve_mode``) is automatic:

- ``ref``       — the pure-jnp oracle; the default on CPU (this
  container), where XLA fuses the jnp ops well and Pallas interpret
  mode would only add overhead;
- ``pallas``    — the compiled kernel; the default on TPU;
- ``interpret`` — the kernel under the Pallas interpreter; used by the
  correctness tests so the kernel path is exercised on any backend.

``REPRO_MAXMIN=ref|pallas|interpret`` overrides.  All three modes are
bit-compatible in float32 up to reduction-order rounding (tested to
0.1% against the numpy ``flowsim.FlowSim`` filling).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ref import loss_factors_reference, maxmin_round_reference

try:  # pallas is optional at runtime: the ref path never imports it
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    HAS_PALLAS = True
except Exception:                               # pragma: no cover - gated
    HAS_PALLAS = False

MODES = ("auto", "ref", "pallas", "interpret")


def _resolve_mode(mode=None) -> str:
    mode = mode or os.environ.get("REPRO_MAXMIN", "auto")
    if mode not in MODES:
        raise ValueError(f"maxmin mode {mode!r}; choose from {MODES}")
    if mode != "auto":
        return mode
    if not HAS_PALLAS or jax.default_backend() != "tpu":
        return "ref"
    return "pallas"


# ------------------------------------------------------------- the kernel

def _round_kernel(links_ref, frozen_ref, rates_ref, cap_ref,
                  rates_out, frozen_out, cap_out,
                  cnt_s, share_s, used_s, tight_s, b_s, *,
                  tol: float = 1e-6):
    """Grid (3, n_tiles): phase-major sequential passes over flow tiles.

    Phase 0 accumulates per-link demand; phase 1 turns it into fair
    shares (once) and each tile's tightest-share vector + the global
    bottleneck; phase 2 freezes, writes rates, and subtracts the frozen
    bandwidth.  All intermediates live in scratch.
    """
    phase = pl.program_id(0)
    i = pl.program_id(1)
    n_tiles = pl.num_programs(1)
    tf = links_ref.shape[0]
    dtype = cap_ref.dtype

    @pl.when((phase == 0) & (i == 0))
    def _init():
        cnt_s[...] = jnp.zeros_like(cnt_s)
        used_s[...] = jnp.zeros_like(used_s)
        b_s[0] = jnp.asarray(jnp.inf, dtype)

    @pl.when(phase == 0)
    def _demand():
        live = 1.0 - frozen_ref[...]
        cnt_s[...] = cnt_s[...].at[links_ref[...]].add(
            jnp.broadcast_to(live[:, None], links_ref.shape))

    @pl.when((phase == 1) & (i == 0))
    def _share():
        cnt = cnt_s[...]
        share_s[...] = jnp.where(cnt > 0.0,
                                 cap_ref[...] / jnp.maximum(cnt, 1.0),
                                 jnp.asarray(jnp.inf, dtype))

    @pl.when(phase == 1)
    def _tightest():
        tight = jnp.min(share_s[...][links_ref[...]], axis=1)
        tight_s[pl.ds(i * tf, tf)] = tight
        limit = jnp.where(frozen_ref[...] > 0.5,
                          jnp.asarray(jnp.inf, dtype), tight)
        b_s[0] = jnp.minimum(b_s[0], jnp.min(limit))

    @pl.when(phase == 2)
    def _freeze():
        b = b_s[0]
        frozen = frozen_ref[...]
        tight = tight_s[pl.ds(i * tf, tf)]
        limit = jnp.where(frozen > 0.5, jnp.asarray(jnp.inf, dtype), tight)
        newly = (frozen < 0.5) & (limit <= b * (1.0 + tol))
        newf = newly.astype(dtype)
        rates_out[...] = jnp.where(newly, b, rates_ref[...])
        frozen_out[...] = jnp.minimum(frozen + newf, 1.0)
        used_s[...] = used_s[...].at[links_ref[...]].add(
            jnp.broadcast_to((newf * b)[:, None], links_ref.shape))

        @pl.when(i == n_tiles - 1)
        def _subtract():
            cap_out[...] = jnp.maximum(cap_ref[...] - used_s[...], 0.0)


def maxmin_round_pallas(flow_links, frozen, rates, cap_rem, *,
                        block_f: int = 256, interpret: bool = False,
                        tol: float = 1e-6):
    """One fused progressive-filling round (see module docstring).

    Pads F up to a multiple of ``block_f`` with pre-frozen sentinel
    rows and slices back, so any F is accepted.  ``tol`` is the
    compile-time freeze slack (see ``maxmin_round_reference``).
    """
    if not HAS_PALLAS:                          # pragma: no cover - gated
        raise RuntimeError("pallas is not importable; use mode='ref'")
    n_flows, n_hops = flow_links.shape
    n_caps = cap_rem.shape[0]
    dtype = cap_rem.dtype
    tf = min(block_f, max(n_flows, 1))
    pad = (-n_flows) % tf
    if pad:
        flow_links = jnp.concatenate(
            [flow_links, jnp.full((pad, n_hops), n_caps - 1, jnp.int32)])
        frozen = jnp.concatenate([frozen, jnp.ones(pad, dtype)])
        rates = jnp.concatenate([rates, jnp.zeros(pad, dtype)])
    f_pad = n_flows + pad
    n_tiles = f_pad // tf

    grid = (3, n_tiles)
    tile_spec = lambda: pl.BlockSpec((tf, n_hops), lambda p, i: (i, 0))
    vec_spec = lambda: pl.BlockSpec((tf,), lambda p, i: (i,))
    cap_spec = lambda: pl.BlockSpec((n_caps,), lambda p, i: (0,))

    rates_o, frozen_o, cap_o = pl.pallas_call(
        functools.partial(_round_kernel, tol=tol),
        grid=grid,
        in_specs=[tile_spec(), vec_spec(), vec_spec(), cap_spec()],
        out_specs=[vec_spec(), vec_spec(), cap_spec()],
        out_shape=[jax.ShapeDtypeStruct((f_pad,), dtype),
                   jax.ShapeDtypeStruct((f_pad,), dtype),
                   jax.ShapeDtypeStruct((n_caps,), dtype)],
        scratch_shapes=[pltpu.VMEM((n_caps,), dtype),    # demand counts
                        pltpu.VMEM((n_caps,), dtype),    # fair shares
                        pltpu.VMEM((n_caps,), dtype),    # frozen bandwidth
                        pltpu.VMEM((f_pad,), dtype),     # tightest shares
                        pltpu.SMEM((1,), dtype)],        # bottleneck b
        interpret=interpret,
    )(flow_links, frozen, rates, cap_rem)
    return rates_o[:n_flows], frozen_o[:n_flows], cap_o


def maxmin_round(flow_links, frozen, rates, cap_rem, *, mode=None,
                 block_f: int = 256, tol: float = 1e-6):
    """Mode-dispatched fused round; returns (rates, frozen, cap_rem)."""
    mode = _resolve_mode(mode)
    if mode == "ref":
        return maxmin_round_reference(flow_links, frozen, rates, cap_rem,
                                      tol=tol)
    return maxmin_round_pallas(flow_links, frozen, rates, cap_rem,
                               block_f=block_f,
                               interpret=(mode == "interpret"), tol=tol)


# ------------------------------------------------------------- the solver

def maxmin_rates(flow_links, cap, active, *, mode=None, block_f: int = 256,
                 tol: float = 1e-6, max_rounds=None):
    """Max-min fair rates by progressive filling over the fused round.

    flow_links (F, H) int32 padded with the sentinel (last) index of
    ``cap``; cap (L+1,) bytes/s with cap[-1] = inf; active (F,) bool.
    Returns (F,) rates; inactive flows get ~0.  Terminates in at most F
    rounds (>= 1 flow freezes per round; in practice a handful, since
    whole bottleneck groups freeze together).

    ``tol`` is the relative freeze slack of each round and
    ``max_rounds`` caps the round count (None keeps the default F+1
    bound).  The dynamic-segment solver passes ``tol=1e-12,
    max_rounds=64`` under float64 to mirror the numpy
    ``flowsim.static_maxmin`` filling round for round.
    """
    mode = _resolve_mode(mode)
    n_flows = flow_links.shape[0]
    dtype = cap.dtype
    step = functools.partial(maxmin_round, mode=mode, block_f=block_f,
                             tol=tol)
    bound = n_flows if max_rounds is None else max_rounds - 1

    def cond(st):
        _, frozen, _, it = st
        return jnp.logical_and(jnp.min(frozen) < 0.5, it <= bound)

    def body(st):
        rates, frozen, cap_rem, it = st
        rates, frozen, cap_rem = step(flow_links, frozen, rates, cap_rem)
        return rates, frozen, cap_rem, it + 1

    init = (jnp.zeros(n_flows, dtype), 1.0 - active.astype(dtype),
            cap, jnp.int32(0))
    rates, _, _, _ = lax.while_loop(cond, body, init)
    return jnp.maximum(rates, 1e-9)


# -------------------------------------------------- the loss-factor kernel

def _loss_kernel(links_ref, rates_ref, active_ref, cap_ref, q_ref, wsq_ref,
                 wnd_ref, ecn_ref, fac_out, util_s, cnt_s, *,
                 dcqcn_num: float, dcqcn_min: float, util_eps: float):
    """Grid (2, n_tiles): fused expected-value loss/DCQCN correction.

    Phase 0 scatter-adds per-link utilization and active-flow counts
    into VMEM scratch; phase 1 turns them into per-flow rate factors
    (go-back-N goodput x DCQCN undershoot — the math documented on
    ``ref.py:loss_factors_reference``) without materializing the hot-
    link mask or any per-link intermediate in HBM.
    """
    phase = pl.program_id(0)
    i = pl.program_id(1)
    dtype = cap_ref.dtype

    @pl.when((phase == 0) & (i == 0))
    def _init():
        util_s[...] = jnp.zeros_like(util_s)
        cnt_s[...] = jnp.zeros_like(cnt_s)

    @pl.when(phase == 0)
    def _scatter():
        act = active_ref[...]
        util_s[...] = util_s[...].at[links_ref[...]].add(
            jnp.broadcast_to((act * rates_ref[...])[:, None],
                             links_ref.shape))
        cnt_s[...] = cnt_s[...].at[links_ref[...]].add(
            jnp.broadcast_to(act[:, None], links_ref.shape))

    @pl.when(phase == 1)
    def _factors():
        hot = ((cnt_s[...] >= 2.0) &
               (util_s[...] >= cap_ref[...] * (1.0 - util_eps))).astype(dtype)
        flow_hot = jnp.max(hot[links_ref[...]], axis=1)
        rates = rates_ref[...]
        q = q_ref[...]
        w = jnp.minimum(jnp.sqrt(jnp.maximum(rates * wsq_ref[...], 0.0)),
                        wnd_ref[...])
        gbn = (1.0 - q) / jnp.maximum(1.0 - q + q * w, 1e-30)
        alpha = jnp.clip(dcqcn_num / jnp.maximum(rates, 1e-30), 0.0, 1.0)
        dc = 1.0 - 0.25 * alpha * ecn_ref[...] * flow_hot
        floor = jnp.minimum(dcqcn_min / jnp.maximum(rates, 1e-30), 1.0)
        fac_out[...] = jnp.clip(gbn * jnp.maximum(dc, floor), 1e-9, 1.0)


def loss_factors_pallas(flow_links, rates, active, cap, q, wsq, wnd, ecn, *,
                        dcqcn_num: float, dcqcn_min: float,
                        util_eps: float = 1e-3, block_f: int = 256,
                        interpret: bool = False):
    """Fused loss/DCQCN factors; pads F with zero (factor-1) sentinel rows."""
    if not HAS_PALLAS:                          # pragma: no cover - gated
        raise RuntimeError("pallas is not importable; use mode='ref'")
    n_flows, n_hops = flow_links.shape
    n_caps = cap.shape[0]
    dtype = cap.dtype
    tf = min(block_f, max(n_flows, 1))
    pad = (-n_flows) % tf
    if pad:
        flow_links = jnp.concatenate(
            [flow_links, jnp.full((pad, n_hops), n_caps - 1, jnp.int32)])
        zeros = jnp.zeros(pad, dtype)
        rates, active, q, wsq, wnd, ecn = (
            jnp.concatenate([v, zeros])
            for v in (rates, active, q, wsq, wnd, ecn))
    f_pad = n_flows + pad
    n_tiles = f_pad // tf

    tile_spec = lambda: pl.BlockSpec((tf, n_hops), lambda p, i: (i, 0))
    vec_spec = lambda: pl.BlockSpec((tf,), lambda p, i: (i,))
    cap_spec = lambda: pl.BlockSpec((n_caps,), lambda p, i: (0,))

    fac = pl.pallas_call(
        functools.partial(_loss_kernel, dcqcn_num=dcqcn_num,
                          dcqcn_min=dcqcn_min, util_eps=util_eps),
        grid=(2, n_tiles),
        in_specs=[tile_spec(), vec_spec(), vec_spec(), cap_spec(),
                  vec_spec(), vec_spec(), vec_spec(), vec_spec()],
        out_specs=vec_spec(),
        out_shape=jax.ShapeDtypeStruct((f_pad,), dtype),
        scratch_shapes=[pltpu.VMEM((n_caps,), dtype),    # link utilization
                        pltpu.VMEM((n_caps,), dtype)],   # active-flow count
        interpret=interpret,
    )(flow_links, rates, active, cap, q, wsq, wnd, ecn)
    return fac[:n_flows]


def loss_factors(flow_links, rates, active, cap, q, wsq, wnd, ecn, *,
                 dcqcn_num: float, dcqcn_min: float, mode=None,
                 block_f: int = 256):
    """Mode-dispatched loss/DCQCN rate factors, (F,) in (0, 1].

    Same mode contract as ``maxmin_round`` (ref / pallas / interpret,
    ``REPRO_MAXMIN`` override); the oracle lives in
    ``ref.py:loss_factors_reference``.
    """
    mode = _resolve_mode(mode)
    if mode == "ref":
        return loss_factors_reference(flow_links, rates, active, cap, q,
                                      wsq, wnd, ecn, dcqcn_num=dcqcn_num,
                                      dcqcn_min=dcqcn_min)
    return loss_factors_pallas(flow_links, rates, active, cap, q, wsq, wnd,
                               ecn, dcqcn_num=dcqcn_num, dcqcn_min=dcqcn_min,
                               block_f=block_f,
                               interpret=(mode == "interpret"))
