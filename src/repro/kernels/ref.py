"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the interpret=True kernel tests compare against
(assert_allclose over shape/dtype sweeps).  They are deliberately the
simplest possible O(S^2)-memory implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def maxmin_round_reference(flow_links, frozen, rates, cap_rem, *,
                           tol: float = 1e-6):
    """One progressive-filling round of max-min fair allocation.

    The oracle for ``kernels/maxmin.py`` — plain jnp, materializing every
    intermediate the fused kernel is allowed to keep on-chip.

    flow_links (F, H) int32 link ids padded with the sentinel (last)
    index of ``cap_rem``; frozen (F,) 0/1 mask in cap dtype (padding
    rows enter frozen); rates (F,); cap_rem (L+1,) with cap_rem[-1]=inf.
    ``tol`` is the relative freeze slack (1e-6 suits float32 solves;
    the float64 dynamic-segment solver passes 1e-12 to mirror the numpy
    ``flowsim.static_maxmin`` filling).  Returns the round's
    (rates, frozen, cap_rem).
    """
    n_caps = cap_rem.shape[0]
    dtype = cap_rem.dtype
    live = 1.0 - frozen
    # per-link demand: scatter every live flow onto its links
    cnt = jnp.zeros(n_caps, dtype).at[flow_links].add(
        jnp.broadcast_to(live[:, None], flow_links.shape))
    share = jnp.where(cnt > 0.0, cap_rem / jnp.maximum(cnt, 1.0), jnp.inf)
    # each flow's tightest link share (sentinel gathers inf)
    tightest = jnp.min(share[flow_links], axis=1)
    limit = jnp.where(frozen > 0.5, jnp.inf, tightest)
    b = jnp.min(limit)
    newly = (frozen < 0.5) & (limit <= b * (1.0 + tol))
    newf = newly.astype(dtype)
    rates = jnp.where(newly, b, rates)
    used = jnp.zeros(n_caps, dtype).at[flow_links].add(
        jnp.broadcast_to((newf * b)[:, None], flow_links.shape))
    cap_rem = jnp.maximum(cap_rem - used, 0.0)
    return rates, jnp.minimum(frozen + newf, 1.0), cap_rem


def loss_factors_reference(flow_links, rates, active, cap, q, wsq, wnd,
                           ecn, *, dcqcn_num: float, dcqcn_min: float,
                           util_eps: float = 1e-3):
    """Expected-value loss/DCQCN rate-correction factors, (F,) in (0, 1].

    The oracle for ``kernels/maxmin.py:loss_factors`` — the per-flow
    multiplier the fluid solver applies to its max-min rates so lossy
    go-back-N transfers slow down the way the packet engine's do (see
    docs/ARCHITECTURE.md "Loss & congestion model"):

    - go-back-N replay: a loss costs ``W = min(sqrt(rate * wsq), wnd)``
      replayed packets (``wsq`` pre-folds the calibrated replay window
      and NACK-merge damping, so ``sqrt(rate * wsq)`` is the geometric
      mean of the flow- and link-BDP in packets); the steady-state
      goodput fraction is ``(1-q) / (1-q + q*W)``.
    - DCQCN: flows crossing a *shared saturated* link (>= 2 active
      flows, utilization at capacity) with ECN marking enabled sit on
      the CNP/recovery sawtooth; the average undershoot is
      ``alpha_eq / 4`` with ``alpha_eq = dcqcn_num / rate`` (clipped to
      [0, 1]), floored so the effective rate never falls below the
      DCQCN minimum rate — and never negative or above capacity, since
      the returned factor is always in (0, 1].

    flow_links (F, H) int32 padded with the sentinel (last) index of
    ``cap``; rates (F,) solved max-min rates; active (F,) 0/1 mask in
    cap dtype; cap (L+1,) with cap[-1] = inf (the sentinel can never be
    saturated); q / wsq / wnd / ecn (F,) per-flow loss-model arrays
    (all-zero rows — padding or lossless flows — get factor exactly 1).
    """
    n_caps = cap.shape[0]
    dtype = cap.dtype
    # per-link utilization + active-flow count (one scatter each)
    util = jnp.zeros(n_caps, dtype).at[flow_links].add(
        jnp.broadcast_to((active * rates)[:, None], flow_links.shape))
    cnt = jnp.zeros(n_caps, dtype).at[flow_links].add(
        jnp.broadcast_to(active[:, None], flow_links.shape))
    hot = ((cnt >= 2.0) & (util >= cap * (1.0 - util_eps))).astype(dtype)
    flow_hot = jnp.max(hot[flow_links], axis=1)
    # go-back-N: replay window in packets, then steady-state goodput
    w = jnp.minimum(jnp.sqrt(jnp.maximum(rates * wsq, 0.0)), wnd)
    gbn = (1.0 - q) / jnp.maximum(1.0 - q + q * w, 1e-30)
    # DCQCN sawtooth undershoot on ECN-marked (shared, saturated) links
    alpha = jnp.clip(dcqcn_num / jnp.maximum(rates, 1e-30), 0.0, 1.0)
    dc = 1.0 - 0.25 * alpha * ecn * flow_hot
    floor = jnp.minimum(dcqcn_min / jnp.maximum(rates, 1e-30), 1.0)
    dc = jnp.maximum(dc, floor)
    return jnp.clip(gbn * dc, 1e-9, 1.0)


def mha_reference(q, k, v, *, causal: bool, window: int = 0):
    """Multi-head attention oracle. q (B,Sq,H,D); k,v (B,Skv,KVH,D).
    GQA: H = KVH * rep.  window > 0 = sliding window (causal band)."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, d).astype(jnp.float32)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_reference(q, k, v, *, kv_len):
    """Single-token decode oracle. q (B,H,D); k,v (B,S,KVH,D);
    kv_len (B,) valid prefix lengths."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, d).astype(jnp.float32)
    logits = jnp.einsum("bkrd,bskd->bkrs", qg, k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(d))
    valid = jnp.arange(s)[None, :] < kv_len[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def ssd_reference(x, dt, a, B_, C_):
    """Sequential SSD (Mamba-2) oracle — the exact recurrence.

    x (B,S,H,P); dt, a (B,S,H); B_, C_ (B,S,N).
      S_t = exp(a_t) * S_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t . S_t
    Returns (y (B,S,H,P), final state (B,H,N,P))."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    f32 = jnp.float32
    x, dt, a = x.astype(f32), dt.astype(f32), a.astype(f32)
    B_, C_ = B_.astype(f32), C_.astype(f32)

    def step(S, inp):
        xt, dtt, at, Bt, Ct = inp
        S = S * jnp.exp(at)[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", Bt, dtt, xt)
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((b, h, n, p), f32)
    S, ys = jax.lax.scan(step, S0, (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                                    a.swapaxes(0, 1), B_.swapaxes(0, 1),
                                    C_.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), S
