"""Pallas TPU flash attention (GQA / causal / sliding-window).

TPU-native adaptation of the memory-bound attention hot spot (DESIGN.md
§6): the online-softmax tiles live in VMEM, sized so each (block_q x
block_k) score tile plus the f32 (m, l, acc) running statistics fit
comfortably; block shapes default to MXU-aligned 128 multiples.

Grid: (batch, q_heads, Sq / block_q, Skv / block_k) — the LAST axis is
the sequential reduction axis on TPU, so the running statistics are
carried in VMEM scratch across kv-block steps.  Causal and sliding-window
masks are applied per-tile from broadcasted iotas; fully-masked tiles are
skipped with pl.when (this is the FLOP saving XLA's masked dense
attention cannot express — see the §Roofline useful-flops discussion).

GQA is handled in the k/v index_map (kv head = q head // rep) so no
head replication is materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, n_kv_blocks: int, kv_limit: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile visibility: skip tiles fully outside the causal band / window
    diag_reachable = k_start <= q_start + block_q - 1
    if window:
        in_window = k_start + block_k - 1 > q_start - window
        visible = diag_reachable & in_window if causal else in_window
    else:
        visible = diag_reachable if causal else True

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < kv_limit          # padded KV tail never wins
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    kv_limit: int | None = None,
                    interpret: bool = False):
    """q (B, Sq, H, D); k, v (B, Skv, KVH, D) -> (B, Sq, H, D).

    Sq % block_q == 0 and Skv % block_k == 0 (pad upstream).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    rep = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q,
                                                      block_k)
    n_q = sq // block_q
    n_k = skv // block_k
    # layout: heads-major so each grid step owns a contiguous (S, D) tile
    qt = q.transpose(0, 2, 1, 3)          # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)          # (B, KVH, Skv, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, n_q, n_k)
    kern = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
        kv_limit=skv if kv_limit is None else kv_limit)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, rep=rep:
                         (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, rep=rep:
                         (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
