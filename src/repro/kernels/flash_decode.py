"""Pallas TPU split-KV flash decode — the per-shard compute under Gleam's
many-to-one combine tree (DESIGN.md §2.2/§6).

One query token attends a long KV cache.  Grid (batch, q_heads,
S / block_k); the last axis sequentially reduces KV blocks with running
(m, l, acc) statistics in VMEM scratch.  Outputs are BOTH the normalized
attention result and the (m, l) softmax statistics, so the distributed
layer (core/collectives.softmax_combine) can merge per-shard partials up
the aggregation tree exactly like the switch merges per-port ack_psn:
an associative monoid combine (max/rescale-add instead of min).

kv_len masks the unfilled cache tail (continuous batching).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out, l_out,
            m_ref, l_ref, acc_ref, *, scale: float, block_k: int,
            n_kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[pl.program_id(0)]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        m_out[0, 0] = m_ref[...]
        l_out[0, 0] = l_ref[...]


def flash_decode(q, k, v, kv_len, *, block_k: int = 512,
                 interpret: bool = False):
    """q (B, H, D); k, v (B, S, KVH, D); kv_len (B,) int32.

    Returns (out (B, H, D), m (B, H), l (B, H)) — out normalized, (m, l)
    the softmax statistics for cross-shard combining (acc = out * l).
    """
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    n_k = s // block_k
    qt = q[:, :, None, :]                   # (B, H, 1, D)
    kt = k.transpose(0, 2, 1, 3)            # (B, KVH, S, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, n_k)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(d),
                             block_k=block_k, n_kv_blocks=n_k)
    out, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # kv_len, full (B,)
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki, rep=rep: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki, rep=rep: (bi, hi // rep, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ki: (bi, hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qt, kt, vt)
    return out[:, :, 0, :], m[..., 0], l[..., 0]
