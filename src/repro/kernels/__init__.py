# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# maxmin.py is the simulator-core hot-spot: the fused progressive-
# filling round behind the flow engine (core/flowsim_jax.py), with its
# pure-jnp oracle in ref.py next to the attention/SSD oracles.
