"""Pallas TPU chunked SSD scan (Mamba-2 state-space duality).

The SSD recurrence  S_t = exp(a_t) S_{t-1} + dt_t B_t x_t^T,
y_t = C_t . S_t  is computed chunk-by-chunk (arXiv:2405.21060 §6):
inside a chunk the contribution is a masked quadratic "attention-like"
term (MXU work); across chunks only the (N x P) state is carried.

Grid (batch, heads, S / chunk): the last axis walks chunks sequentially
with the running state in VMEM scratch — exactly the TPU-native shape of
the recurrence: chunk-local dense matmuls for the MXU, a tiny carried
state instead of a length-S serial scan.

VMEM working set per step (chunk=128, N=128, P=64, f32):
x (128x64) + B/C (128x128) + L (128x128) + state (128x64) + y (128x64)
~= 320 KB — comfortably inside the ~16 MB VMEM budget, MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, state_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    B_ = b_ref[0].astype(jnp.float32)               # (Q, N)
    C_ = c_ref[0].astype(jnp.float32)               # (Q, N)

    cum = jnp.cumsum(a)                              # (Q,)
    # intra-chunk: L[i, j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(qi >= kj, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]                            # (Q, P)
    y_diag = jax.lax.dot_general(scores * L, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    # inter-chunk: y_off = (C * exp(cum)) @ S_prev
    S_prev = state_ref[...]                          # (N, P)
    y_off = jax.lax.dot_general(C_ * jnp.exp(cum)[:, None], S_prev,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)
    # state update: S = exp(cum_last) S_prev + B^T (exp(cum_last - cum) dt x)
    decay = jnp.exp(cum[-1] - cum)                   # (Q,)
    S_new = (jnp.exp(cum[-1]) * S_prev
             + jax.lax.dot_general(B_, xdt * decay[:, None],
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    state_ref[...] = S_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_ref[0, 0] = S_new


def ssd_scan(x, dt, a, B_, C_, *, chunk: int = 128,
             interpret: bool = False):
    """x (B,S,H,P); dt, a (B,S,H); B_, C_ (B,S,N).

    Returns (y (B,S,H,P) in x.dtype, final state (B,H,N,P) f32).
    S % chunk == 0 (pad upstream).
    """
    b, s, h, p = x.shape
    n = B_.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    grid = (b, h, nc)
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, S = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, B_, C_)
    return y, S
