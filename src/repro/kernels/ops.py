"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
where the kernels compile to Mosaic.  The wrappers pad ragged sequence
lengths up to block multiples and slice back, so callers never care about
tile alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import flash_decode as fd
from repro.kernels import ssd_scan as ssd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """Drop-in flash attention. q (B,Sq,H,D); k,v (B,Skv,KVH,D)."""
    interpret = _on_cpu() if interpret is None else interpret
    sq0, skv0 = q.shape[1], k.shape[1]
    bq = min(block_q, max(sq0, 16))
    bk = min(block_k, max(skv0, 16))
    q, _ = _pad_to(q, 1, bq)
    k, _ = _pad_to(k, 1, bk)
    v, _ = _pad_to(v, 1, bk)
    out = fa.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=bq, block_k=bk, kv_limit=skv0,
                             interpret=interpret)
    return out[:, :sq0]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k, v, kv_len, *, block_k=512, interpret=None):
    """Split-KV decode. q (B,H,D); k,v (B,S,KVH,D); kv_len (B,).
    Returns (out, m, l) — see kernels/flash_decode.py."""
    interpret = _on_cpu() if interpret is None else interpret
    s0 = k.shape[1]
    bk = min(block_k, max(s0, 16))
    k, _ = _pad_to(k, 1, bk)
    v, _ = _pad_to(v, 1, bk)
    return fd.flash_decode(q, k, v, kv_len, block_k=bk,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, B_, C_, *, chunk=128, interpret=None):
    """Chunked SSD scan. Returns (y, final_state)."""
    interpret = _on_cpu() if interpret is None else interpret
    s0 = x.shape[1]
    ch = min(chunk, max(s0, 16))
    if s0 % ch:
        x, _ = _pad_to(x, 1, ch)
        dt, _ = _pad_to(dt, 1, ch)
        a, _ = _pad_to(a, 1, ch)       # exp(a)=exp(0)=1 keeps state frozen
        B_, _ = _pad_to(B_, 1, ch)
        C_, _ = _pad_to(C_, 1, ch)
    y, S = ssd.ssd_scan(x, dt, a, B_, C_, chunk=ch, interpret=interpret)
    return y[:, :s0], S
