"""Gleam collectives on the TPU ICI (the adapted layer, DESIGN.md §2.2).

The paper's two data-plane primitives map onto mesh collectives:

- one-to-many *in-fabric multicast*  -> ``tree_broadcast`` (binomial tree of
  collective_permutes; the sender transmits O(log n) times instead of n-1,
  interior "switches" forward — cf. Fig. 4 left).
- many-to-one *feedback aggregation* -> ``tree_reduce`` /
  ``butterfly_allreduce`` with an arbitrary associative combine — exactly
  Algorithm 2/3's min-PSN aggregation generalized to any monoid.  The
  flagship use is ``softmax_combine``: merging split-KV decode-attention
  partials (m, l, acc) up the aggregation tree.

Baselines mirror the paper's §2.3 design space:
- ``unicast_broadcast``  — "multiple unicasts" (root sends n-1 times).
- ``ring_broadcast``     — overlay multicast (store-and-forward pipeline).

All functions are shard_map-compatible: they must be called INSIDE a
shard_map body (they use axis names).  Axis sizes must be powers of two for
the tree/butterfly schedules (production meshes: 2, 16).
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


from repro.compat import axis_size as _axis_size  # noqa: E402


def _log2(n: int) -> int:
    k = int(math.log2(n))
    assert 2 ** k == n, f"axis size {n} must be a power of two"
    return k


# ---------------------------------------------------------------- schedules

def tree_broadcast(x, axis_name, root: int = 0):
    """Binomial-tree one-to-many multicast (Gleam in-fabric forwarding).

    Round j: ranks [0, 2^j) forward to ranks [2^j, 2^{j+1}) (rank space is
    rotated so `root` is rank 0).  log2(n) rounds; each value crosses each
    link once -> optimal forwarding, no sender bottleneck.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    rank = (idx - root) % n
    for j in range(_log2(n)):
        half = 2 ** j
        perm = [(((r + root) % n), ((r + half + root) % n))
                for r in range(half)]
        recv = jax.lax.ppermute(x, axis_name, perm)
        is_recv = (rank >= half) & (rank < 2 * half)
        x = jax.tree.map(
            lambda a, b: jnp.where(is_recv, b, a), x, recv)
    return x


def unicast_broadcast(x, axis_name, root: int = 0):
    """'Multiple unicasts' baseline: root sends to every receiver in turn
    (n-1 serialized rounds; the sender's link is the bottleneck)."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    for t in range(1, n):
        dst = (root + t) % n
        recv = jax.lax.ppermute(x, axis_name, [(root, dst)])
        x = jax.tree.map(lambda a, b: jnp.where(idx == dst, b, a), x, recv)
    return x


def ring_broadcast(x, axis_name, root: int = 0, chunks: int = 1):
    """Overlay-multicast baseline: store-and-forward around a ring.

    chunks > 1 pipelines the transfer (the paper's Ring algorithm): total
    rounds = (n - 1) + (chunks - 1) instead of (n - 1) * chunks.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    rank = (idx - root) % n
    perm = [(((r + root) % n), ((r + 1 + root) % n)) for r in range(n - 1)]

    def fwd_rounds(val):
        v = val
        for t in range(n - 1):
            recv = jax.lax.ppermute(v, axis_name, perm)
            v = jax.tree.map(
                lambda a, b: jnp.where(rank == t + 1, b, a), v, recv)
        return v

    if chunks <= 1:
        return fwd_rounds(x)
    leaves, treedef = jax.tree.flatten(x)
    split = [jnp.array_split(leaf, chunks) for leaf in leaves]
    outs = []
    for c in range(chunks):
        piece = jax.tree.unflatten(treedef, [s[c] for s in split])
        outs.append(fwd_rounds(piece))
    out_leaves = [jnp.concatenate([jax.tree.leaves(o)[i] for o in outs])
                  for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, out_leaves)


def tree_reduce(x, axis_name, combine: Callable, root: int = 0):
    """Binomial-tree many-to-one aggregation (Algorithm 2/3 generalized).

    Mirror of tree_broadcast: round j, ranks [2^j, 2^{j+1}) send to ranks
    [0, 2^j) which combine.  After log2(n) rounds rank-0 (root) holds the
    full reduction; other ranks hold partials (garbage to callers).
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    rank = (idx - root) % n
    for j in reversed(range(_log2(n))):
        half = 2 ** j
        perm = [(((r + half + root) % n), ((r + root) % n))
                for r in range(half)]
        recv = jax.lax.ppermute(x, axis_name, perm)
        merged = combine(x, recv)
        is_recv = rank < half
        x = jax.tree.map(lambda a, b: jnp.where(is_recv, b, a), x, merged)
    return x


def butterfly_allreduce(x, axis_name, combine: Callable):
    """Recursive-doubling allreduce with an arbitrary associative combine:
    log2(n) full-exchange rounds (reduce+multicast fused)."""
    n = _axis_size(axis_name)
    for j in range(_log2(n)) if n > 1 else []:
        mask = 2 ** j
        perm = [(i, i ^ mask) for i in range(n)]
        recv = jax.lax.ppermute(x, axis_name, perm)
        x = combine(x, recv)
    return x


def tree_allreduce(x, axis_name, combine: Callable, root: int = 0):
    """Gleam round trip: many-to-one aggregation then one-to-many
    multicast of the result (Fig. 4 right then left)."""
    x = tree_reduce(x, axis_name, combine, root)
    return tree_broadcast(x, axis_name, root)


# ---------------------------------------------------------------- combines

def _softmax_merge(a, b):
    """Associative merge of split-KV softmax partials (m, l, acc)."""
    m_a, l_a, acc_a = a
    m_b, l_b, acc_b = b
    m = jnp.maximum(m_a, m_b)
    sa = jnp.exp(m_a - m)
    sb = jnp.exp(m_b - m)
    l = l_a * sa + l_b * sb
    acc = acc_a * sa[..., None] + acc_b * sb[..., None]
    return m, l, acc


def softmax_combine(parts, axis_names: Sequence[str], schedule: str = "xla"):
    """Merge (m, l, acc) decode-attention partials across seq-shard axes.

    schedule:
      "xla"        — pmax/psum (XLA picks its own all-reduce schedule);
      "gleam_tree" — explicit butterfly aggregation tree (the paper's
                     in-fabric feedback aggregation, adapted);
    Both are exact (the merge is associative up to fp error).
    """
    m, l, acc = parts
    if schedule == "gleam_tree":
        for ax in axis_names:
            m, l, acc = butterfly_allreduce((m, l, acc), ax, _softmax_merge)
        return m, l, acc
    m_g = m
    for ax in axis_names:
        m_g = jax.lax.pmax(m_g, ax)
    scale = jnp.exp(m - m_g)
    l_s = l * scale
    acc_s = acc * scale[..., None]
    for ax in axis_names:
        l_s = jax.lax.psum(l_s, ax)
        acc_s = jax.lax.psum(acc_s, ax)
    return m_g, l_s, acc_s


def allreduce_sum(x, axis_names: Sequence[str], schedule: str = "xla"):
    """Gradient-sync allreduce with selectable schedule (DP sync)."""
    if schedule in ("xla", "psum"):
        for ax in axis_names:
            x = jax.tree.map(lambda a: jax.lax.psum(a, ax), x)
        return x
    comb = lambda a, b: jax.tree.map(jnp.add, a, b)  # noqa: E731
    for ax in axis_names:
        if schedule == "gleam_tree":
            x = butterfly_allreduce(x, ax, comb)
        elif schedule == "ring":
            # reduce around the ring then ring-broadcast (overlay baseline)
            x = tree_reduce(x, ax, comb)
            x = ring_broadcast(x, ax)
        elif schedule == "unicast":
            x = tree_reduce(x, ax, comb)
            x = unicast_broadcast(x, ax)
        else:
            raise ValueError(schedule)
    return x


# ------------------------------------------------- schedule cost model

# The analytic alpha-beta JCT model moved to core/metrics.py with the
# rest of the accounting; re-exported here for existing callers.
from repro.core.metrics import schedule_cost  # noqa: E402,F401
