"""Workload IR — engine-agnostic group operations with first-class
transport strategies.

The paper's headline results (§5, Figs. 9-16) are *comparisons*: the
same group operation carried by Gleam's in-fabric multicast vs the
application-layer transports of §2.3 (multiple unicasts, pipelined
ring, binary tree).  This module makes that comparison axis a *value*
instead of a parallel class hierarchy:

- ``GroupOp``   — one declarative group operation: ``op`` (bcast /
  write / unicast / allreduce), ``members``, ``nbytes``, and a
  ``transport`` naming how the bytes move (``gleam`` | ``multiunicast``
  | ``ring`` | ``binary-tree``).
- ``MemberEvent`` — a timed membership change riding a ``GroupOp``:
  ``kind`` (``join`` | ``leave`` | ``fail`` | ``master-switch``),
  ``member``, and ``at`` (seconds after the op's submission).  A
  ``GroupOp`` with a non-empty ``events`` tuple is a *dynamic* op: the
  engines lower the events onto their membership control plane (the
  packet engine schedules in-band MFT-update envelopes; the flow
  engine integrates piecewise-membership segments — see
  ``core/engine.py`` and ``docs/ARCHITECTURE.md``).
- ``Workload``  — an ordered batch of ``GroupOp``s that runs as ONE
  independent scenario (no bandwidth sharing with other workloads).
- the **transport registry** — ``Transport`` descriptors looked up by
  ``get_transport``; each engine lowers a descriptor its own way (the
  packet engine onto the ``baselines`` relay machinery, the flow
  engine onto the transport's relay edge-set; see ``core/engine.py``).

Both simulation engines consume the IR through one entry point:

    rec  = eng.stage(GroupOp("bcast", members, nbytes,
                             transport="ring"))   # -> MsgRecord
    recs = eng.run_workloads([wl_a, wl_b])        # batched scenarios

which replaces the deprecated per-op staging methods (``add_bcast`` /
``add_write`` / ``add_unicast`` — thin shims now delegate here).

The IR is plain data: ``to_dict`` / ``from_dict`` round-trip a
``Workload`` through JSON-compatible dicts, so sweeps can be declared
in config files and checked into reference fixtures
(``tools/check_fig09.py`` drives CI's divergence gate this way).

Built-in transports register from ``core/baselines.py`` (imported
lazily on first lookup, so flow-only users never pay for it eagerly);
``register_transport`` accepts additional strategies.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.faults import FAULT_CHOICES, FaultEvent

__all__ = [
    "OP_CHOICES", "TRANSPORT_CHOICES", "EVENT_CHOICES", "FAULT_CHOICES",
    "RELAY_OVERHEAD", "GroupOp", "MemberEvent", "FaultEvent", "Workload",
    "Transport", "register_transport", "get_transport", "transport_names",
]

OP_CHOICES = ("bcast", "write", "unicast", "allreduce")

# Timed membership events a dynamic GroupOp may carry (§3.4 maintenance).
EVENT_CHOICES = ("join", "leave", "fail", "master-switch")

# The four §5 transport strategies.  The registry may hold more
# (register_transport), but these are what --transport advertises.
TRANSPORT_CHOICES = ("gleam", "multiunicast", "ring", "binary-tree")

# Spelling tolerance: the pre-IR baselines API called the binary tree
# "bintree"; argparse-unfriendly spellings normalize too.
_TRANSPORT_ALIASES = {
    "bintree": "binary-tree",
    "binary_tree": "binary-tree",
    "binarytree": "binary-tree",
    "multi-unicast": "multiunicast",
}

# Host store-and-forward cost per relayed message (RX stack -> CPU ->
# TX stack, §2.3) — the overlay transports' per-hop software penalty.
# Lives here (not baselines.py) because every engine's overlay lowering
# needs it; baselines re-exports it for compatibility.
RELAY_OVERHEAD = 1.5e-6


# ============================================================== registry

@dataclasses.dataclass(frozen=True)
class Transport:
    """How a one-to-many operation moves bytes.

    ``relay_edges(members) -> [(parent, child), ...]`` is the overlay
    relay schedule over the member list (source first); ``None`` means
    the transport is *native* — the fabric itself replicates (Gleam)
    and the engine's multicast machinery applies.  ``chunked``
    transports pipeline the message in ``GroupOp.chunks`` segments,
    re-serialized at every relay hop.  ``packet_bcast(net, members,
    chunks, **qp_kw)`` builds the packet-level runner (a
    ``baselines._Bcast``); ``None`` again means native.
    """

    name: str
    relay_edges: Optional[Callable[[Sequence[str]],
                                   List[Tuple[str, str]]]] = None
    chunked: bool = False
    packet_bcast: Optional[Callable] = None

    @property
    def native(self) -> bool:
        return self.relay_edges is None


_TRANSPORTS: Dict[str, Transport] = {}


def register_transport(t: Transport) -> Transport:
    """Add a transport strategy to the registry (last writer wins)."""
    _TRANSPORTS[t.name] = t
    return t


_builtins_loaded = False


def _ensure_builtin_transports() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        _builtins_loaded = True
        # baselines.py registers the four built-ins at import time
        from repro.core import baselines  # noqa: F401  (side effect)


def transport_names() -> Tuple[str, ...]:
    """Registered transport names (built-ins register on first use)."""
    _ensure_builtin_transports()
    return tuple(sorted(_TRANSPORTS))


def canonical_transport(name: str) -> str:
    """Normalize aliases and validate; raises ValueError when unknown."""
    _ensure_builtin_transports()
    canon = _TRANSPORT_ALIASES.get(name, name)
    if canon not in _TRANSPORTS:
        raise ValueError(
            f"unknown transport {name!r}; choose from "
            f"{tuple(sorted(_TRANSPORTS))}")
    return canon


def get_transport(name: str) -> Transport:
    """Look up a transport by name; ValueError lists the valid names."""
    return _TRANSPORTS[canonical_transport(name)]


# ==================================================================== IR

@dataclasses.dataclass(frozen=True)
class MemberEvent:
    """One timed membership change on a dynamic GroupOp.

    ``at`` is seconds after the op's submission.  ``join`` adds a host
    that is not yet a member (it locks onto the live PSN stream and is
    not required to deliver the in-flight message); ``leave`` is the
    graceful departure of a receiver; ``fail`` is a silent receiver
    crash (isolated by the master after its failure-detection delay);
    ``master-switch`` hands the master+source roles to another member
    (Appendix B, no re-registration).
    """

    kind: str
    member: str
    at: float

    def __post_init__(self):
        if self.kind not in EVENT_CHOICES:
            raise ValueError(
                f"unknown event kind {self.kind!r}; choose from "
                f"{EVENT_CHOICES}")
        if not self.member:
            raise ValueError("event member must be a host name")
        if self.at < 0:
            raise ValueError(f"event time must be >= 0, got {self.at}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MemberEvent":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown MemberEvent fields: {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class GroupOp:
    """One declarative group operation.

    ``members`` is the participant list; the first member is the
    source unless ``source`` overrides it.  ``unicast`` takes exactly
    ``(src, dst)``.  ``transport`` selects the strategy (see
    TRANSPORT_CHOICES); ``chunks`` is the pipeline depth of the
    chunked overlay transports (ring / binary-tree) and ignored
    elsewhere; ``same_mr`` is the Appendix-C WRITE optimization
    (gleam only); ``key`` seeds ECMP spreading; ``events`` is the
    timed membership-change list making the op *dynamic*.  Joins,
    fails, and master-switches need the native gleam transport (the
    overlay relays have no in-fabric membership to update), but a
    graceful ``leave`` is valid on the overlays too: the engines
    resplice the relay schedule around the departing host at the
    leave instant (the dark-relay repair machinery, minus the
    failure-detection delay).

    ``phase`` is a free-form application label (``"tp-allreduce"``,
    ``"prefill"``, …) carried through to dicts and ignored by the
    engines — ``apps/metrics.py`` groups records by it.

    ``faults`` is the timed fault-injection list (``core/faults.py``):
    link/switch/master faults require the native transport (the fabric
    recovery paths are Gleam machinery); ``host_gone_dark`` is also
    valid on the overlay relays, where the engines repair the relay
    schedule around the dead host instead.

    ``loss_rate`` / ``ecn_backlog`` are the §5 loss/congestion
    scenario parameters (Figs. 15/16), carried in the IR so a sweep
    point is one serializable value: ``loss_rate`` is the per-hop
    switch-egress drop probability; ``ecn_backlog`` the egress-queue
    depth (bytes) beyond which packets are ECN-marked (DCQCN).
    ``None`` defers to the engine-level setting.  The packet engine
    applies them to the fabric (they are physical, hence global per
    scenario — conflicting non-None values in one run are an error);
    the flow engines fold them into the expected-value loss model
    (``core/flowsim.py``).
    """

    op: str
    members: Tuple[str, ...]
    nbytes: int
    transport: str = "gleam"
    source: Optional[str] = None
    same_mr: bool = False
    key: int = 0
    chunks: int = 8
    events: Tuple[MemberEvent, ...] = ()
    faults: Tuple[FaultEvent, ...] = ()
    loss_rate: Optional[float] = None
    ecn_backlog: Optional[float] = None
    phase: str = ""

    def __post_init__(self):
        object.__setattr__(self, "members", tuple(self.members))
        object.__setattr__(self, "transport",
                           canonical_transport(self.transport))
        object.__setattr__(self, "events", tuple(
            e if isinstance(e, MemberEvent) else MemberEvent.from_dict(e)
            for e in self.events))
        object.__setattr__(self, "faults", tuple(
            f if isinstance(f, FaultEvent) else FaultEvent.from_dict(f)
            for f in self.faults))
        if self.op not in OP_CHOICES:
            raise ValueError(
                f"unknown op {self.op!r}; choose from {OP_CHOICES}")
        if self.nbytes <= 0:
            raise ValueError(f"nbytes must be positive, got {self.nbytes}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.op == "unicast":
            if len(self.members) != 2:
                raise ValueError("unicast takes exactly (src, dst) members, "
                                 f"got {len(self.members)}")
        elif len(self.members) < 2:
            raise ValueError(f"{self.op} needs >= 2 members, "
                             f"got {len(self.members)}")
        if self.source is not None and self.source not in self.members:
            raise ValueError(f"source {self.source!r} not in members")
        if self.loss_rate is not None and not 0.0 <= self.loss_rate < 1.0:
            raise ValueError(
                f"loss_rate must be in [0, 1), got {self.loss_rate}")
        if self.ecn_backlog is not None and self.ecn_backlog <= 0.0:
            raise ValueError(
                f"ecn_backlog must be positive bytes, got {self.ecn_backlog}")
        if self.events:
            self._check_events()
        if self.faults:
            self._replay_dynamics()            # validates as it replays

    def _check_events(self) -> None:
        """Replay the membership timeline so invalid sequences fail at
        construction, not mid-simulation."""
        if self.op not in ("bcast", "write"):
            raise ValueError(
                f"membership events require a bcast/write op, not {self.op}")
        if not get_transport(self.transport).native:
            bad = [e for e in self.events if e.kind != "leave"]
            if bad:
                raise ValueError(
                    "only graceful 'leave' events are valid on an overlay "
                    f"relay transport; {self.transport!r} got "
                    f"{bad[0].kind!r} (join/fail/master-switch need the "
                    "native gleam fabric)")
        present = set(self.members)
        source = self.source or self.members[0]
        for e in sorted(self.events, key=lambda e: e.at):
            if e.kind == "join":
                if e.member in present:
                    raise ValueError(
                        f"join: {e.member!r} is already a member at t={e.at}")
                present.add(e.member)
            elif e.kind in ("leave", "fail"):
                if e.member not in present:
                    raise ValueError(
                        f"{e.kind}: {e.member!r} is not a member at t={e.at}")
                if e.member == source:
                    raise ValueError(
                        f"{e.kind}: {e.member!r} is the current source "
                        f"(switch the master first)")
                present.remove(e.member)
            else:                           # master-switch
                if e.member not in present:
                    raise ValueError(
                        f"master-switch: {e.member!r} is not a member "
                        f"at t={e.at}")
                source = e.member

    def ordered_members(self) -> List[str]:
        """Members with the effective source rotated to the front —
        the relay order the overlay schedules consume."""
        members = list(self.members)
        src = self.source or members[0]
        if members[0] != src:
            members.remove(src)
            members.insert(0, src)
        return members

    def sorted_events(self) -> List[MemberEvent]:
        """Events in time order (stable for equal ``at``)."""
        return sorted(self.events, key=lambda e: e.at)

    def sorted_faults(self) -> List[FaultEvent]:
        """Faults in time order (stable for equal ``at``)."""
        return sorted(self.faults, key=lambda f: f.at)

    def _replay_dynamics(self) -> dict:
        """Replay the merged event+fault timeline (events first on
        ties), validating it and returning the role bookkeeping the
        fault-aware lowerings share.  The re-election rule mirrors the
        runtime (``gleam.MulticastGroup``): member rank is list order
        (source first, joins appended), and a crashed master hands the
        source role to the lowest-rank survivor."""
        if self.op not in ("bcast", "write"):
            raise ValueError(
                f"faults require a bcast/write op, not {self.op}")
        native = get_transport(self.transport).native
        order = self.ordered_members()
        present = set(order)
        source = order[0]
        sources = {source}
        dark: set = set()
        snaps: List[Tuple[float, frozenset, str]] = []
        timeline = sorted(
            [(e.at, 0, e) for e in self.events]
            + [(f.at, 1, f) for f in self.faults],
            key=lambda x: (x[0], x[1]))
        for at, is_fault, ev in timeline:
            if not is_fault:
                # _check_events validated the event stream alone; the
                # merged replay re-checks against fault-induced removals
                if ev.kind == "join":
                    if ev.member in present:
                        raise ValueError(
                            f"join: {ev.member!r} already a member at "
                            f"t={at}")
                    present.add(ev.member)
                    order.append(ev.member)
                elif ev.kind in ("leave", "fail"):
                    if ev.member not in present or ev.member == source:
                        raise ValueError(
                            f"{ev.kind}: {ev.member!r} is not a removable "
                            f"member at t={at} (fault interleaving)")
                    present.discard(ev.member)
                    order.remove(ev.member)
                else:                           # master-switch
                    if ev.member not in present:
                        raise ValueError(
                            f"master-switch: {ev.member!r} is not a member "
                            f"at t={at} (fault interleaving)")
                    source = ev.member
                    sources.add(source)
            elif ev.kind == "host_gone_dark":
                if ev.node not in present:
                    raise ValueError(
                        f"host_gone_dark: {ev.node!r} is not a member "
                        f"at t={at}")
                if ev.node == source:
                    raise ValueError(
                        f"host_gone_dark: {ev.node!r} is the current "
                        f"source (use master_crash)")
                present.discard(ev.node)
                order.remove(ev.node)
                dark.add(ev.node)
            elif ev.kind == "master_crash":
                if not native:
                    raise ValueError(
                        "master_crash requires the native (gleam) "
                        f"transport, not {self.transport!r}")
                if len(present) < 2:
                    raise ValueError(
                        f"master_crash at t={at}: no survivor left to "
                        f"re-elect (need >= 2 live members)")
                present.discard(source)
                order.remove(source)
                dark.add(source)
                source = order[0]               # lowest-rank survivor
                sources.add(source)
            else:                               # link/switch fabric fault
                if not native:
                    raise ValueError(
                        f"{ev.kind} requires the native (gleam) "
                        f"transport, not {self.transport!r}")
            snaps.append((at, frozenset(present), source))
        return {"present": frozenset(present), "source": source,
                "sources": frozenset(sources), "dark": frozenset(dark),
                "snaps": snaps}

    def fault_roles(self) -> dict:
        """Membership/source timeline of the merged event+fault replay.

        Returns ``present`` / ``source`` / ``sources`` (every member
        that ever held the source role) / ``dark`` plus ``present_at``
        and ``source_at`` closures over the replay snapshots (state
        *after* everything scheduled at or before the queried time)."""
        roles = self._replay_dynamics()
        snaps = roles["snaps"]
        init = (frozenset(self.ordered_members()), self.ordered_members()[0])

        def _at(t: float) -> Tuple[frozenset, str]:
            state = init
            for at, present, source in snaps:
                if at > t:
                    break
                state = (present, source)
            return state

        roles["present_at"] = lambda t: _at(t)[0]
        roles["source_at"] = lambda t: _at(t)[1]
        return roles

    def surviving_receivers(self) -> List[str]:
        """Initial receivers that are still members when every event has
        fired — the set a dynamic op must deliver to (joiners receive
        from their join point and are not required to complete the
        in-flight message).  With faults, members that went dark or ever
        held the source role (a re-elected master re-originates the
        stream instead of receiving it) are excused too."""
        src = self.source or self.members[0]
        gone = {e.member for e in self.events
                if e.kind in ("leave", "fail")}
        if not self.faults:
            return [m for m in self.members if m != src and m not in gone]
        roles = self._replay_dynamics()
        return [m for m in self.members
                if m in roles["present"] and m not in roles["sources"]]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "GroupOp":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown GroupOp fields: {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass
class Workload:
    """An ordered batch of GroupOps run as ONE independent scenario.

    The builder methods append an op and return it, so benchmark code
    can keep a handle for record lookup:

        wl = Workload("fig09/1MB")
        wl.bcast(members, 1 << 20)                       # gleam
        wl.bcast(members, 1 << 20, transport="ring")     # baseline
        recs = eng.run_workloads([wl])[0]                # per-op records

    ``meta`` is a JSON-compatible free-form dict for generator
    provenance (arrival seed / rate / trace, mesh shape, model name —
    see ``apps/``), round-tripped by ``to_dict``/``from_dict`` so a
    staged app workload is replayable from its serialized form.
    """

    name: str = ""
    ops: List[GroupOp] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    def add(self, op: GroupOp) -> GroupOp:
        self.ops.append(op)
        return op

    def bcast(self, members: Sequence[str], nbytes: int, **kw) -> GroupOp:
        return self.add(GroupOp("bcast", tuple(members), nbytes, **kw))

    def write(self, members: Sequence[str], nbytes: int, **kw) -> GroupOp:
        return self.add(GroupOp("write", tuple(members), nbytes, **kw))

    def unicast(self, src: str, dst: str, nbytes: int, **kw) -> GroupOp:
        return self.add(GroupOp("unicast", (src, dst), nbytes, **kw))

    def allreduce(self, members: Sequence[str], nbytes: int,
                  **kw) -> GroupOp:
        return self.add(GroupOp("allreduce", tuple(members), nbytes, **kw))

    def __len__(self) -> int:
        return len(self.ops)

    def to_dict(self) -> dict:
        d = {"name": self.name, "ops": [op.to_dict() for op in self.ops]}
        if self.meta:               # omitted when empty: old fixtures stable
            d["meta"] = dict(self.meta)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Workload":
        unknown = set(d) - {"name", "ops", "meta"}
        if unknown:
            raise ValueError(f"unknown Workload fields: {sorted(unknown)}")
        return cls(name=d.get("name", ""),
                   ops=[GroupOp.from_dict(o) for o in d.get("ops", [])],
                   meta=dict(d.get("meta", {})))


# relay_plan memo: staging sweeps re-plan the same (transport, member
# tuple) constantly.  Keyed by transport identity (the object is held in
# the value, so a recycled id() can never alias a dead transport);
# coarse-cleared past the cap.
_PLAN_MEMO: Dict[tuple, tuple] = {}
_PLAN_MEMO_ENTRIES = 1 << 16


def relay_plan(transport: Transport, members: Sequence[str]
               ) -> List[Tuple[str, str, int]]:
    """Lowered overlay schedule: ``(parent, child, hops_from_source)``
    per relay edge, hops computed by walking the edge list's parent
    chain — any registered transport only has to provide edges.
    Memoized; each call returns a fresh list."""
    key = (id(transport), tuple(members))
    hit = _PLAN_MEMO.get(key)
    if hit is not None and hit[0] is transport:
        return list(hit[1])
    plan = _relay_plan_uncached(transport, members)
    if len(_PLAN_MEMO) >= _PLAN_MEMO_ENTRIES:
        _PLAN_MEMO.clear()
    _PLAN_MEMO[key] = (transport, plan)
    return list(plan)


def _relay_plan_uncached(transport: Transport, members: Sequence[str]
                         ) -> List[Tuple[str, str, int]]:
    edges = transport.relay_edges(members)
    parent = {b: a for a, b in edges}
    hops: Dict[str, int] = {members[0]: 0}

    def depth(node: str) -> int:
        chain = []
        while node not in hops:                 # iterative: rings are deep
            chain.append(node)
            node = parent[node]
        d = hops[node]
        for n in reversed(chain):
            d = hops[n] = d + 1
        return d

    return [(a, b, depth(b)) for a, b in edges]
