"""Application-layer multicast baselines (§2.3, Fig. 2a/2b).

The overlay *schedules* (which host relays to which) are plain edge
lists — ``ring_edges`` / ``binary_tree_edges`` — shared by both
simulation backends, so packet-level and flow-level runs of the same
baseline route identically:

- ``MultiUnicastBcast`` — the sender transmits identical data over one RC
  connection per receiver (Fig. 2a): sender-link bottleneck.
- ``RingBcast``         — overlay pipeline (the HPL *increasing-ring*):
  the message is split into chunks; receiver i relays each chunk to i+1
  after a host forwarding overhead (RX stack -> CPU -> TX stack, §2.3).
- ``BinaryTreeBcast``   — overlay binomial/binary tree relay, the
  double-binary-tree family's single-tree member.

The classes run over plain RC unicast QPs in the packet simulator and
record per-receiver delivery times so JCT is measured exactly like the
Gleam path.  ``flow_baseline_jct`` is the fluid-model counterpart: it
stages each overlay edge as a unicast flow on a ``FlowEngine`` and
applies the pipelined-round structure analytically on the fluid
steady-state hop time (the standard scalable approximation).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import packet as pk
from repro.core.gleam import GleamNetwork

RELAY_OVERHEAD = 1.5e-6       # host store-and-forward cost per message


# ------------------------------------------------------------- schedules

def ring_edges(members: Sequence[str]) -> List[Tuple[str, str]]:
    """Pipeline ring relay edges: 0 -> 1 -> 2 -> ... -> n-1."""
    return [(members[i], members[i + 1]) for i in range(len(members) - 1)]


def binary_tree_edges(members: Sequence[str]) -> List[Tuple[str, str]]:
    """Binary tree relay edges: member i relays to 2i+1, 2i+2."""
    out = []
    for i, m in enumerate(members):
        for c in (2 * i + 1, 2 * i + 2):
            if c < len(members):
                out.append((m, members[c]))
    return out


def multiunicast_edges(members: Sequence[str]) -> List[Tuple[str, str]]:
    """Fig. 2a: one sender edge per receiver (no relaying)."""
    return [(members[0], m) for m in members[1:]]


def _tree_depth(n: int) -> int:
    """Rounds for the deepest leaf of binary_tree_edges over n members
    (heap indexing: member i sits at depth floor(log2(i+1)))."""
    return int(math.floor(math.log2(n))) if n > 1 else 0


class _Bcast:
    def __init__(self, net: GleamNetwork, members: Sequence[str]):
        self.net = net
        self.members = list(members)
        self.source = self.members[0]
        self.t_deliver: Dict[str, float] = {}
        self.t_start = 0.0

    def n_receivers(self) -> int:
        return len(self.members) - 1

    def jct(self) -> float:
        if len(self.t_deliver) < self.n_receivers():
            return float("inf")
        return max(self.t_deliver.values()) - self.t_start

    def run(self, timeout: float = 10.0) -> float:
        sim = self.net.sim
        deadline = sim.now + timeout
        while len(self.t_deliver) < self.n_receivers():
            before = sim.events
            sim.run(until=deadline)
            if sim.events == before or sim.now >= deadline:
                break
        return self.jct()


class MultiUnicastBcast(_Bcast):
    """Fig. 2a: n-1 serialized copies through the sender's link."""

    def __init__(self, net: GleamNetwork, members: Sequence[str], **qp_kw):
        super().__init__(net, members)
        self.qps = []
        for r in self.members[1:]:
            qa, qb = net.unicast_qp(self.source, r, **qp_kw)
            qb.on_deliver = self._mk_deliver(r)
            self.qps.append((qa, qb))

    def _mk_deliver(self, member):
        def fn(msg_id, now):
            self.t_deliver[member] = now
        return fn

    def start(self, nbytes: int) -> None:
        sim = self.net.sim
        self.t_start = sim.now
        for qa, _ in self.qps:
            qa.submit(nbytes, sim.now)
        sim.kick(sim.hosts[self.source], sim.now)


class _RelayBcast(_Bcast):
    """Common machinery for overlay relays: edges (parent -> child), each
    chunk is re-submitted downstream `RELAY_OVERHEAD` after delivery."""

    def __init__(self, net: GleamNetwork, members: Sequence[str],
                 chunks: int = 8, relay_overhead: float = RELAY_OVERHEAD,
                 **qp_kw):
        super().__init__(net, members)
        self.chunks = max(1, chunks)
        self.relay_overhead = relay_overhead
        self.edges = self._edges()                     # (parent, child)
        self.children: Dict[str, List[str]] = {}
        for a, b in self.edges:
            self.children.setdefault(a, []).append(b)
        self.qp_out: Dict[tuple, object] = {}
        self.n_chunks_done: Dict[str, int] = {}
        for a, b in self.edges:
            qa, qb = net.unicast_qp(a, b, **qp_kw)
            self.qp_out[(a, b)] = qa
            qb.on_deliver = self._mk_deliver(b)
        self.chunk_bytes = 0

    def _edges(self) -> List[tuple]:
        raise NotImplementedError

    def _mk_deliver(self, member: str):
        def fn(msg_id, now):
            self.n_chunks_done[member] = self.n_chunks_done.get(member, 0) + 1
            if self.n_chunks_done[member] == self.chunks:
                self.t_deliver[member] = now
            # relay this chunk downstream after the host forwarding cost
            for c in self.children.get(member, ()):
                qp = self.qp_out[(member, c)]
                sim = self.net.sim
                t = now + self.relay_overhead
                sim.schedule(t, lambda tt, q=qp, n=self.chunk_bytes, m=msg_id:
                             self._relay(q, member, n, m, tt))
        return fn

    def _relay(self, qp, member, nbytes, msg_id, now):
        qp.submit(nbytes, now, msg_id=msg_id)
        self.net.sim.kick(self.net.sim.hosts[member], now)

    def start(self, nbytes: int) -> None:
        sim = self.net.sim
        self.t_start = sim.now
        self.chunk_bytes = max(1, math.ceil(nbytes / self.chunks))
        for c in self.children.get(self.source, ()):
            qp = self.qp_out[(self.source, c)]
            for k in range(self.chunks):
                qp.submit(self.chunk_bytes, sim.now, msg_id=k)
        sim.kick(sim.hosts[self.source], sim.now)


class RingBcast(_RelayBcast):
    """Overlay pipeline ring: 0 -> 1 -> 2 -> ... -> n-1."""

    def _edges(self):
        return ring_edges(self.members)


class BinaryTreeBcast(_RelayBcast):
    """Overlay binary tree: member i relays to 2i+1, 2i+2."""

    def _edges(self):
        return binary_tree_edges(self.members)


# ------------------------------------------------------------ flow level

BASELINE_KINDS = ("multiunicast", "ring", "bintree")


def flow_baseline_jct(engine, kind: str, members: Sequence[str],
                      nbytes: int, *, chunks: int = 8,
                      relay_overhead: float = RELAY_OVERHEAD,
                      key: int = 0) -> float:
    """Fluid-model JCT of an overlay baseline on a flow ``SimEngine``.

    Stages every relay edge as a concurrent unicast flow of one chunk, so
    sender fan-out and any shared fabric links contend for bandwidth the
    max-min-fair way, then applies the schedule's round structure on the
    steady-state chunk time:

    - ``multiunicast``: no rounds — the n-1 full-volume flows' max
      completion IS the JCT (the sender link serializes them);
    - ``ring``:    (n-1 + chunks-1) pipelined rounds;
    - ``bintree``: (depth + chunks-1) rounds, degree-2 fanout contention
      captured by the concurrent per-edge flows.
    """
    n = len(members)
    if n <= 1:
        return 0.0
    if kind == "multiunicast":
        recs = [engine.add_unicast(members[0], m, nbytes, key=key)
                for m in members[1:]]
        engine.run()
        return max(r.jct(1) for r in recs)
    if kind == "ring":
        edges, rounds = ring_edges(members), (n - 1) + (chunks - 1)
    elif kind == "bintree":
        edges, rounds = binary_tree_edges(members), \
            _tree_depth(n) + (chunks - 1)
    else:
        raise ValueError(f"unknown baseline kind {kind!r}")
    chunk = max(1, math.ceil(nbytes / max(chunks, 1)))
    recs = [engine.add_unicast(a, b, chunk, key=key) for a, b in edges]
    engine.run()
    chunk_t = max(r.jct(1) for r in recs)
    return rounds * (chunk_t + relay_overhead)
