"""Application-layer multicast baselines (§2.3, Fig. 2a/2b).

The overlay *schedules* (which host relays to which) are plain edge
lists — ``ring_edges`` / ``binary_tree_edges`` — shared by both
simulation backends, so packet-level and flow-level runs of the same
baseline route identically:

- ``MultiUnicastBcast`` — the sender transmits identical data over one RC
  connection per receiver (Fig. 2a): sender-link bottleneck.
- ``RingBcast``         — overlay pipeline (the HPL *increasing-ring*):
  the message is split into chunks; receiver i relays each chunk to i+1
  after a host forwarding overhead (RX stack -> CPU -> TX stack, §2.3).
- ``BinaryTreeBcast``   — overlay binomial/binary tree relay, the
  double-binary-tree family's single-tree member.

The classes run over plain RC unicast QPs in the packet simulator and
record per-receiver delivery times so JCT is measured exactly like the
Gleam path.

Each baseline is also registered as a first-class **transport** in the
Workload-IR registry (``core/workload.py``), so any engine stages it
through the uniform API:

    eng.stage(GroupOp("bcast", members, nbytes, transport="ring"))

The packet engine lowers the transport onto the relay classes below;
the flow engine lowers it onto the relay edge-set (``ring_edges`` etc.)
and applies the pipelined-round structure analytically on the fluid
steady-state hop time (the standard scalable approximation) — see
``core/engine.py``.  ``flow_baseline_jct`` survives as a thin legacy
wrapper over that path.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import packet as pk
from repro.core import workload as wl
from repro.core.gleam import GleamNetwork

# host store-and-forward cost per message (canonical home: workload.py)
RELAY_OVERHEAD = wl.RELAY_OVERHEAD


# ------------------------------------------------------------- schedules

def ring_edges(members: Sequence[str]) -> List[Tuple[str, str]]:
    """Pipeline ring relay edges: 0 -> 1 -> 2 -> ... -> n-1."""
    return [(members[i], members[i + 1]) for i in range(len(members) - 1)]


def binary_tree_edges(members: Sequence[str]) -> List[Tuple[str, str]]:
    """Binary tree relay edges: member i relays to 2i+1, 2i+2."""
    out = []
    for i, m in enumerate(members):
        for c in (2 * i + 1, 2 * i + 2):
            if c < len(members):
                out.append((m, members[c]))
    return out


def multiunicast_edges(members: Sequence[str]) -> List[Tuple[str, str]]:
    """Fig. 2a: one sender edge per receiver (no relaying)."""
    return [(members[0], m) for m in members[1:]]


class _Bcast:
    def __init__(self, net: GleamNetwork, members: Sequence[str]):
        self.net = net
        self.members = list(members)
        self.source = self.members[0]
        self.t_deliver: Dict[str, float] = {}
        self.t_start = 0.0

    def n_receivers(self) -> int:
        return len(self.members) - 1

    def jct(self) -> float:
        if len(self.t_deliver) < self.n_receivers():
            return float("inf")
        return max(self.t_deliver.values()) - self.t_start

    def run(self, timeout: float = 10.0) -> float:
        sim = self.net.sim
        deadline = sim.now + timeout
        while len(self.t_deliver) < self.n_receivers():
            before = sim.events
            sim.run(until=deadline)
            if sim.events == before or sim.now >= deadline:
                break
        return self.jct()

    def repair_dead_relay(self, member: str, now: float) -> None:
        """A receiver went dark (detected): stop waiting for it.  The
        relay subclasses also splice the schedule around the hole."""
        if member in self.members and member != self.source:
            self.members.remove(member)
            self.t_deliver.pop(member, None)


class MultiUnicastBcast(_Bcast):
    """Fig. 2a: n-1 serialized copies through the sender's link."""

    def __init__(self, net: GleamNetwork, members: Sequence[str], **qp_kw):
        super().__init__(net, members)
        self.qps = []
        for r in self.members[1:]:
            qa, qb = net.unicast_qp(self.source, r, **qp_kw)
            qb.on_deliver = self._mk_deliver(r)
            self.qps.append((qa, qb))

    def _mk_deliver(self, member):
        def fn(msg_id, now):
            if member not in self.members:      # spliced out (leave): the
                return                          # host is up but no longer
            self.t_deliver[member] = now        # a receiver
        return fn

    def start(self, nbytes: int) -> None:
        sim = self.net.sim
        self.t_start = sim.now
        for qa, _ in self.qps:
            qa.submit(nbytes, sim.now)
        sim.kick(sim.hosts[self.source], sim.now)


class _RelayBcast(_Bcast):
    """Common machinery for overlay relays: edges (parent -> child), each
    chunk is re-submitted downstream `RELAY_OVERHEAD` after delivery."""

    def __init__(self, net: GleamNetwork, members: Sequence[str],
                 chunks: int = 8, relay_overhead: float = RELAY_OVERHEAD,
                 **qp_kw):
        super().__init__(net, members)
        self.chunks = max(1, chunks)
        self.relay_overhead = relay_overhead
        self._qp_kw = dict(qp_kw)                      # for repair re-wiring
        self.edges = self._edges()                     # (parent, child)
        self.children: Dict[str, List[str]] = {}
        for a, b in self.edges:
            self.children.setdefault(a, []).append(b)
        self.qp_out: Dict[tuple, object] = {}
        self.n_chunks_done: Dict[str, int] = {}
        for a, b in self.edges:
            qa, qb = net.unicast_qp(a, b, **qp_kw)
            self.qp_out[(a, b)] = qa
            qb.on_deliver = self._mk_deliver(b)
        self.chunk_bytes = 0

    def _edges(self) -> List[tuple]:
        raise NotImplementedError

    def _mk_deliver(self, member: str):
        def fn(msg_id, now):
            if member not in self.members:      # spliced out (leave/dark):
                return                          # don't count or relay
            self.n_chunks_done[member] = self.n_chunks_done.get(member, 0) + 1
            if self.n_chunks_done[member] == self.chunks:
                self.t_deliver[member] = now
            # relay this chunk downstream after the host forwarding cost
            for c in self.children.get(member, ()):
                qp = self.qp_out[(member, c)]
                sim = self.net.sim
                t = now + self.relay_overhead
                sim.schedule(t, lambda tt, q=qp, n=self.chunk_bytes, m=msg_id:
                             self._relay(q, member, n, m, tt))
        return fn

    def _relay(self, qp, member, nbytes, msg_id, now):
        qp.submit(nbytes, now, msg_id=msg_id)
        self.net.sim.kick(self.net.sim.hosts[member], now)

    def start(self, nbytes: int) -> None:
        sim = self.net.sim
        self.t_start = sim.now
        self.chunk_bytes = max(1, math.ceil(nbytes / self.chunks))
        for c in self.children.get(self.source, ()):
            qp = self.qp_out[(self.source, c)]
            for k in range(self.chunks):
                qp.submit(self.chunk_bytes, sim.now, msg_id=k)
        sim.kick(sim.hosts[self.source], sim.now)

    def repair_dead_relay(self, member: str, now: float) -> None:
        """Splice the relay schedule around a dark relay: its children
        re-parent onto ITS parent (ring: the chain re-links; tree: the
        grandparent adopts), fresh QPs are wired for the new edges, and
        the full chunk stream is resubmitted on each — a software relay
        keeps no per-child progress state, so conservative full
        resubmission is the overlay's go-back-N.  The chunk counter
        counts duplicates as progress (a child that already held k
        chunks delivers after ``chunks - k`` repaired arrivals), which
        is the same first-order bookkeeping the flow engine's repaired-
        schedule model applies analytically."""
        if member not in self.members or member == self.source:
            return
        sim = self.net.sim
        kids = self.children.pop(member, [])
        parent = next((a for a, b in self.edges if b == member),
                      self.source)
        super().repair_dead_relay(member, now)
        self.edges = [(a, b) for a, b in self.edges
                      if a != member and b != member]
        for c in kids:
            self.edges.append((parent, c))
            self.children.setdefault(parent, []).append(c)
            qa, qb = self.net.unicast_qp(parent, c, **self._qp_kw)
            self.qp_out[(parent, c)] = qa
            qb.on_deliver = self._mk_deliver(c)
            for k in range(self.chunks):
                qa.submit(self.chunk_bytes, now, msg_id=k)
        if kids:
            sim.kick(sim.hosts[parent], now)


class RingBcast(_RelayBcast):
    """Overlay pipeline ring: 0 -> 1 -> 2 -> ... -> n-1."""

    def _edges(self):
        return ring_edges(self.members)


class BinaryTreeBcast(_RelayBcast):
    """Overlay binary tree: member i relays to 2i+1, 2i+2."""

    def _edges(self):
        return binary_tree_edges(self.members)


# ----------------------------------------------------- transport registry

BASELINE_KINDS = ("multiunicast", "ring", "bintree")


def _packet_multiunicast(net, members, chunks, **qp_kw):
    return MultiUnicastBcast(net, members, **qp_kw)   # chunking n/a


def _packet_ring(net, members, chunks, **qp_kw):
    return RingBcast(net, members, chunks=chunks, **qp_kw)


def _packet_binary_tree(net, members, chunks, **qp_kw):
    return BinaryTreeBcast(net, members, chunks=chunks, **qp_kw)


# The four §5 transport strategies.  "gleam" is native: no relay edges,
# the engines use their own multicast machinery (switch replication /
# one flow over the distribution tree).
wl.register_transport(wl.Transport("gleam"))
wl.register_transport(wl.Transport(
    "multiunicast", relay_edges=multiunicast_edges, chunked=False,
    packet_bcast=_packet_multiunicast))
wl.register_transport(wl.Transport(
    "ring", relay_edges=ring_edges, chunked=True,
    packet_bcast=_packet_ring))
wl.register_transport(wl.Transport(
    "binary-tree", relay_edges=binary_tree_edges, chunked=True,
    packet_bcast=_packet_binary_tree))


# ------------------------------------------------------------ flow level

def flow_baseline_jct(engine, kind: str, members: Sequence[str],
                      nbytes: int, *, chunks: int = 8,
                      relay_overhead: float = RELAY_OVERHEAD,
                      key: int = 0) -> float:
    """Legacy fluid-model JCT of an overlay baseline on a flow engine.

    Thin wrapper over the Workload-IR path: stages one bcast GroupOp
    with the requested transport and returns its JCT (the engines'
    overlay lowering stages every relay edge as a concurrent flow and
    applies the pipelined-round structure on the steady-state chunk
    time — see ``core/engine.py``).  Prefer ``engine.stage`` directly.
    """
    n = len(members)
    if n <= 1:
        return 0.0
    op = wl.GroupOp("bcast", tuple(members), nbytes, transport=kind,
                    chunks=chunks, key=key)
    old_overhead = getattr(engine, "relay_overhead", relay_overhead)
    engine.relay_overhead = relay_overhead
    try:
        rec = engine.stage(op)
        engine.run()
    finally:
        engine.relay_overhead = old_overhead
    return rec.jct(n - 1)
