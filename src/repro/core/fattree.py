"""Datacenter topologies for the faithful Gleam layer.

``Topology`` is a port-numbered multigraph with per-directed-link bandwidth
and propagation delay, plus shortest-path routing helpers:

- ``next_hop_ports(node, dst, flow_key)`` — the deterministic ECMP choice
  used by unicast forwarding;
- ``candidate_ports(node, dst)`` — the full equal-cost port set ("the set
  of accessible ports", Algorithm 4 line 14) used by the registration
  protocol's group-level load balancing.

Builders:
- ``testbed()``       — the paper's prototype (Fig. 8): one switch, four
  100Gbps hosts (the FPGA board is folded into the switch model: the
  Gleam logic runs "in" the switch, exactly the deployment the ACL
  redirect emulates).
- ``fig4()``          — the 3-layer example of Fig. 4 (4 leaves, 3 spines /
  2 pods, 2 cores) for unit tests of multi-hop trees.
- ``fat_tree(...)``   — parametric 3-layer pod/core fat-tree with 1:1
  oversubscription for the large-scale simulations (§5.3: 16384 hosts,
  64-port switches, 200Gbps).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Link:
    bw: float       # bytes / second
    delay: float    # seconds (propagation + fixed switch latency)


class Topology:
    # Per-destination BFS distance maps are cached for routing; on a
    # 16384-host fat tree one map is ~25k entries and every host is
    # eventually a destination, so an unbounded cache walks into tens
    # of GB.  LRU-bound it BY MEMORY, not count: packet-level sims keep
    # hundreds of destinations hot at once (every forwarded packet does
    # a dist() lookup) and must all fit, while flow-level staging on
    # 16k-host topologies touches each destination in tight succession
    # and tolerates a small cache.  ~150B per dict entry, measured.
    DIST_CACHE_BYTES = 256 << 20
    _DIST_ENTRY_BYTES = 150
    # candidate_ports memo: one small keyed list each.  Same byte-budget
    # LRU discipline as the dist cache — a many-destination churn run
    # (every (hop node, dst) pair on every routed path of a 16k-host
    # sweep) must not grow the memo without limit.  ~100B per entry
    # (two interned key strings + a short port list), measured.
    CAND_CACHE_BYTES = 64 << 20
    _CAND_ENTRY_BYTES = 100

    def __init__(self):
        self.ports: Dict[str, Dict[int, Tuple[str, int]]] = {}
        self.links: Dict[Tuple[str, int], Link] = {}   # (node, port) -> Link
        self.hosts: List[str] = []
        self.switches: List[str] = []
        self._dist: "OrderedDict[str, Dict[str, int]]" = OrderedDict()
        self._cand: "OrderedDict[Tuple[str, str], List[int]]" = OrderedDict()
        self._csr: Optional[tuple] = None  # (names, index, indptr, nbrs, prt)
        # directed (node, port) pairs whose link is administratively or
        # fault-wise down — routing treats them as absent (fault plane)
        self._down: set = set()
        # fingerprint state: (structural revision, frozen down-set).
        # Deliberately STATE-based, not a mutation counter: transient
        # set_link_down()/clear_down() round trips (flow-engine fault
        # staging) return to the original fingerprint, so staging caches
        # keyed on it survive fault sweeps on a pristine fabric.
        self._struct_rev = 0
        self._fp: Tuple[int, frozenset] = (0, frozenset())
        # When False, dist()/candidate_ports() recompute on every call
        # without memoizing — the cache-disabled reference mode for
        # bit-identity tests (slow; testing only).
        self.route_cache = True

    # ------------------------------------------------------------ building

    def add_host(self, name: str):
        self.hosts.append(name)
        self.ports[name] = {}

    def add_switch(self, name: str):
        self.switches.append(name)
        self.ports[name] = {}

    def connect(self, a: str, b: str, bw: float, delay: float):
        pa = len(self.ports[a])
        pb = len(self.ports[b])
        self.ports[a][pa] = (b, pb)
        self.ports[b][pb] = (a, pa)
        self.links[(a, pa)] = Link(bw, delay)
        self.links[(b, pb)] = Link(bw, delay)
        self._dist.clear()
        self._cand.clear()
        self._csr = None
        self._struct_rev += 1
        self._fp = (self._struct_rev, frozenset(self._down))

    def fingerprint(self) -> Tuple[int, frozenset]:
        """Cheap identity of the current routed topology.

        Changes exactly when routing could change: on ``connect`` (the
        structural revision bumps) and whenever the down-set changes
        (``set_link_down``/``set_switch_down``/``clear_down``).  Staging
        caches key derived artifacts (trees, paths, latencies) on this
        value and drop them when it moves."""
        return self._fp

    # ------------------------------------------------------- fault plane

    def _link_ports(self, a: str, b: str) -> Tuple[int, int]:
        """Port pair of the (single) a<->b link; KeyError when absent."""
        for pa, (peer, pb) in self.ports[a].items():
            if peer == b:
                return pa, pb
        raise KeyError(f"no link {a!r} <-> {b!r}")

    def set_link_down(self, a: str, b: str, down: bool = True) -> None:
        """Mark the a<->b link down (or back up) for routing.

        Down links vanish from the BFS adjacency and the ECMP candidate
        sets, so ``dist``/``candidate_ports``/``path_links`` re-derive
        onto surviving paths — the repair half of the fault plane.  The
        routing caches are invalidated on every change."""
        pa, pb = self._link_ports(a, b)
        pairs = {(a, pa), (b, pb)}
        if down:
            self._down |= pairs
        else:
            self._down -= pairs
        self._dist.clear()
        self._cand.clear()
        self._csr = None
        self._fp = (self._struct_rev, frozenset(self._down))

    def set_switch_down(self, name: str, down: bool = True) -> None:
        """Fail (or restore) every link of a switch at once."""
        for p, (peer, pp) in self.ports[name].items():
            pairs = {(name, p), (peer, pp)}
            if down:
                self._down |= pairs
            else:
                self._down -= pairs
        self._dist.clear()
        self._cand.clear()
        self._csr = None
        self._fp = (self._struct_rev, frozenset(self._down))

    def is_down(self, node: str, port: int) -> bool:
        return (node, port) in self._down

    def clear_down(self) -> None:
        """Restore every downed link (scenario quiesce)."""
        if not self._down:
            return
        self._down.clear()
        self._dist.clear()
        self._cand.clear()
        self._csr = None
        self._fp = (self._struct_rev, frozenset())

    def down_links(self) -> frozenset:
        return frozenset(self._down)

    # ------------------------------------------------------------ routing

    def _adjacency(self):
        """CSR adjacency over integer node ids, built lazily.

        One BFS per destination is the staging hot path of large-scale
        flow batches (a 16k-host fat tree eventually BFSes every host);
        walking the per-node port dicts in Python is ~10x slower than
        level-synchronous numpy sweeps over this CSR form.
        """
        if self._csr is None:
            names = list(self.ports)
            index = {n: i for i, n in enumerate(names)}
            down = self._down
            # CSR entries keep the ports dict's insertion order, which
            # is ascending port number by construction (``connect``
            # allocates ports densely) — the same order ``sorted()``
            # yields in candidate_ports, so vectorized ECMP picks over
            # this CSR are bit-identical to the scalar walk.
            live = {n: [(p, peer) for p, (peer, _) in self.ports[n].items()
                        if not down or (n, p) not in down]
                    for n in names}
            indptr = np.zeros(len(names) + 1, np.int32)
            for i, n in enumerate(names):
                indptr[i + 1] = indptr[i] + len(live[n])
            nbrs = np.empty(indptr[-1], np.int32)
            prt = np.empty(indptr[-1], np.int32)
            k = 0
            for n in names:
                for p, peer in live[n]:
                    nbrs[k] = index[peer]
                    prt[k] = p
                    k += 1
            self._csr = (names, index, indptr, nbrs, prt)
        return self._csr

    def _bfs(self, dst: str) -> Dict[str, int]:
        """Level-synchronous numpy BFS.  Unreachable nodes get -1 (the
        builders only produce connected topologies)."""
        names, index, indptr, nbrs, _ = self._adjacency()
        dist = np.full(len(names), -1, np.int32)
        frontier = np.asarray([index[dst]], np.int32)
        dist[frontier] = 0
        d = 0
        while frontier.size:
            d += 1
            # gather all neighbors of the frontier in one CSR sweep
            starts, ends = indptr[frontier], indptr[frontier + 1]
            counts = ends - starts
            rel = np.arange(int(counts.sum()), dtype=np.int32) \
                - np.repeat(np.cumsum(counts) - counts, counts)
            cand = nbrs[np.repeat(starts, counts) + rel]
            cand = cand[dist[cand] < 0]
            if not cand.size:
                break
            dist[cand] = d
            frontier = np.flatnonzero(dist == d).astype(np.int32)
        return dict(zip(names, dist.tolist()))

    def _bfs_many(self, dst_ids: np.ndarray) -> np.ndarray:
        """Hop counts to many destinations in ONE shared frontier sweep.

        Returns a (K, N) int32 matrix, row k = distances to dst_ids[k]
        (-1 where unreachable).  All K BFS expansions advance level by
        level together, so the CSR gathers amortize across destinations
        — the batched replacement for K scalar ``_bfs`` calls when
        staging a whole sweep's groups at once.
        """
        names, index, indptr, nbrs, _ = self._adjacency()
        N = len(names)
        K = len(dst_ids)
        dist = np.full((K, N), -1, np.int32)
        fk = np.arange(K, dtype=np.int64)
        fn = np.asarray(dst_ids, np.int64)
        dist[fk, fn] = 0
        d = 0
        while fn.size:
            d += 1
            starts = indptr[fn].astype(np.int64)
            counts = (indptr[fn + 1] - indptr[fn]).astype(np.int64)
            total = int(counts.sum())
            if not total:
                break
            rel = np.arange(total, dtype=np.int64) \
                - np.repeat(np.cumsum(counts) - counts, counts)
            cand_n = nbrs[np.repeat(starts, counts) + rel].astype(np.int64)
            cand_k = np.repeat(fk, counts)
            fresh = dist[cand_k, cand_n] < 0
            if not fresh.any():
                break
            # dedupe (k, node) pairs discovered via several frontier
            # nodes at once; unique also keeps the frontier sorted
            flat = np.unique(cand_k[fresh] * N + cand_n[fresh])
            fk = flat // N
            fn = flat - fk * N
            dist[fk, fn] = d
        return dist

    # destinations per batched-BFS chunk: bounds the (K, N) distance
    # matrix (256 cols x ~17k nodes x int32 ~= 17MB on the 16k-host
    # fat tree) while keeping the shared-frontier amortization
    PATHS_CHUNK = 256

    def paths_many(self, requests: Sequence[Tuple[str, str, int]]
                   ) -> List[Tuple[Tuple[str, int], ...]]:
        """Batch ``path_links`` over many (src, dst, flow_key) requests.

        Destinations are grouped into chunks; each chunk runs one shared
        ``_bfs_many`` sweep and then every request advances one hop per
        vectorized step (padded candidate gather + masked ``flow_key %
        n_cands`` pick).  Bit-identical to per-request ``path_links``
        because the CSR preserves ascending-port candidate order and
        excludes down links at build time.
        """
        if not requests:
            return []
        names, index, indptr, nbrs, prt = self._adjacency()
        out: List[Optional[list]] = [None] * len(requests)
        by_dst: Dict[str, List[int]] = {}
        for i, (src, dst, key) in enumerate(requests):
            if src == dst:
                out[i] = []
            else:
                by_dst.setdefault(dst, []).append(i)
        dst_names = sorted(by_dst)
        max_deg = int(np.max(np.diff(indptr))) if len(names) else 0
        deg_cols = np.arange(max_deg, dtype=np.int32)
        for c0 in range(0, len(dst_names), self.PATHS_CHUNK):
            chunk = dst_names[c0:c0 + self.PATHS_CHUNK]
            dst_ids = np.asarray([index[d] for d in chunk], np.int32)
            dist = self._bfs_many(dst_ids)
            ridx: List[int] = []
            cur: List[int] = []
            col: List[int] = []
            keys: List[int] = []
            for k, dname in enumerate(chunk):
                for i in by_dst[dname]:
                    ridx.append(i)
                    cur.append(index[requests[i][0]])
                    col.append(k)
                    keys.append(requests[i][2])
                    out[i] = []
            cur_a = np.asarray(cur, np.int64)
            col_a = np.asarray(col, np.int64)
            key_a = np.asarray(keys, np.int64)
            tgt = dst_ids[col_a].astype(np.int64)
            alive = np.flatnonzero(cur_a != tgt)
            while alive.size:
                n = cur_a[alive]
                k = col_a[alive]
                d = dist[k, n]
                if (d < 0).any():
                    bad = int(alive[np.flatnonzero(d < 0)[0]])
                    i = ridx[bad]
                    raise ValueError(
                        f"{requests[i][1]!r} is unreachable from "
                        f"{requests[i][0]!r}")
                starts = indptr[n].astype(np.int64)
                counts = (indptr[n + 1] - indptr[n]).astype(np.int64)
                md = int(counts.max())
                pad = deg_cols[:md]
                gidx = np.where(pad[None, :] < counts[:, None],
                                starts[:, None] + pad[None, :], 0)
                valid = pad[None, :] < counts[:, None]
                pn = nbrs[gidx].astype(np.int64)
                cand = valid & (dist[k[:, None], pn] == (d - 1)[:, None])
                ncand = cand.sum(axis=1)
                if (ncand == 0).any():
                    bad = int(alive[np.flatnonzero(ncand == 0)[0]])
                    i = ridx[bad]
                    raise ValueError(
                        f"{requests[i][1]!r} is unreachable from "
                        f"{requests[i][0]!r}")
                pick = key_a[alive] % ncand
                # index of the pick-th True per row, in CSR (port) order
                sel = np.argmax(np.cumsum(cand, axis=1)
                                == (pick + 1)[:, None], axis=1)
                rows = np.arange(alive.size)
                port_sel = prt[gidx[rows, sel]]
                nxt = pn[rows, sel]
                for r in range(alive.size):
                    out[ridx[int(alive[r])]].append(
                        (names[int(n[r])], int(port_sel[r])))
                cur_a[alive] = nxt
                alive = alive[nxt != tgt[alive]]
        return [tuple(p) for p in out]

    def _dist_cache_cap(self) -> int:
        """Max cached distance maps within the memory budget (>= 64)."""
        per_map = max(len(self.ports), 1) * self._DIST_ENTRY_BYTES
        return max(self.DIST_CACHE_BYTES // per_map, 64)

    def dist(self, node: str, dst: str) -> int:
        if not self.route_cache:
            return self._bfs(dst)[node]
        d = self._dist.get(dst)
        if d is None:
            d = self._dist[dst] = self._bfs(dst)
            cap = self._dist_cache_cap()
            while len(self._dist) > cap:
                self._dist.popitem(last=False)
        else:
            self._dist.move_to_end(dst)
        return d[node]

    def _cand_cache_cap(self) -> int:
        """Max cached candidate lists within the memory budget (>= 1k)."""
        return max(self.CAND_CACHE_BYTES // self._CAND_ENTRY_BYTES, 1024)

    def candidate_ports(self, node: str, dst: str) -> List[int]:
        """All ports on shortest paths node -> dst (the ECMP set).

        Memoized (LRU, byte-budgeted like ``dist``): staging a
        large-scale flow batch walks the same (intermediate node,
        destination) pairs from many sources, and each uncached call
        costs one ``dist`` lookup per port.
        """
        if node == dst:
            return []
        memo = self._cand.get((node, dst)) if self.route_cache else None
        if memo is None:
            d = self.dist(node, dst)
            if d < 0:
                raise ValueError(f"{dst!r} is unreachable from {node!r}")
            memo = [
                p for p, (peer, _) in sorted(self.ports[node].items())
                if (node, p) not in self._down
                and self.dist(peer, dst) == d - 1]
            if self.route_cache:
                self._cand[(node, dst)] = memo
                cap = self._cand_cache_cap()
                while len(self._cand) > cap:
                    self._cand.popitem(last=False)
        else:
            self._cand.move_to_end((node, dst))
        return memo

    def next_hop_port(self, node: str, dst: str, flow_key: int = 0) -> int:
        cands = self.candidate_ports(node, dst)
        return cands[flow_key % len(cands)]

    def path(self, src: str, dst: str, flow_key: int = 0) -> List[str]:
        node, out = src, [src]
        while node != dst:
            p = self.next_hop_port(node, dst, flow_key)
            node = self.ports[node][p][0]
            out.append(node)
        return out

    def path_links(self, src: str, dst: str,
                   flow_key: int = 0) -> List[Tuple[str, int]]:
        """Directed (node, port) hops along the unicast path."""
        node, out = src, []
        while node != dst:
            p = self.next_hop_port(node, dst, flow_key)
            out.append((node, p))
            node = self.ports[node][p][0]
        return out

    def link(self, node: str, port: int) -> Link:
        return self.links[(node, port)]

    def peer(self, node: str, port: int) -> Tuple[str, int]:
        return self.ports[node][port]


# ---------------------------------------------------------------- builders

GBPS = 1e9 / 8.0   # bytes/s per Gbit/s


def testbed(n_hosts: int = 4, bw: float = 100 * GBPS,
            delay: float = 0.6e-6) -> Topology:
    """Fig. 8: commodity switch + FPGA Gleam logic + 4 servers @100G."""
    t = Topology()
    t.add_switch("SW0")
    for i in range(n_hosts):
        h = f"h{i}"
        t.add_host(h)
        t.connect(h, "SW0", bw, delay)
    return t


def fig4(bw: float = 100 * GBPS, delay: float = 0.6e-6) -> Topology:
    """The 3-layer example topology of Fig. 4.

    Hosts: S=h0 (under L1), R1=h1 (L2), R2=h2 (L3), R3=h3 (L4).
    Pods: (L1,L2)+(S1,S2); (L3,L4)+(S3,S4).  Cores: C1, C2.
    """
    t = Topology()
    for c in ("C1", "C2"):
        t.add_switch(c)
    for s in ("S1", "S2", "S3", "S4"):
        t.add_switch(s)
    for l in ("L1", "L2", "L3", "L4"):
        t.add_switch(l)
    for i in range(4):
        t.add_host(f"h{i}")
    # hosts to leaves
    for i, l in enumerate(("L1", "L2", "L3", "L4")):
        t.connect(f"h{i}", l, bw, delay)
    # pod 0: L1, L2 <-> S1, S2 ; pod 1: L3, L4 <-> S3, S4
    for l in ("L1", "L2"):
        for s in ("S1", "S2"):
            t.connect(l, s, bw, delay)
    for l in ("L3", "L4"):
        for s in ("S3", "S4"):
            t.connect(l, s, bw, delay)
    # cores: C1 on (S1,S3), C2 on (S2,S4) -- two spine planes
    t.connect("S1", "C1", bw, delay)
    t.connect("S3", "C1", bw, delay)
    t.connect("S2", "C2", bw, delay)
    t.connect("S4", "C2", bw, delay)
    return t


def fat_tree(n_pods: int = 4, leaves_per_pod: int = 2,
             hosts_per_leaf: int = 4, aggs_per_pod: int = 2,
             bw: float = 200 * GBPS, delay: float = 0.6e-6) -> Topology:
    """Parametric 3-layer fat-tree, 1:1 oversubscription.

    Each leaf connects to every agg in its pod; agg plane j (one agg per
    pod) connects to a dedicated core group sized to keep capacity 1:1.
    Uplink bandwidths are scaled so ingress == egress capacity at every
    tier (flow-level capacity is what matters for the fluid simulator; the
    paper's §5.3 config is port-count-exact, ours is capacity-exact).
    """
    t = Topology()
    host_bw = bw
    # leaf: hosts_per_leaf * bw down, spread over aggs_per_pod uplinks
    leaf_up_bw = hosts_per_leaf * bw / aggs_per_pod
    # agg: leaves_per_pod * leaf_up_bw down, one core uplink per agg
    agg_up_bw = leaves_per_pod * leaf_up_bw
    for j in range(aggs_per_pod):
        t.add_switch(f"C{j}")           # one core (group) per agg plane
    for p in range(n_pods):
        for j in range(aggs_per_pod):
            t.add_switch(f"A{p}.{j}")
        for l in range(leaves_per_pod):
            leaf = f"L{p}.{l}"
            t.add_switch(leaf)
            for h in range(hosts_per_leaf):
                hn = f"h{p}.{l}.{h}"
                t.add_host(hn)
                t.connect(hn, leaf, host_bw, delay)
            for j in range(aggs_per_pod):
                t.connect(leaf, f"A{p}.{j}", leaf_up_bw, delay)
        for j in range(aggs_per_pod):
            t.connect(f"A{p}.{j}", f"C{j}", agg_up_bw, delay)
    return t


def host_ip_map(topo: Topology) -> Dict[str, int]:
    """Stable host-name -> integer IP assignment (IPs >= 1)."""
    return {h: i + 1 for i, h in enumerate(topo.hosts)}
