"""Gleam high-level API: network wiring + multicast groups.

``GleamNetwork`` owns a topology + PacketSim and provides

- ``multicast_group(members)`` -> ``MulticastGroup`` with:
  * ``register()``        — the Appendix-A envelope registration (Alg. 4):
    master collects member L3/L4 states, envelopes flow hop-by-hop
    building the extended forwarding tables, members answer ACKs;
  * ``bcast(nbytes)``     — one-to-many SEND through the virtual RC
    connection (Alg. 1 forwarding + Algs. 2/3 feedback aggregation);
  * ``write(nbytes)``     — one-to-many WRITE: an MR_UPDATE message
    precedes each request so leaf switches rewrite va/rkey (§3.3);
    ``same_mr=True`` enables the Appendix-C optimization (all receivers
    share VA/R_key: no MR_UPDATE traffic, models the modified-RNIC mode);
  * the **membership control plane** (§3.4 one-to-many connection
    maintenance) — a ``MulticastGroup`` is a state machine
    (``idle -> registering -> active <-> updating -> closed``) whose
    transitions are in-band control traffic on the live fabric:
    ``join(m)`` installs the new member's ports with an incremental
    MFT-update envelope and re-arms its QP onto the live PSN stream
    (no reset); ``leave(m)`` walks a teardown envelope down the tree,
    releasing ports and un-wedging aggregation; ``fail(m)`` models a
    silent receiver crash — the master isolates the dead port after
    ``fail_detect`` with the same teardown envelope so the pending
    aggregate drains and the sender resumes; ``master_switch(m)`` folds
    the Appendix-B source rotation (sqPSN/rqPSN synchronization, NO
    re-registration) into a master handover; ``close()`` deregisters.
    Every operation lands a ``MembershipRecord`` in ``events_log``
    (request time, completion time — fail records measure recovery).
- ``unicast_qp(a, b)``    — plain RC connections for the baselines.

Completion bookkeeping: every submitted group message records the sender
CQE time (cumulative aggregated ACK covered the last PSN — hardware
reliability) and each receiver's delivery time, so benchmarks can measure
JCT, IOPS and IO latency exactly as §5 defines them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core import packet as pk
from repro.core.endpoint import QP
from repro.core.fattree import Topology
from repro.core.faults import DEFAULT_LINK_DETECT
from repro.core.metrics import MsgRecord
from repro.core.packetsim import Host, PacketSim

__all__ = ["GleamNetwork", "MulticastGroup", "MembershipRecord",
           "MsgRecord", "VIRTUAL_QPN", "DEFAULT_FAIL_DETECT",
           "DEFAULT_LINK_DETECT",
           "IDLE", "REGISTERING", "ACTIVE", "UPDATING", "CLOSED"]

VIRTUAL_QPN = 0x1
GROUP_IP_BASE = 1 << 20          # far above any host IP
ENVELOPE_MAX_NODES = 183         # MTU-limited (Appendix A, Fig. 17)

# group lifecycle states (docs/ARCHITECTURE.md has the diagram)
IDLE = "idle"                    # constructed, tables not installed
REGISTERING = "registering"      # Appendix-A envelopes in flight
ACTIVE = "active"                # steady state, data plane live
UPDATING = "updating"            # >= 1 membership operation in flight
CLOSED = "closed"                # deregistered, QPs quiesced

# How long the master takes to notice a silently-failed receiver before
# isolating its port (keepalive-timeout scale, >> RTO so the sender has
# visibly wedged by the time isolation un-wedges it).
DEFAULT_FAIL_DETECT = 1e-3


@dataclasses.dataclass
class MembershipRecord:
    """One control-plane operation's bookkeeping.  For ``fail`` records
    ``latency`` is the recovery time: crash -> detection (+
    ``fail_detect``) -> in-band isolation -> fabric confirmation."""

    kind: str                    # join | leave | fail | master-switch
    member: str
    t_request: float
    t_done: float = -1.0

    @property
    def latency(self) -> float:
        return self.t_done - self.t_request

    @property
    def complete(self) -> bool:
        return self.t_done >= 0.0


class MulticastGroup:
    def __init__(self, net: "GleamNetwork", members: Sequence[str],
                 group_ip: int, *, master: Optional[str] = None,
                 mtu: int = pk.MTU, window: int = 256,
                 ack_freq: int = 4, rto: float = 200e-6,
                 fail_detect: float = DEFAULT_FAIL_DETECT,
                 link_detect: float = DEFAULT_LINK_DETECT,
                 max_retries: Optional[int] = None):
        self.net = net
        self.members = list(members)
        self.group_ip = group_ip
        self.master = master or self.members[0]
        self.source = self.master
        self.mtu = mtu
        self.window = window
        self.ack_freq = ack_freq
        self.rto = rto
        self.fail_detect = fail_detect
        self.link_detect = link_detect
        self.max_retries = max_retries
        self.qps: Dict[str, QP] = {}
        self.records: Dict[int, MsgRecord] = {}
        self._next_msg = 0
        self.registered = False
        self.register_time = -1.0
        self.state = IDLE
        self.events_log: List[MembershipRecord] = []
        self._op_seq = 0
        self._inflight: Dict[int, MembershipRecord] = {}
        # member -> (op_seq, node record) of a fail whose isolation
        # envelope has not been sent yet (detection pending)
        self._pending_isolation: Dict[str, tuple] = {}
        # fault plane: member ip -> (op_seq, record) of a gone-dark host
        # whose switch-originated teardown-confirm is still in flight
        self._pending_dark: Dict[int, tuple] = {}
        # op_seq -> outstanding affirmation count for repair re-floods
        # (they retire when EVERY targeted member re-affirms, unlike
        # single-member ops)
        self._inflight_n: Dict[int, int] = {}
        self._n_expected = 0
        for m in self.members:
            self._make_member_qp(m)
        self._acked: set = set()

    # ------------------------------------------------------------ control

    def _make_member_qp(self, m: str) -> QP:
        h = self.net.sim.hosts[m]
        qpn = self.net.alloc_qpn(m)
        qp = QP(qpn, h.ip, self.group_ip, VIRTUAL_QPN,
                link_bw=self.net.host_bw(m), mtu=self.mtu,
                window=self.window, ack_freq=self.ack_freq, rto=self.rto,
                max_retries=self.max_retries)
        va = 0x1000_0000 + qpn * 0x10000
        rkey = 0x100 + qpn
        qp.register_mr(rkey, va, 1 << 30)
        qp.on_complete = self._mk_on_complete()
        qp.on_deliver = self._mk_on_deliver(m)
        qp.on_error = self._mk_on_error()
        self.qps[m] = h.add_qp(qp)
        return qp

    def _node_record(self, m: str) -> dict:
        qp = self.qps[m]
        rkey = next(iter(qp.mrs.keys()))
        return {"ip": qp.ip, "qpn": qp.qpn,
                "va": qp.mrs[rkey][0], "rkey": rkey}

    def _records_payload(self) -> List[dict]:
        return [self._node_record(m) for m in self.members]

    # ----- host-side handlers (installed per host by GleamNetwork and
    # dispatched here by group ip, so many groups can churn at once)

    def _member_envelope(self, host: Host, p: pk.Packet, now: float) -> None:
        info = p.payload
        if info.get("mft_op") == "prune":
            # switch-originated teardown-confirm landed on the master:
            # the fabric pruned the gone-dark member's ports on its own,
            # so the pending dark record retires here — no master-driven
            # isolation round-trip ever happened
            for node in info["nodes"]:
                pend = self._pending_dark.pop(node["ip"], None)
                if pend is not None:
                    seq, rec = pend
                    rec.t_done = now
                    self._inflight.pop(seq, None)
                    if not self._inflight and self.state == UPDATING:
                        self.state = ACTIVE
            return
        if not any(n["ip"] == host.ip for n in info["nodes"]):
            return
        sim = self.net.sim
        mft_op = info.get("mft_op", "install")
        if mft_op in ("install", "repair"):
            # membership affirmation (② in Fig. 4); joins carry an
            # op_seq so the master can retire the specific operation
            if host.ip != info["master_ip"]:
                seq = info.get("op_seq")
                payload = (self.group_ip if seq is None else
                           {"group_ip": self.group_ip, "op_seq": seq,
                            "member_ip": host.ip})
                ack = pk.Packet(pk.ENVELOPE_ACK, host.ip,
                                info["master_ip"], payload=payload)
                sim.send_control(host, ack, now)
            return
        # leave/fail teardown reached the member: a graceful leaver
        # quiesces its QP; either way the arrival confirms the tree is
        # pruned up to the leaf, so acknowledge to the master (for a
        # failed member this is the NIC-level confirmation standing in
        # for the fabric's — the RC QP above it is already dead)
        qp = self.qps.get(host.name)
        if qp is not None and mft_op == "leave":
            qp.deactivate()
        ack = pk.Packet(pk.ENVELOPE_ACK, host.ip, info["master_ip"],
                        payload={"group_ip": self.group_ip,
                                 "op_seq": info.get("op_seq"),
                                 "member_ip": host.ip})
        sim.send_control(host, ack, now)

    def _master_env_ack(self, host: Host, p: pk.Packet, now: float) -> None:
        pl = p.payload
        if isinstance(pl, dict):                     # membership op ack
            seq = pl.get("op_seq")
            n = self._inflight_n.get(seq)
            if n is not None:
                # repair re-flood: retire only when EVERY targeted
                # member has re-affirmed its (possibly moved) path
                n -= 1
                if n > 0:
                    self._inflight_n[seq] = n
                    return
                del self._inflight_n[seq]
            rec = self._inflight.pop(seq, None)
            if rec is not None:
                rec.t_done = now
                if not self._inflight and self.state == UPDATING:
                    self.state = ACTIVE
            return
        if pl == self.group_ip and not self.registered:  # registration
            self._acked.add(p.src_ip)
            if len(self._acked) >= self._n_expected:
                self.registered = True
                self.register_time = now
                self.state = ACTIVE

    def register(self, *, run: bool = True) -> float:
        """Appendix-A centralized registration; returns completion time."""
        sim = self.net.sim
        master_host = sim.hosts[self.master]
        self.state = REGISTERING
        nodes = self._records_payload()
        n_pkts = max(1, math.ceil(len(nodes) / ENVELOPE_MAX_NODES))
        for i in range(n_pkts):
            chunk = nodes[i * ENVELOPE_MAX_NODES:(i + 1) * ENVELOPE_MAX_NODES]
            env = pk.Packet(pk.ENVELOPE, master_host.ip, self.group_ip,
                            size=pk.HDR + 8 + 11 * len(chunk),
                            payload={"group_ip": self.group_ip,
                                     "master_ip": master_host.ip,
                                     "nodes": chunk, "seq": i,
                                     "total": n_pkts})
            sim.send_control(master_host, env, sim.now)
        self._n_expected = len({m for m in self.members
                                if m != self.master})
        for m in self.members:
            self.net.attach_host_handlers(m)
        if run:
            sim.run(until=sim.now + 1.0)
            assert self.registered, "registration did not complete"
        return self.register_time

    # -------------------------------------------------------------- data

    def _mk_on_complete(self):
        def fn(msg, now):
            rec = self.records.get(msg.msg_id)
            if rec is not None:
                rec.t_sender_cqe = now
        return fn

    def _mk_on_deliver(self, member: str):
        def fn(msg_id, now):
            rec = self.records.get(msg_id)
            if rec is not None:
                rec.t_deliver[member] = now
        return fn

    def _mk_on_error(self):
        def fn(qp, reason, now):
            # bounded retry exhausted: if the erroring QP is the current
            # source's, its unfinished messages can never complete —
            # surface the verdict on their records instead of hanging
            if self.qps.get(self.source) is qp:
                for rec in self.records.values():
                    if rec.t_sender_cqe < 0 and not rec.error:
                        rec.error = reason
        return fn

    def n_receivers(self) -> int:
        return len(self.members) - 1

    def bcast(self, nbytes: int, *, now: Optional[float] = None) -> MsgRecord:
        if self.state == CLOSED:
            raise RuntimeError("bcast on a closed group")
        sim = self.net.sim
        t = sim.now if now is None else now
        qp = self.qps[self.source]
        mid = self._next_msg
        self._next_msg += 1
        self.records[mid] = MsgRecord(mid, nbytes, t)
        qp.submit(nbytes, t, op="send", msg_id=mid)
        sim.kick(sim.hosts[self.source], t)
        return self.records[mid]

    def write(self, nbytes: int, *, same_mr: bool = False,
              now: Optional[float] = None) -> MsgRecord:
        """One-to-many WRITE.  Without Appendix C (same_mr=False) every
        request is preceded by an MR_UPDATE message carrying per-receiver
        (va, rkey) for the leaf switches to install (§3.3)."""
        if self.state == CLOSED:
            raise RuntimeError("write on a closed group")
        sim = self.net.sim
        t = sim.now if now is None else now
        qp = self.qps[self.source]
        mid = self._next_msg
        self._next_msg += 1
        self.records[mid] = MsgRecord(mid, nbytes, t)
        if not same_mr:
            mr_map = {}
            for m in self.members:
                if m == self.source:
                    continue
                rqp = self.qps[m]
                rkey = next(iter(rqp.mrs.keys()))
                mr_map[rqp.ip] = (rqp.mrs[rkey][0], rkey)
            upd_bytes = 12 * len(mr_map) + 16
            qp.submit(upd_bytes, t, op="mr_update", payload=mr_map,
                      msg_id=-mid - 1)
        rkey0 = next(iter(self.qps[self.source].mrs.keys()))
        va0 = self.qps[self.source].mrs[rkey0][0]
        qp.submit(nbytes, t, op="write", va=va0, rkey=rkey0, msg_id=mid)
        sim.kick(sim.hosts[self.source], t)
        return self.records[mid]

    # --------------------------------------------------------- Appendix B

    def switch_source(self, new_source: str) -> None:
        assert new_source in self.members
        old = self.qps[self.source]
        new = self.qps[new_source]
        old.sync_psn_for_source_switch(becoming_source=False)
        new.sync_psn_for_source_switch(becoming_source=True)
        self.source = new_source

    # ----------------------------------------- membership control plane

    def _require_live(self, what: str) -> None:
        if self.state not in (ACTIVE, UPDATING):
            raise RuntimeError(
                f"{what} requires an active group, state is {self.state!r}")

    def _begin_op(self, kind: str, member: str, t: float, *,
                  rec: Optional[MembershipRecord] = None
                  ) -> tuple[int, MembershipRecord]:
        self._op_seq += 1
        if rec is None:
            rec = MembershipRecord(kind, member, t)
            self.events_log.append(rec)
        self._inflight[self._op_seq] = rec
        self.state = UPDATING
        return self._op_seq, rec

    def _send_update_envelope(self, nodes: List[dict], mft_op: str,
                              op_seq: int, t: float) -> None:
        """One incremental MFT-update envelope from the master into the
        live fabric (same wire format as registration + the op tag)."""
        sim = self.net.sim
        master_host = sim.hosts[self.master]
        env = pk.Packet(pk.ENVELOPE, master_host.ip, self.group_ip,
                        size=pk.HDR + 8 + 11 * len(nodes),
                        payload={"group_ip": self.group_ip,
                                 "master_ip": master_host.ip,
                                 "nodes": nodes, "seq": 0, "total": 1,
                                 "mft_op": mft_op, "op_seq": op_seq})
        sim.send_control(master_host, env, t)

    def _run_until_op(self, rec: MembershipRecord,
                      timeout: float = 1.0) -> None:
        sim = self.net.sim
        deadline = sim.now + timeout
        while not rec.complete:
            before = sim.events
            sim.run(until=deadline)
            if sim.events == before or sim.now >= deadline:
                break
        assert rec.complete, \
            f"membership op {rec.kind}({rec.member}) did not complete"

    def join(self, member: str, *, now: Optional[float] = None,
             run: bool = False) -> MembershipRecord:
        """Add ``member`` to the live group: allocate its QP, re-arm the
        receive side onto the live PSN stream (no reset), and install
        its tree ports with an incremental MFT-update envelope.  The
        joiner receives data from the moment its leaf port is installed;
        new entries seed their cumulative ACK state from the group's
        aggregate, so the join never wedges Algorithm 3."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._require_live("join")
        if member in self.members:
            raise ValueError(f"{member!r} is already a member")
        pending = self._pending_isolation.pop(member, None)
        if pending is not None:
            # the member rejoins before its failure was even detected:
            # the rejoin IS the detection.  Send the teardown envelope
            # now, immediately ahead of the install (FIFO on the same
            # control path), so the dead port's entry and ref are
            # released before the fresh ones land — the stale timer
            # fires into a no-op.
            self._send_update_envelope([pending[1]], "fail", pending[0], t)
        qp = self._make_member_qp(member)
        qp.rearm_receiver()
        self.members.append(member)
        self.net.attach_host_handlers(member)
        seq, rec = self._begin_op("join", member, t)
        self._send_update_envelope([self._node_record(member)],
                                   "install", seq, t)
        if run:
            self._run_until_op(rec)
        return rec

    def _check_removable(self, kind: str, member: str) -> None:
        self._require_live(kind)
        if member not in self.members:
            raise ValueError(f"{member!r} is not a member")
        if member == self.source:
            raise ValueError(
                f"cannot {kind} the current source {member!r}; "
                f"master_switch first")

    def leave(self, member: str, *, now: Optional[float] = None,
              run: bool = False) -> MembershipRecord:
        """Graceful departure: a teardown envelope walks the member's
        tree path releasing ports; the member quiesces its QP when the
        envelope reaches it and confirms to the master."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._check_removable("leave", member)
        self.members.remove(member)
        seq, rec = self._begin_op("leave", member, t)
        self._send_update_envelope([self._node_record(member)],
                                   "leave", seq, t)
        if run:
            self._run_until_op(rec)
        return rec

    def fail(self, member: str, *, now: Optional[float] = None,
             run: bool = False) -> MembershipRecord:
        """Silent receiver crash at ``now``: the QP dies immediately (it
        stops ACKing, so the aggregate minimum freezes and the sender
        wedges once its window drains), and after ``fail_detect`` the
        master isolates the dead port with the same teardown envelope —
        pruned switches recompute the pending aggregate and drain the
        outstanding feedback, un-wedging the stream.  The record's
        ``latency`` is the §3.4 recovery time."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._check_removable("fail", member)
        self.qps[member].deactivate()
        self.members.remove(member)
        seq, rec = self._begin_op("fail", member, t)
        node = self._node_record(member)
        self._pending_isolation[member] = (seq, node)

        def isolate(tt: float) -> None:
            # superseded if the member rejoined first (join sends this
            # exact envelope itself, ahead of the re-install)
            if self._pending_isolation.get(member, (None,))[0] == seq:
                del self._pending_isolation[member]
                self._send_update_envelope([node], "fail", seq, tt)

        sim.schedule(t + self.fail_detect, isolate)
        if run:
            self._run_until_op(rec)
        return rec

    def master_switch(self, member: str, *, now: Optional[float] = None
                      ) -> MembershipRecord:
        """Master handover + Appendix-B source rotation: the new master
        takes the source role (sqPSN/rqPSN synchronized, NO
        re-registration — ``ack_out_port`` re-learns from its first
        data packet) and future control-plane envelopes originate from
        it."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._require_live("master-switch")
        if member not in self.members:
            raise ValueError(f"{member!r} is not a member")
        self.switch_source(member)
        self.master = member
        rec = MembershipRecord("master-switch", member, t, t_done=t)
        self.events_log.append(rec)
        return rec

    # -------------------------------------- fault plane & self-healing

    def reinstall(self, *, now: Optional[float] = None, run: bool = False,
                  rec: Optional[MembershipRecord] = None
                  ) -> MembershipRecord:
        """Multicast-tree repair: re-flood the FULL install envelope
        from the master.  Switch installs are idempotent, so only the
        members whose tree path crossed a failed element actually move
        ports (Alg. 4 re-runs onto the surviving fat-tree paths);
        moved entries seed their ``ack_psn`` from the group aggregate,
        so the repaired branch joins the cumulative-ACK state without
        ever wedging Alg. 3.  Retires when every targeted member has
        re-affirmed."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._require_live("repair")
        seq, rec = self._begin_op("repair", "*", t, rec=rec)
        self._inflight_n[seq] = len(
            [m for m in self.members if m != self.master])
        nodes = self._records_payload()
        master_host = sim.hosts[self.master]
        env = pk.Packet(pk.ENVELOPE, master_host.ip, self.group_ip,
                        size=pk.HDR + 8 + 11 * len(nodes),
                        payload={"group_ip": self.group_ip,
                                 "master_ip": master_host.ip,
                                 "nodes": nodes, "seq": 0, "total": 1,
                                 "mft_op": "repair", "op_seq": seq})
        sim.send_control(master_host, env, t)
        if run:
            self._run_until_op(rec)
        return rec

    def link_fault(self, a: str, b: str, *, now: Optional[float] = None,
                   duration: Optional[float] = None,
                   run: bool = False) -> MembershipRecord:
        """Fabric link failure under the live stream: traffic into the
        link black-holes immediately; after ``link_detect`` (loss of
        light) the master repairs the tree onto surviving paths with a
        full re-flood.  ``duration`` makes it a flap — the link heals
        on its own, but the repaired tree deliberately stays on the
        surviving paths (no flap-back thrash).  The record's latency is
        fault -> every member re-affirmed on the repaired tree."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._require_live("link-fault")
        sim.link_down(a, b)
        if duration is not None:
            sim.schedule(t + duration, lambda tt: sim.link_up(a, b))
        rec = MembershipRecord("link-fault", f"{a}~{b}", t)
        self.events_log.append(rec)
        sim.schedule(t + self.link_detect,
                     lambda tt: self.reinstall(now=tt, rec=rec))
        if run:
            self._run_until_op(rec)
        return rec

    def switch_fault(self, name: str, *, now: Optional[float] = None,
                     run: bool = False) -> MembershipRecord:
        """Whole-switch failure: every one of its links goes dark at
        once; recovery is the same detect + re-flood as ``link_fault``
        (the fault plan validator has already rejected plans that leave
        a member unreachable — fail a leaf and you must model its hosts
        as ``host_gone_dark`` instead)."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._require_live("switch-fault")
        sim.switch_down(name)
        rec = MembershipRecord("switch-fault", name, t)
        self.events_log.append(rec)
        sim.schedule(t + self.link_detect,
                     lambda tt: self.reinstall(now=tt, rec=rec))
        if run:
            self._run_until_op(rec)
        return rec

    def host_gone_dark(self, member: str, *, now: Optional[float] = None,
                       run: bool = False) -> MembershipRecord:
        """A member host dies silently (NIC stops answering anything —
        harder than ``fail``, which only kills the group QP).  The
        access leaf detects the dark port after ``link_detect`` and
        originates the teardown itself: ports are pruned hop-by-hop
        along the aggregation reverse path, each tree switch un-wedges
        locally, and the envelope lands on the master as the confirm —
        recovery with NO master round-trip, so it completes in
        detect + one-way latency rather than detect + RTT."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._check_removable("host-dark", member)
        ip = self.qps[member].ip
        sim.host_dark(member)
        sim.retire_qp(self.qps[member])     # excised for good: the
        self.members.remove(member)         # scenario reset must not
                                            # resurrect it
        seq, rec = self._begin_op("host-dark", member, t)
        self._pending_dark[ip] = (seq, rec)
        leaf, _ = self.net.topo.peer(member, 0)

        def detect(tt: float) -> None:
            for port, q in sim.switches[leaf].prune_dead_member(
                    ip, tt, group_ip=self.group_ip):
                sim.send(leaf, port, q, tt)

        sim.schedule(t + self.link_detect, detect)
        if run:
            self._run_until_op(rec)
        return rec

    def master_crash(self, *, now: Optional[float] = None,
                     run: bool = False) -> MembershipRecord:
        """The master/source host dies mid-stream; the survivors heal
        (Appendix B generalized to an unplanned handover):

        1. ``link_detect`` later, the dead master's access leaf prunes
           its connected entry (``prune_dead_member``) — BEFORE
           re-election makes that never-ACKing entry aggregable again
           (``link_detect`` << ``fail_detect``: the order is
           load-bearing).
        2. ``fail_detect`` later, the lowest-rank surviving member
           re-elects itself master + source and resumes transmission
           from the dead sender's ``snd_una``: the aggregate minimum is
           exactly what ``snd_una`` tracked, so every receiver's rqPSN
           is >= it (nobody NACKs below the new base) and the
           outstanding span fits the window (no wedge).  Unfinished
           messages are resubmitted as tails under their original
           msg_ids, so the original records complete normally."""
        sim = self.net.sim
        t = sim.now if now is None else now
        self._require_live("master-crash")
        if len(self.members) < 2:
            raise ValueError("master_crash needs a surviving member")
        old = self.source
        old_qp = self.qps[old]
        una = old_qp.snd_una
        incomplete = [m for m in old_qp.msgs if m.t_complete < 0]
        sim.host_dark(old)
        sim.retire_qp(old_qp)   # the group moves on without it: a
                                # scenario-reset revival would replay
                                # its frozen window into severed tables
        self.members.remove(old)
        seq, rec = self._begin_op("master-crash", old, t)
        old_ip = old_qp.ip
        leaf, _ = self.net.topo.peer(old, 0)

        def dark_detect(tt: float) -> None:
            for port, q in sim.switches[leaf].prune_dead_member(
                    old_ip, tt, group_ip=self.group_ip):
                sim.send(leaf, port, q, tt)

        def reelect(tt: float) -> None:
            new = self.members[0]               # lowest-rank survivor
            nqp = self.qps[new]
            # resume exactly at the dead sender's cumulative-ACK point
            nqp.sq_psn = nqp.snd_una = nqp.snd_nxt = una
            self.source = self.master = new
            for m in incomplete:
                end = pk.psn_add(m.base_psn, m.n_pkts)
                tail = pk.psn_sub(end, pk.psn_max(una, m.base_psn))
                if tail <= 0:
                    continue
                nbytes = m.nbytes - (m.n_pkts - tail) * self.mtu
                nqp.submit(max(nbytes, 1), tt, op=m.op, va=m.va,
                           rkey=m.rkey, payload=m.payload,
                           msg_id=m.msg_id)
            rec.t_done = tt
            self._inflight.pop(seq, None)
            if not self._inflight and self.state == UPDATING:
                self.state = ACTIVE
            # re-flood the install envelope from the new master: the
            # repair sweep prunes the tree branches that only existed to
            # reach the dead master's leaf (they would otherwise sit in
            # the aggregate as never-ACKing forwarded entries), and the
            # tree re-roots at the survivor.
            self.reinstall(now=tt)
            sim.kick(sim.hosts[new], tt)

        sim.schedule(t + self.link_detect, dark_detect)
        sim.schedule(t + self.fail_detect, reelect)
        if run:
            self._run_until_op(rec)
        return rec

    def close(self) -> None:
        """Deregister the group: uninstall every switch table (their
        memory and port-utilization load are released through the
        store's ``on_remove`` hook) and quiesce the member QPs."""
        for sw in self.net.sim.switches.values():
            sw.tables.remove(self.group_ip)
        for qp in self.qps.values():
            qp.deactivate()
        self.net.groups_by_ip.pop(self.group_ip, None)
        self.state = CLOSED

    # ------------------------------------------------------------- stats

    def run_until_delivered(self, rec: MsgRecord,
                            timeout: float = 5.0) -> float:
        sim = self.net.sim
        deadline = sim.now + timeout
        while (len(rec.t_deliver) < self.n_receivers()
               or rec.t_sender_cqe < 0):
            before = sim.events
            sim.run(until=deadline)
            if sim.events == before or sim.now >= deadline:
                break
        return rec.jct(self.n_receivers())


class GleamNetwork:
    def __init__(self, topo: Topology, **sim_kw):
        self.topo = topo
        self.sim = PacketSim(topo, **sim_kw)
        self._qpn: Dict[str, int] = {}
        self._groups = 0
        # group-ip -> MulticastGroup: the demux the per-host envelope
        # handlers dispatch through, so several groups can register and
        # churn on the same hosts concurrently
        self.groups_by_ip: Dict[int, MulticastGroup] = {}
        self._handled_hosts: set = set()

    def alloc_qpn(self, host: str) -> int:
        n = self._qpn.get(host, 16) + 1
        self._qpn[host] = n
        return n

    def host_bw(self, host: str) -> float:
        return self.topo.link(host, 0).bw

    def attach_host_handlers(self, member: str) -> None:
        """Install the (idempotent) control-plane dispatchers on a
        member host: envelopes and envelope-ACKs route to the owning
        group by the group ip they carry."""
        if member in self._handled_hosts:
            return
        self._handled_hosts.add(member)
        host = self.sim.hosts[member]

        def on_envelope(p: pk.Packet, now: float) -> None:
            g = self.groups_by_ip.get(p.payload.get("group_ip"))
            if g is not None:
                g._member_envelope(host, p, now)

        def on_envelope_ack(p: pk.Packet, now: float) -> None:
            pl = p.payload
            gid = pl.get("group_ip") if isinstance(pl, dict) else pl
            g = self.groups_by_ip.get(gid)
            if g is not None:
                g._master_env_ack(host, p, now)

        host.on_envelope = on_envelope
        host.on_envelope_ack = on_envelope_ack

    def multicast_group(self, members: Sequence[str],
                        **kw) -> MulticastGroup:
        g = MulticastGroup(self, members,
                           GROUP_IP_BASE + self._groups, **kw)
        self._groups += 1
        self.groups_by_ip[g.group_ip] = g
        return g

    def unicast_qp(self, a: str, b: str, *, mtu: int = pk.MTU,
                   window: int = 256, ack_freq: int = 4,
                   rto: float = 200e-6,
                   max_retries: Optional[int] = None) -> tuple[QP, QP]:
        """A plain RC connection a -> b (baselines: multiple unicasts,
        overlay relays).  ``max_retries`` bounds the sender QP's RTO
        retransmits (fault scenarios); receivers never retry."""
        ha, hb = self.sim.hosts[a], self.sim.hosts[b]
        qa = QP(self.alloc_qpn(a), ha.ip, hb.ip, 0,
                link_bw=self.host_bw(a), mtu=mtu, window=window,
                ack_freq=ack_freq, rto=rto, max_retries=max_retries)
        qb = QP(self.alloc_qpn(b), hb.ip, ha.ip, qa.qpn,
                link_bw=self.host_bw(b), mtu=mtu, window=window,
                ack_freq=ack_freq, rto=rto)
        qa.dst_qpn = qb.qpn
        ha.add_qp(qa)
        hb.add_qp(qb)
        return qa, qb
