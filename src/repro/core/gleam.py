"""Gleam high-level API: network wiring + multicast groups.

``GleamNetwork`` owns a topology + PacketSim and provides

- ``multicast_group(members)`` -> ``MulticastGroup`` with:
  * ``register()``        — the Appendix-A envelope registration (Alg. 4):
    master collects member L3/L4 states, envelopes flow hop-by-hop
    building the extended forwarding tables, members answer ACKs;
  * ``bcast(nbytes)``     — one-to-many SEND through the virtual RC
    connection (Alg. 1 forwarding + Algs. 2/3 feedback aggregation);
  * ``write(nbytes)``     — one-to-many WRITE: an MR_UPDATE message
    precedes each request so leaf switches rewrite va/rkey (§3.3);
    ``same_mr=True`` enables the Appendix-C optimization (all receivers
    share VA/R_key: no MR_UPDATE traffic, models the modified-RNIC mode);
  * ``switch_source(m)``  — Appendix-B source rotation with sqPSN/rqPSN
    synchronization and NO re-registration;
- ``unicast_qp(a, b)``    — plain RC connections for the baselines.

Completion bookkeeping: every submitted group message records the sender
CQE time (cumulative aggregated ACK covered the last PSN — hardware
reliability) and each receiver's delivery time, so benchmarks can measure
JCT, IOPS and IO latency exactly as §5 defines them.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.core import packet as pk
from repro.core.endpoint import QP
from repro.core.fattree import Topology
from repro.core.metrics import MsgRecord
from repro.core.packetsim import Host, PacketSim

__all__ = ["GleamNetwork", "MulticastGroup", "MsgRecord", "VIRTUAL_QPN"]

VIRTUAL_QPN = 0x1
GROUP_IP_BASE = 1 << 20          # far above any host IP
ENVELOPE_MAX_NODES = 183         # MTU-limited (Appendix A, Fig. 17)


class MulticastGroup:
    def __init__(self, net: "GleamNetwork", members: Sequence[str],
                 group_ip: int, *, master: Optional[str] = None,
                 mtu: int = pk.MTU, window: int = 256,
                 ack_freq: int = 4, rto: float = 200e-6):
        self.net = net
        self.members = list(members)
        self.group_ip = group_ip
        self.master = master or self.members[0]
        self.source = self.master
        self.qps: Dict[str, QP] = {}
        self.records: Dict[int, MsgRecord] = {}
        self._next_msg = 0
        self.registered = False
        self.register_time = -1.0
        sim = net.sim
        for m in self.members:
            h = sim.hosts[m]
            qpn = net.alloc_qpn(m)
            qp = QP(qpn, h.ip, group_ip, VIRTUAL_QPN,
                    link_bw=net.host_bw(m), mtu=mtu, window=window,
                    ack_freq=ack_freq, rto=rto)
            va = 0x1000_0000 + qpn * 0x10000
            rkey = 0x100 + qpn
            qp.register_mr(rkey, va, 1 << 30)
            qp.on_complete = self._mk_on_complete()
            qp.on_deliver = self._mk_on_deliver(m)
            self.qps[m] = h.add_qp(qp)
        self._acked: set = set()

    # ------------------------------------------------------------ control

    def _records_payload(self) -> List[dict]:
        out = []
        for m in self.members:
            qp = self.qps[m]
            va, _ = next(iter(qp.mrs.values()))[0], None
            rkey = next(iter(qp.mrs.keys()))
            out.append({"ip": qp.ip, "qpn": qp.qpn,
                        "va": qp.mrs[rkey][0], "rkey": rkey})
        return out

    def register(self, *, run: bool = True) -> float:
        """Appendix-A centralized registration; returns completion time."""
        sim = self.net.sim
        master_host = sim.hosts[self.master]
        nodes = self._records_payload()
        n_pkts = max(1, math.ceil(len(nodes) / ENVELOPE_MAX_NODES))
        for i in range(n_pkts):
            chunk = nodes[i * ENVELOPE_MAX_NODES:(i + 1) * ENVELOPE_MAX_NODES]
            env = pk.Packet(pk.ENVELOPE, master_host.ip, self.group_ip,
                            size=pk.HDR + 8 + 11 * len(chunk),
                            payload={"group_ip": self.group_ip,
                                     "master_ip": master_host.ip,
                                     "nodes": chunk, "seq": i,
                                     "total": n_pkts})
            sim.send_control(master_host, env, sim.now)
        # membership affirmation (② in Fig. 4)
        expected = {m for m in self.members if m != self.master}

        def on_env(host: Host):
            def fn(p: pk.Packet, now: float):
                my = any(n["ip"] == host.ip for n in p.payload["nodes"])
                if my and host.ip != p.payload["master_ip"]:
                    ack = pk.Packet(pk.ENVELOPE_ACK, host.ip,
                                    p.payload["master_ip"],
                                    payload=self.group_ip)
                    sim.send_control(host, ack, now)
            return fn

        def on_env_ack(p: pk.Packet, now: float):
            if p.payload == self.group_ip:
                self._acked.add(p.src_ip)
                if len(self._acked) >= len(expected):
                    self.registered = True
                    self.register_time = now

        for m in self.members:
            sim.hosts[m].on_envelope = on_env(sim.hosts[m])
        master_host.on_envelope_ack = on_env_ack
        if run:
            sim.run(until=sim.now + 1.0)
            assert self.registered, "registration did not complete"
        return self.register_time

    # -------------------------------------------------------------- data

    def _mk_on_complete(self):
        def fn(msg, now):
            rec = self.records.get(msg.msg_id)
            if rec is not None:
                rec.t_sender_cqe = now
        return fn

    def _mk_on_deliver(self, member: str):
        def fn(msg_id, now):
            rec = self.records.get(msg_id)
            if rec is not None:
                rec.t_deliver[member] = now
        return fn

    def n_receivers(self) -> int:
        return len(self.members) - 1

    def bcast(self, nbytes: int, *, now: Optional[float] = None) -> MsgRecord:
        sim = self.net.sim
        t = sim.now if now is None else now
        qp = self.qps[self.source]
        mid = self._next_msg
        self._next_msg += 1
        self.records[mid] = MsgRecord(mid, nbytes, t)
        qp.submit(nbytes, t, op="send", msg_id=mid)
        sim.kick(sim.hosts[self.source], t)
        return self.records[mid]

    def write(self, nbytes: int, *, same_mr: bool = False,
              now: Optional[float] = None) -> MsgRecord:
        """One-to-many WRITE.  Without Appendix C (same_mr=False) every
        request is preceded by an MR_UPDATE message carrying per-receiver
        (va, rkey) for the leaf switches to install (§3.3)."""
        sim = self.net.sim
        t = sim.now if now is None else now
        qp = self.qps[self.source]
        mid = self._next_msg
        self._next_msg += 1
        self.records[mid] = MsgRecord(mid, nbytes, t)
        if not same_mr:
            mr_map = {}
            for m in self.members:
                if m == self.source:
                    continue
                rqp = self.qps[m]
                rkey = next(iter(rqp.mrs.keys()))
                mr_map[rqp.ip] = (rqp.mrs[rkey][0], rkey)
            upd_bytes = 12 * len(mr_map) + 16
            qp.submit(upd_bytes, t, op="mr_update", payload=mr_map,
                      msg_id=-mid - 1)
        rkey0 = next(iter(self.qps[self.source].mrs.keys()))
        va0 = self.qps[self.source].mrs[rkey0][0]
        qp.submit(nbytes, t, op="write", va=va0, rkey=rkey0, msg_id=mid)
        sim.kick(sim.hosts[self.source], t)
        return self.records[mid]

    # --------------------------------------------------------- Appendix B

    def switch_source(self, new_source: str) -> None:
        assert new_source in self.members
        old = self.qps[self.source]
        new = self.qps[new_source]
        old.sync_psn_for_source_switch(becoming_source=False)
        new.sync_psn_for_source_switch(becoming_source=True)
        self.source = new_source

    # ------------------------------------------------------------- stats

    def run_until_delivered(self, rec: MsgRecord,
                            timeout: float = 5.0) -> float:
        sim = self.net.sim
        deadline = sim.now + timeout
        while (len(rec.t_deliver) < self.n_receivers()
               or rec.t_sender_cqe < 0):
            before = sim.events
            sim.run(until=deadline)
            if sim.events == before or sim.now >= deadline:
                break
        return rec.jct(self.n_receivers())


class GleamNetwork:
    def __init__(self, topo: Topology, **sim_kw):
        self.topo = topo
        self.sim = PacketSim(topo, **sim_kw)
        self._qpn: Dict[str, int] = {}
        self._groups = 0

    def alloc_qpn(self, host: str) -> int:
        n = self._qpn.get(host, 16) + 1
        self._qpn[host] = n
        return n

    def host_bw(self, host: str) -> float:
        return self.topo.link(host, 0).bw

    def multicast_group(self, members: Sequence[str],
                        **kw) -> MulticastGroup:
        g = MulticastGroup(self, members,
                           GROUP_IP_BASE + self._groups, **kw)
        self._groups += 1
        return g

    def unicast_qp(self, a: str, b: str, *, mtu: int = pk.MTU,
                   window: int = 256, ack_freq: int = 4,
                   rto: float = 200e-6) -> tuple[QP, QP]:
        """A plain RC connection a -> b (baselines: multiple unicasts,
        overlay relays)."""
        ha, hb = self.sim.hosts[a], self.sim.hosts[b]
        qa = QP(self.alloc_qpn(a), ha.ip, hb.ip, 0,
                link_bw=self.host_bw(a), mtu=mtu, window=window,
                ack_freq=ack_freq, rto=rto)
        qb = QP(self.alloc_qpn(b), hb.ip, ha.ip, qa.qpn,
                link_bw=self.host_bw(b), mtu=mtu, window=window,
                ack_freq=ack_freq, rto=rto)
        qa.dst_qpn = qb.qpn
        ha.add_qp(qa)
        hb.add_qp(qb)
        return qa, qb
