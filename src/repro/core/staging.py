"""Shared staging cache — the fleet-scale sweep plane's artifact store.

Staging (tree derivation, path walks, per-receiver latencies, per-op
flow layouts) is the flow engine's hot path once the solver is batched:
a `run_many` sweep across seeds/loss-points/arrival-draws re-derives
each group's artifacts per scenario unless they are cached.  One
``StagingCache`` lives on each ``Topology`` (``StagingCache.of``), so
every engine instance built over the same fabric — including the fresh
engines a benchmark builds per pass — shares one set of derived
artifacts.

Keying and invalidation rules (docs/ARCHITECTURE.md "Fleet-scale sweep
plane"):

- every artifact is implicitly keyed by ``Topology.fingerprint()`` —
  the (structural revision, frozen down-set) pair.  ``sync()`` compares
  the stored fingerprint against the topology's current one and drops
  EVERYTHING on mismatch, so ``connect``/``set_link_down``/
  ``set_switch_down``/``clear_down`` invalidate by construction.
  The fingerprint is state-based, not a mutation counter: a transient
  down/up round trip (flow-engine fault staging) restores the original
  fingerprint and the pristine artifacts survive.
- ``paths``  : (src, dst, ecmp key)            -> directed link ids
- ``trees``  : (source, member frozenset, key) -> multicast tree links
- ``lat``    : (src, dst, seg_wire, key)       -> (latency, return prop)
- ``ops``    : engine-config-prefixed per-op layouts (links, deliver
  map, loss params) for STATIC ops only — ops with membership events or
  faults re-derive every time (their staging mutates the down-set
  mid-op, and their artifacts are timeline-dependent).
- ``misc``   : small derived singletons (the LinkMap link-id/capacity
  arrays) keyed by an arbitrary string; same invalidation rules.  The
  batched dynamic-segment solver parks its solved-rate memo here
  (``misc['segrates']``: (link-set tuple, loss params) -> fair rate),
  so a sweep's second pass over the same churn/fault timelines skips
  the segment solves entirely — and a fingerprint move (real topology
  mutation) drops the memo with everything else.

Entries are plain derived values; nothing downstream mutates them
(``FlowEngine._backfill`` reads deliver maps read-only), which is what
makes fixed-seed results bit-identical with the cache on or off — the
guarantee ``tests/test_staging.py`` pins down.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.fattree import Topology

# coarse safety valve: artifact dicts are cleared wholesale when any one
# of them exceeds this many entries (a 16k-host x 1k-group sweep stages
# ~20k paths; the cap only trips on degenerate churn)
MAX_ENTRIES = 1 << 20


class StagingCache:
    """Per-topology store of derived staging artifacts (see module doc)."""

    def __init__(self, topo: Topology):
        self.topo = topo
        self._fp = topo.fingerprint()
        self.paths: Dict[tuple, Tuple[int, ...]] = {}
        self.trees: Dict[tuple, Tuple[int, ...]] = {}
        self.lat: Dict[tuple, Tuple[float, float]] = {}
        self.ops: Dict[tuple, tuple] = {}
        self.misc: Dict[str, object] = {}
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    @classmethod
    def of(cls, topo: Topology) -> "StagingCache":
        """The topology's shared cache (created on first use)."""
        cache = getattr(topo, "_staging_cache", None)
        if cache is None:
            cache = topo._staging_cache = cls(topo)
        return cache

    # --------------------------------------------------------- lifecycle

    def sync(self) -> "StagingCache":
        """Drop every artifact if the topology fingerprint moved."""
        if self.topo.fingerprint() != self._fp:
            self.invalidate()
        return self

    def invalidate(self) -> None:
        self.paths.clear()
        self.trees.clear()
        self.lat.clear()
        self.ops.clear()
        self.misc.clear()
        self._fp = self.topo.fingerprint()
        self.invalidations += 1

    def bound(self) -> None:
        """Coarse entry-count safety valve (see MAX_ENTRIES)."""
        if max(len(self.paths), len(self.trees), len(self.lat),
               len(self.ops)) > MAX_ENTRIES:
            self.invalidate()

    # --------------------------------------------------------- telemetry

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "invalidations": self.invalidations,
            "paths": len(self.paths),
            "trees": len(self.trees),
            "lat": len(self.lat),
            "ops": len(self.ops),
        }
