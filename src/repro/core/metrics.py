"""Shared result records and metric accounting for every simulation engine.

The paper's §5 evaluation reports three quantities, and both simulation
backends (``packetsim`` and the flow-level engines behind
``core/engine.py``) produce them through the same ``MsgRecord``:

- **JCT** (job completion time): submission of a group message until the
  LAST receiver has delivered it — ``max(t_deliver) - t_submit``.  This is
  what Figs. 9-11 and 14-15 plot.
- **IO latency**: submission until the SENDER's completion event
  (the CQE raised when the cumulative aggregated ACK covers the last PSN;
  "hardware reliability") — ``t_sender_cqe - t_submit``.  Fig. 13.
- **IOPS**: completed IOs divided by the wall-clock span of the batch
  (``iops()`` below).  Fig. 12.

Keeping the records engine-agnostic is what makes the engines swappable:
a benchmark asks its engine for records and computes metrics identically,
whether the record was filled in by a per-packet event loop or by a
vectorized max-min fair-share solve.

``schedule_cost`` (the analytic alpha-beta broadcast model used by the
adapted-layer benchmarks) lives here too: it is JCT accounting with the
network abstracted away entirely, the zeroth engine in the fidelity
ladder analytic -> flow -> packet.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Sequence


@dataclasses.dataclass(slots=True)
class MsgRecord:
    """Completion bookkeeping for one submitted group message.

    ``t_sender_cqe`` is -1 until the sender-side completion is observed;
    ``t_deliver`` maps member name -> delivery time and fills in as
    receivers finish (flow-level engines fill all of it at once).
    ``error`` is the bounded-retry verdict: empty for a clean completion,
    else an attributable reason (e.g. ``"retry_exceeded"``) meaning the
    op terminated explicitly instead of completing — never a hang.
    """

    msg_id: int
    nbytes: int
    t_submit: float
    t_sender_cqe: float = -1.0
    t_deliver: Dict[str, float] = dataclasses.field(default_factory=dict)
    error: str = ""

    @property
    def errored(self) -> bool:
        return bool(self.error)

    def jct(self, n_receivers: int) -> float:
        """Submission -> last receiver delivery (inf while incomplete)."""
        if len(self.t_deliver) < n_receivers:
            return float("inf")
        return max(self.t_deliver.values()) - self.t_submit

    @property
    def io_latency(self) -> float:
        """Submission -> sender CQE (§5.2.2's single-IO latency)."""
        return self.t_sender_cqe - self.t_submit

    @property
    def complete(self) -> bool:
        return self.t_sender_cqe >= 0.0


# ------------------------------------------------------------- aggregates

def iops(records: Sequence[MsgRecord], t0: float) -> float:
    """Completed IOs per second over the batch span starting at ``t0``.

    Matches Fig. 12's measurement: the denominator is the time the LAST
    sender CQE lands, so pipelining across outstanding IOs is credited.
    """
    if not records:
        return 0.0
    t_end = max(r.t_sender_cqe for r in records)
    span = t_end - t0
    return len(records) / span if span > 0 else float("inf")


def mean_io_latency(records: Iterable[MsgRecord]) -> float:
    """Arithmetic mean of per-IO submit->CQE latency (Fig. 13)."""
    recs = list(records)
    return sum(r.io_latency for r in recs) / max(len(recs), 1)


def max_jct(records: Iterable[MsgRecord], n_receivers: int) -> float:
    """Batch JCT: the slowest message's JCT (epoch completion time)."""
    return max(r.jct(n_receivers) for r in records)


# ------------------------------------------------- schedule cost model

def schedule_cost(schedule: str, n: int, bytes_: int, *, chunks: int = 1,
                  link_bw: float = 50e9, hop_latency: float = 1e-6):
    """Analytic alpha-beta cost of broadcasting ``bytes_`` to n-1 receivers.

    Used by benchmarks/collective_schedules.py to compare against the
    paper's Fig. 9 structure (sender-bottleneck vs tree vs overlay):

    - ``unicast``:   n-1 serialized sends through the sender's link;
    - ``ring``:      pipelined store-and-forward, (n-1 + chunks-1) rounds;
    - ``tree``:      binomial tree, ceil(log2 n) rounds;
    - ``infabric``:  ideal switch multicast — one hop, one serialization
      (Gleam's data plane in the limit of free replication).
    """
    beta = bytes_ / link_bw
    if n == 1:
        return 0.0
    if schedule == "unicast":
        return (n - 1) * (hop_latency + beta)     # serialized at sender
    if schedule == "ring":
        c = max(chunks, 1)
        return (n - 1 + c - 1) * (hop_latency + beta / c)
    if schedule in ("gleam_tree", "tree"):
        return math.ceil(math.log2(n)) * (hop_latency + beta)
    if schedule == "infabric":                    # ideal switch multicast
        return hop_latency + beta
    raise ValueError(schedule)
