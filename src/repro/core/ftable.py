"""The extended multicast forwarding table (Fig. 5) — the foundation of
Gleam's in-fabric logic.

Indexed by GroupIP; holds
- group-level state: ``last_ack_psn``, ``ack_out_port`` (the port data
  packets enter, learned from the data plane — this also implements the
  source-switching detection of Appendix B), the pending-NACK record
  (``nack_epsn``), and per-port congestion counters for CNP filtering
  (§3.5);
- port-level entries (one per tree port): type ``connected`` (directly
  attached receiver: carries its L3/L4 and MR rewrite states) or
  ``forwarded`` (next hop is a switch); both carry the per-port
  cumulative ``ack_psn``.

Memory accounting mirrors the paper's claim (§3.3: 1K groups <= 0.92MB when
every group uses all n ports): ``entry_bytes``/``table_bytes`` let the
tests reproduce that arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.packet import PSN_MOD, PSN_WINDOW, psn_geq, psn_min

CONNECTED = "connected"
FORWARDED = "forwarded"

# Per-entry state sizes in bytes (Fig. 5 scale):
#   connected: port(1) type(1) ip(4) qpn(3) va(8) rkey(4) ack_psn(3) = 24
#   forwarded: port(1) type(1) ack_psn(3)                            = 8
# group-level: group_ip(4) last_ack_psn(3) ack_out_port(1) nack(8)
#              cc counters (4 per port)
ENTRY_BYTES = {CONNECTED: 24, FORWARDED: 8}
GROUP_BYTES = 16


@dataclasses.dataclass
class PortEntry:
    port: int
    type: str                           # connected | forwarded
    dest_ip: int = 0                    # connected only
    dest_qpn: int = 0                   # connected only
    va: int = 0                         # connected only (MR rewrite state)
    rkey: int = 0                       # connected only
    ack_psn: int = PSN_MOD - 1          # cumulative: "acked up to -1"


@dataclasses.dataclass
class GroupTable:
    group_ip: int
    entries: Dict[int, PortEntry] = dataclasses.field(default_factory=dict)
    # --- group-level ACK state (Alg 2/3)
    last_ack_psn: int = PSN_MOD - 1
    ack_out_port: Optional[int] = None  # learned: port data packets enter
    # --- fault plane: the master's IP, stamped at envelope install so a
    # switch-originated teardown-confirm can still be routed when
    # ``ack_out_port`` has not been learned yet (no data flowed)
    master_ip: int = 0
    # --- group-level NACK state (Alg 2 lines 14-16)
    nack_epsn: Optional[int] = None     # None = no pending NACK
    # --- congestion-signal filtering (§3.5): per-port CNP counters
    cnp_count: Dict[int, float] = dataclasses.field(default_factory=dict)
    psn_window: int = PSN_WINDOW        # 2^22 in p4 mode
    # --- registration load attributed to each port by THIS group, so
    # uninstalling the group can release its share of the switch-wide
    # port-utilization counters (Alg. 4's load-balancing input)
    port_refs: Dict[int, int] = dataclasses.field(default_factory=dict)
    # --- membership index (control-plane bookkeeping, not Fig. 5 state):
    # member IP -> the port this switch serves it through, recorded at
    # envelope-install time so an incremental leave/fail envelope can
    # release exactly the port the member registered through (a real
    # deployment re-derives this from the removal envelope's routing;
    # the simulator keeps the index to stay deterministic under the
    # port-utilization drift of Algorithm 4's load balancing).
    member_port: Dict[int, int] = dataclasses.field(default_factory=dict)
    # --- Alg. 3 hot-path caches (simulator-internal, not Fig. 5 state):
    # ``agg_entries_cache`` is the entry list excluding the source-facing
    # port; ``agg_min`` is (min ack_psn over that list, owning port).
    # ``ack_psn`` values only advance, so the minimum is stable until the
    # owning entry itself advances — both caches are invalidated on entry
    # or ``ack_out_port`` changes and rebuilt lazily by the switch.
    agg_entries_cache: Optional[list] = None
    agg_min: Optional[tuple] = None

    def add_connected(self, port: int, dest_ip: int, dest_qpn: int,
                      va: int = 0, rkey: int = 0):
        # new entries join the cumulative-ACK state "as caught up as the
        # group": seeding ack_psn from last_ack_psn keeps a mid-stream
        # install (dynamic join) from wedging the aggregate minimum.  At
        # registration time last_ack_psn is still the fresh-entry default
        # (PSN_MOD - 1), so the static path is unchanged.
        self.entries[port] = PortEntry(port, CONNECTED, dest_ip, dest_qpn,
                                       va, rkey,
                                       ack_psn=self.last_ack_psn)
        self.agg_entries_cache = self.agg_min = None

    def add_forwarded(self, port: int):
        if port not in self.entries:
            self.entries[port] = PortEntry(port, FORWARDED,
                                           ack_psn=self.last_ack_psn)
            self.agg_entries_cache = self.agg_min = None

    def remove_port(self, port: int) -> Optional[PortEntry]:
        """Incremental teardown of one tree port (§3.4 maintenance).

        Drops the port's entry AND its per-port group state (the CNP
        counter), so ``table_bytes`` shrinks by exactly the install
        cost.  Invalidate both aggregation caches: the removed port may
        have owned the pending minimum, and the switch re-runs Alg. 3
        right after to un-wedge (emit the newly-satisfied aggregate)."""
        e = self.entries.pop(port, None)
        if e is not None:
            self.cnp_count.pop(port, None)
            self.agg_entries_cache = self.agg_min = None
        return e

    def retarget(self, port: int, dest_ip: int, dest_qpn: int,
                 va: int = 0, rkey: int = 0) -> PortEntry:
        """Swap the receiver behind a ``connected`` port in place
        (member migration / replacement): new L3/L4 + MR rewrite
        state, per-port cumulative ACK state reset to the aggregate so
        the newcomer is not charged with the departed receiver's lag."""
        e = self.entries[port]
        if e.type != CONNECTED:
            raise ValueError(f"port {port} is not a connected entry")
        e.dest_ip, e.dest_qpn, e.va, e.rkey = dest_ip, dest_qpn, va, rkey
        e.ack_psn = self.last_ack_psn
        self.agg_entries_cache = self.agg_min = None
        return e

    # ------------------------------------------------------------ queries

    def min_ack(self) -> tuple[int, int]:
        """(min ack_psn over entries, owning port) — Alg 3 lines 6-9."""
        it = iter(self.entries.values())
        first = next(it)
        mn, mp = first.ack_psn, first.port
        for e in it:
            m2 = psn_min(mn, e.ack_psn, self.psn_window)
            if m2 != mn:
                mn, mp = e.ack_psn, e.port
        return mn, mp

    def table_bytes(self) -> int:
        return GROUP_BYTES + sum(ENTRY_BYTES[e.type] + 4
                                 for e in self.entries.values())


class ForwardingTables:
    """All multicast tables on one switch, indexed by GroupIP.

    Switch table memory is finite (the §3.3 arithmetic: 1K maximal
    groups in under a megabyte), so the store supports an optional
    ``capacity`` (max concurrently installed groups): installing one
    more evicts the least-recently-used group, exactly what a
    deployment does when group registrations outlive their tenants.
    ``get``/``create`` count as uses; ``remove`` is the explicit
    deregistration path.  ``evictions`` counts LRU victims so tests and
    benchmarks can see thrash.
    """

    def __init__(self, p4_mode: bool = False,
                 capacity: Optional[int] = None):
        from repro.core.packet import PSN_WINDOW_P4
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.tables: Dict[int, GroupTable] = {}
        self.window = PSN_WINDOW_P4 if p4_mode else PSN_WINDOW
        self.capacity = capacity
        self.evictions = 0
        self.salvages = 0                   # re-installs that reseeded PSN
        self.on_remove = None               # callback(table) on uninstall
        self._lru: Dict[int, None] = {}     # insertion-ordered id set
        # LRU-evicted MID-STREAM groups leave their cumulative ACK high
        # water mark here (group_ip -> last_ack_psn).  If the group is
        # re-created while its broadcast is still running, the fresh
        # table starts from that mark instead of the fresh-entry default,
        # so add_connected/add_forwarded seed every entry's ack_psn at
        # the stream position — otherwise the aggregate minimum would
        # wedge at "acked up to -1" and the whole group would stall
        # waiting for ACKs that can never go backwards.  ack_out_port is
        # the mid-stream marker: it is only ever learned from live data.
        self._evicted_psn: Dict[int, int] = {}

    def _touch(self, group_ip: int) -> None:
        self._lru.pop(group_ip, None)
        self._lru[group_ip] = None

    def get(self, group_ip: int) -> Optional[GroupTable]:
        t = self.tables.get(group_ip)
        if t is not None and self.capacity is not None:
            self._touch(group_ip)       # LRU order only matters under a cap
        return t

    def create(self, group_ip: int) -> GroupTable:
        if (self.capacity is not None and group_ip not in self.tables
                and len(self.tables) >= self.capacity):
            victim = next(iter(self._lru))
            vt = self.remove(victim)
            if vt.ack_out_port is not None:     # mid-stream: salvage PSN
                self._evicted_psn[victim] = vt.last_ack_psn
            self.evictions += 1
        t = GroupTable(group_ip, psn_window=self.window)
        salvaged = self._evicted_psn.pop(group_ip, None)
        if salvaged is not None:
            t.last_ack_psn = salvaged
            self.salvages += 1
        self.tables[group_ip] = t
        self._touch(group_ip)
        return t

    def remove(self, group_ip: int) -> Optional[GroupTable]:
        """Uninstall a group (deregistration); returns the old table.

        Explicit removal also forgets any eviction-salvaged PSN mark —
        deregistration means the stream is over, so a future re-install
        of the same GroupIP is a brand-new group."""
        self._lru.pop(group_ip, None)
        self._evicted_psn.pop(group_ip, None)
        t = self.tables.pop(group_ip, None)
        if t is not None and self.on_remove is not None:
            self.on_remove(t)
        return t

    def total_bytes(self) -> int:
        return sum(t.table_bytes() for t in self.tables.values())
