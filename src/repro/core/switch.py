"""Gleam switch data plane + control plane (§3.3–§3.5, §4, Appendix A).

Implements, faithfully:

- **Algorithm 1** — one-to-many data forwarding with per-port header
  rewrite (`connected` entries get dest IP/QPN replaced, src IP becomes
  GroupIP; WRITE packets additionally get their RETH va/rkey replaced from
  the per-receiver MR states).
- **Algorithms 2 & 3** — many-to-one ACK aggregation and NACK filtering:
  per-port cumulative ``ack_psn``; the aggregated ACK carries the minimum
  over downstream ports and is emitted when that minimum advances; a NACK
  is forwarded only when every receiver has acknowledged everything below
  its expected PSN (the Fig. 7 ordering hazard).
- **Algorithm 4** — envelope-driven table registration: reuse already-
  `forwarded` ports (optimal tree), least-utilized port for new ones
  (group-level load balancing), per-port sub-envelopes downstream.
- **§3.5 congestion-signal filtering** — per-port CNP counters with aging;
  only the most-congested port's signal passes upstream.
- **Appendix B source switching** — ``ack_out_port`` is re-learned when
  data enters a new port; the entry facing the current source is excluded
  from aggregation (it is the one port that never ACKs).
- **§4 P4 mode** — wrapped PSN comparisons in a 2^22 window instead of
  2^23.

The switch is transport-agnostic plumbing: it returns (out_port, packet)
emissions and the simulator owns queues, delays, ECN marking, and loss.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core import packet as pk
from repro.core.fattree import Topology
from repro.core.ftable import (CONNECTED, FORWARDED, ForwardingTables,
                               GroupTable)

Emit = Tuple[int, pk.Packet]


@dataclasses.dataclass
class SwitchStats:
    data_in: int = 0
    data_copies: int = 0
    acks_in: int = 0
    acks_out: int = 0
    nacks_in: int = 0
    nacks_out: int = 0
    cnps_in: int = 0
    cnps_out: int = 0
    envelopes: int = 0


class GleamSwitch:
    """One Gleam-capable switch; plain unicast forwarding for everything
    that doesn't hit a multicast table."""

    def __init__(self, name: str, topo: Topology, host_ip: Dict[str, int],
                 *, p4_mode: bool = False, cnp_aging_tau: float = 100e-6,
                 table_capacity: Optional[int] = None):
        self.name = name
        self.topo = topo
        self.host_ip = host_ip
        self.ip_host = {v: k for k, v in host_ip.items()}
        self.tables = ForwardingTables(p4_mode=p4_mode,
                                       capacity=table_capacity)
        self.tables.on_remove = self._release_ports
        self.port_util: Dict[int, int] = {}     # group registrations / port
        self.stats = SwitchStats()
        self.cnp_tau = cnp_aging_tau
        self._cnp_t: Dict[Tuple[int, int], float] = {}  # (group, port) -> t
        self.p4_mode = p4_mode
        # unicast next-hop memo: (dst_ip, flow_key) -> port or None.  The
        # topology is immutable during a run and every forwarded packet
        # of a connection hits the same pair, so the route is computed
        # once instead of per packet.
        self._nh_memo: Dict[Tuple[int, int], Optional[int]] = {}

    # --------------------------------------------------------- entry point

    def on_packet(self, p: pk.Packet, in_port: int, now: float) -> List[Emit]:
        kind = p.kind
        if kind == pk.ENVELOPE:
            return self._envelope(p, in_port, now)
        t = self.tables.get(p.dst_ip)
        if t is None:
            return self._unicast(p)
        if kind == pk.DATA:
            return self._data(t, p, in_port, now)
        if kind == pk.ACK:
            return self._ack(t, p, in_port, now)
        if kind == pk.NACK:
            return self._nack(t, p, in_port, now)
        if kind == pk.CNP:
            return self._cnp(t, p, in_port, now)
        return self._unicast(p)

    def route_envelope(self, p: pk.Packet, in_port: int,
                       now: float) -> List[Emit]:
        return self._envelope(p, in_port, now)

    def _release_ports(self, t) -> None:
        """A group was uninstalled (eviction/deregistration): give its
        registration load back to the port-utilization counters so
        Algorithm 4's least-utilized-port choice is not skewed by
        ghosts."""
        for port, refs in t.port_refs.items():
            self.port_util[port] = max(self.port_util.get(port, 0) - refs,
                                       0)

    def _count_port_ref(self, t: GroupTable, port: int) -> None:
        self.port_util[port] = self.port_util.get(port, 0) + 1
        t.port_refs[port] = t.port_refs.get(port, 0) + 1

    def _release_port_ref(self, t: GroupTable, port: int) -> int:
        """Give ONE member's registration load on ``port`` back;
        returns the remaining per-group refcount (0 = last member
        behind this port is gone and the tree edge can be pruned)."""
        self.port_util[port] = max(self.port_util.get(port, 0) - 1, 0)
        n = t.port_refs.get(port, 0) - 1
        if n > 0:
            t.port_refs[port] = n
            return n
        t.port_refs.pop(port, None)
        return 0

    # --------------------------------------------------------- data plane

    def _unicast(self, p: pk.Packet) -> List[Emit]:
        if p.kind == pk.ENVELOPE:
            return []  # envelopes are consumed by _envelope
        key = (p.dst_ip, p.src_ip * 131 + p.dst_qpn)
        port = self._nh_memo.get(key, -1)
        if port == -1:
            host = self.ip_host.get(p.dst_ip)
            try:
                port = None if host is None else self.topo.next_hop_port(
                    self.name, host, flow_key=key[1])
            except ValueError:
                port = None     # unroutable mid-fault: drop, not crash
            self._nh_memo[key] = port
        if port is None:
            return []
        return [(port, p)]

    def _data(self, t: GroupTable, p: pk.Packet, in_port: int,
              now: float) -> List[Emit]:
        """Algorithm 1 (+ MR-update interception, + Appendix B learning)."""
        self.stats.data_in += 1
        sync: List[Emit] = []
        if t.ack_out_port != in_port:
            # first data packet, or multicast source switched (Appendix B):
            # feedback must now exit through the new ingress port.
            prev_out = t.ack_out_port
            t.ack_out_port = in_port
            t.agg_entries_cache = t.agg_min = None
            if prev_out is not None and self._agg_entries(t):
                # source switch: the NEW reverse path has never seen this
                # subtree's cumulative state, so re-emit the aggregate
                # toward the new source.  In the planned Appendix-B
                # rotation the aggregate equals last_ack_psn and this
                # emits nothing; after a crash recovery it is what syncs
                # the re-elected sender's snd_una with reality.
                sync = self._generate(t, now)
        if p.op == "mr_update" and isinstance(p.payload, dict):
            # §3.3: the extra WRITE message carrying per-receiver MR info.
            # Update connected entries, then forward it as normal data so
            # every downstream switch (and receiver, for PSN continuity)
            # sees it.
            for e in t.entries.values():
                if e.type == CONNECTED and e.dest_ip in p.payload:
                    e.va, e.rkey = p.payload[e.dest_ip]
        out: List[Emit] = []
        for e in t.entries.values():
            if e.port == in_port:
                continue
            q = p.copy()
            if e.type == CONNECTED:
                q.dst_ip = e.dest_ip
                q.dst_qpn = e.dest_qpn
                q.src_ip = t.group_ip     # feedback will route by GroupIP
                if q.op == "write":       # rewrite RETH per receiver (§3.3)
                    q.va, q.rkey = e.va, e.rkey
            out.append((e.port, q))
        self.stats.data_copies += len(out)
        if sync:
            out.extend(sync)
        return out

    # ------------------------------------------------------ feedback plane

    def _agg_entries(self, t: GroupTable):
        """Entries that participate in aggregation: every tree port except
        the one facing the current source (it never ACKs).  Cached on the
        table; invalidated when entries or ``ack_out_port`` change."""
        lst = t.agg_entries_cache
        if lst is None:
            lst = t.agg_entries_cache = [
                e for e in t.entries.values() if e.port != t.ack_out_port]
        return lst

    def _advance_ack_psn(self, t: GroupTable, e, psn: int, w: int) -> None:
        """Cumulative per-port state (Alg. 2): ``ack_psn`` only moves
        forward.  The cached aggregate minimum survives unless the entry
        holding it is the one advancing."""
        if (psn - e.ack_psn) % pk.PSN_MOD < w:          # psn_geq, inlined
            e.ack_psn = psn
            agg = t.agg_min
            if agg is not None and agg[1] == e.port:
                t.agg_min = None

    def _ack(self, t: GroupTable, p: pk.Packet, in_port: int,
             now: float) -> List[Emit]:
        """Algorithm 2, ACK branch."""
        self.stats.acks_in += 1
        e = t.entries.get(in_port)
        if e is None or t.ack_out_port is None:
            return []
        self._advance_ack_psn(t, e, p.psn, t.psn_window)
        agg = t.agg_min
        if agg is not None and agg[0] == t.last_ack_psn \
                and t.nack_epsn is None:
            return []       # aggregate unchanged: Alg. 3 emits nothing
        return self._generate(t, now)

    def _nack(self, t: GroupTable, p: pk.Packet, in_port: int,
              now: float) -> List[Emit]:
        """Algorithm 2, NACK branch (lines 12-17)."""
        self.stats.nacks_in += 1
        e = t.entries.get(in_port)
        if e is None or t.ack_out_port is None:
            return []
        w = t.psn_window
        implied = pk.psn_sub(p.psn, 1)          # NACK acks everything < ePSN
        self._advance_ack_psn(t, e, implied, w)
        if t.nack_epsn is None or pk.psn_geq(t.nack_epsn, p.psn, w):
            t.nack_epsn = p.psn
        return self._generate(t, now)

    def _generate(self, t: GroupTable, now: float) -> List[Emit]:
        """Algorithm 3: aggregated ACK when the minimum advances; NACK only
        when all receivers acked everything below its expected PSN.

        The minimum over per-port ``ack_psn`` is cached in ``t.agg_min``:
        per-port cumulative ACKs only advance, so a full rescan is needed
        only when the entry that owned the minimum advances (or the entry
        set / source port changes) — every other feedback packet leaves
        the aggregate untouched.  This turns the per-ACK cost from
        O(ports) to amortized O(1), bit-identical to the full scan."""
        entries = self._agg_entries(t)
        if not entries:
            return []
        w = t.psn_window
        M = pk.PSN_MOD
        agg = t.agg_min
        if agg is None:
            e0 = entries[0]
            mn, mport = e0.ack_psn, e0.port
            for e in entries[1:]:
                a = e.ack_psn
                if a != mn and (mn - a) % M < w:        # psn_gt(mn, a)
                    mn, mport = a, e.port
            t.agg_min = (mn, mport)
        else:
            mn = agg[0]
        out: List[Emit] = []
        if mn != t.last_ack_psn and (mn - t.last_ack_psn) % M < w:
            out.append((t.ack_out_port,
                        self._feedback(t, pk.ack_packet(t.group_ip,
                                                        t.group_ip, mn))))
            t.last_ack_psn = mn
            self.stats.acks_out += 1
        if t.nack_epsn is not None:
            if pk.psn_add(mn, 1) == t.nack_epsn:
                out.append((t.ack_out_port,
                            self._feedback(t, pk.nack_packet(
                                t.group_ip, t.group_ip, t.nack_epsn))))
                t.nack_epsn = None
                self.stats.nacks_out += 1
            elif pk.psn_geq(mn, t.nack_epsn, w):
                t.nack_epsn = None   # loss already recovered downstream
        return out

    def _feedback(self, t: GroupTable, q: pk.Packet) -> pk.Packet:
        """Rewrite feedback headers at the source-facing hop ('L1 changes
        the connection-related states in the ACK header to match S's QP')."""
        e = t.entries.get(t.ack_out_port)
        if e is not None and e.type == CONNECTED:
            q.dst_ip = e.dest_ip
            q.dst_qpn = e.dest_qpn
        return q

    # -------------------------------------------------- congestion (§3.5)

    def _cnp(self, t: GroupTable, p: pk.Packet, in_port: int,
             now: float) -> List[Emit]:
        self.stats.cnps_in += 1
        if t.ack_out_port is None:
            return []
        key = (t.group_ip, in_port)
        # exponential aging (the paper's periodic aging, continuous form)
        last = self._cnp_t.get(key, now)
        cnt = t.cnp_count.get(in_port, 0.0)
        cnt = cnt * math.exp(-(now - last) / self.cnp_tau) + 1.0
        t.cnp_count[in_port] = cnt
        self._cnp_t[key] = now
        # age the others lazily for the comparison
        most = True
        for port, c in t.cnp_count.items():
            if port == in_port:
                continue
            lp = self._cnp_t.get((t.group_ip, port), now)
            c_aged = c * math.exp(-(now - lp) / self.cnp_tau)
            if c_aged > cnt:
                most = False
                break
        if not most:
            return []      # filtered: not the most congested link
        self.stats.cnps_out += 1
        return [(t.ack_out_port, self._feedback(t, p.copy()))]

    # ------------------------------------------------- control plane (A)

    def _envelope(self, p: pk.Packet, in_port: int, now: float) -> List[Emit]:
        """Algorithm 4 (install) or the §3.4 incremental teardown path,
        selected by the envelope's ``mft_op`` (absent = install, which
        keeps registration envelopes bit-identical).  Install is already
        incremental — a join envelope lands on the existing table and
        only adds the ports its nodes need."""
        self.stats.envelopes += 1
        info = p.payload
        if info.get("mft_op") in ("leave", "fail"):
            return self._envelope_remove(p, in_port, now)
        if info.get("mft_op") == "prune":
            return self._envelope_prune(p, in_port, now)
        if info.get("mft_op") == "sever":
            return self._envelope_sever(p, in_port, now)
        repair = info.get("mft_op") == "repair"
        g = info["group_ip"]
        t = self.tables.get(g) or self.tables.create(g)
        if info.get("master_ip"):
            t.master_ip = info["master_ip"]
        # Make the tree traversable from ANY member (Appendix B: the master
        # "can be any node" and the source may rotate): the upstream port the
        # envelope entered through is part of the tree too.  If it faces a
        # host the node-record branch below creates the connected entry;
        # otherwise it is a forwarded entry.
        up_peer = self.topo.ports[self.name][in_port][0]
        if up_peer not in self.host_ip and in_port not in t.entries:
            t.add_forwarded(in_port)
        down: Dict[int, list] = {}
        released = False
        if self.topo._down:
            # repair re-flood: tree edges over downed links are dead
            # weight — black-holed data copies AND a never-ACKing
            # aggregation entry.  Drop them up front; surviving members
            # re-register through live ports below (candidate_ports
            # already excludes downed ports), releasing any refs.
            for port in [pt for pt in t.entries
                         if (self.name, pt) in self.topo._down]:
                t.remove_port(port)
                released = True
        for node in info["nodes"]:
            ip = node["ip"]
            host = self.ip_host.get(ip)
            if host is None:
                continue
            # re-install (fault repair re-floods the full envelope): a
            # member already registered through its chosen port is left
            # untouched — idempotence keeps refcounts and ACK state from
            # drifting — but the sub-envelope still continues downstream
            # (a deeper switch may be the one that had to move).
            prev = t.member_port.get(ip)
            # directly connected?
            direct = None
            for port, (peer, _) in self.topo.ports[self.name].items():
                if peer == host:
                    direct = port
                    break
            if direct is not None:
                if prev == direct:
                    down.setdefault(direct, []).append(node)
                    continue
                if prev is not None:
                    released |= self._drop_member(t, ip)
                t.add_connected(direct, ip, node["qpn"],
                                node.get("va", 0), node.get("rkey", 0))
                self._count_port_ref(t, direct)
                t.member_port[ip] = direct
                down.setdefault(direct, []).append(node)
                continue
            try:
                cands = self.topo.candidate_ports(self.name, host)
            except ValueError:
                continue        # unroutable mid-fault: skip this node
            cands = [c for c in cands if c != in_port]
            if not cands:
                continue
            reuse = [c for c in cands
                     if c in t.entries and t.entries[c].type == FORWARDED]
            if reuse:
                out = reuse[0]            # reuse existing tree edge
            else:
                out = min(cands, key=lambda c: (self.port_util.get(c, 0), c))
            if prev == out:
                down.setdefault(out, []).append(node)
                continue
            if prev is not None:
                released |= self._drop_member(t, ip)
            t.add_forwarded(out)
            self._count_port_ref(t, out)
            t.member_port[ip] = out
            down.setdefault(out, []).append(node)
        if repair:
            # a repair envelope carries the FULL membership, so any
            # member still indexed here but absent from the sub-envelope
            # was rerouted around this switch by the new tree: release
            # its refs, or the stale branch below its old port survives
            # the sweep and keeps black-holing copies into the fault.
            node_ips = {node["ip"] for node in info["nodes"]}
            for ip in [m for m in t.member_port if m not in node_ips]:
                released |= self._drop_member(t, ip)
            # this switch is on the repaired tree, and the repaired tree
            # at this switch is exactly {in_port} + the sub-envelope
            # ports.  Any ref-less forwarded edge outside that set is a
            # stale old-tree edge: it would bounce data copies into
            # bypassed switches (and a never-ACKing aggregation entry).
            keep = set(down)
            keep.add(in_port)
            for port in [pt for pt, e in t.entries.items()
                         if pt not in keep and e.type == FORWARDED
                         and not t.port_refs.get(pt)]:
                t.remove_port(port)
                released = True
        emits: List[Emit] = []
        for port, nodes in down.items():
            q = p.copy()
            q.payload = {**info, "nodes": nodes}
            q.size = pk.HDR + 8 + 11 * len(nodes)   # Fig. 17 layout scale
            emits.append((port, q))
        if released and t.ack_out_port is not None and self._agg_entries(t):
            # a moved member's old port may have owned the pending
            # minimum: re-run Alg. 3 so the repaired tree un-wedges
            emits.extend(self._generate(t, now))
        return emits

    def _envelope_remove(self, p: pk.Packet, in_port: int,
                         now: float) -> List[Emit]:
        """Incremental MFT teardown (§3.4 maintenance): release each
        departing member's share of its tree port, prune forwarded
        ports whose last member is gone, uninstall the whole table when
        no member registers through this switch anymore — and un-wedge
        aggregation, because the removed receiver may have been the
        straggler holding the pending minimum (its outstanding PSN
        window is drained by re-running Algorithm 3 without it)."""
        info = p.payload
        g = info["group_ip"]
        t = self.tables.get(g)
        emits: List[Emit] = []
        if t is None:
            return emits
        down: Dict[int, list] = {}
        for node in info["nodes"]:
            ip = node["ip"]
            port = t.member_port.pop(ip, None)
            if port is None:
                # the member did not register THROUGH this switch (the
                # removal originates at a post-handover master whose
                # path differs from the install path): hold no local
                # ref to release, just relay the teardown along a tree
                # edge toward the member — the switches that did index
                # it (exactly the ones that counted refs) prune there
                host = self.ip_host.get(ip)
                if host is None:
                    continue
                try:
                    cands = [c for c in self.topo.candidate_ports(
                        self.name, host)
                        if c != in_port and c in t.entries]
                except ValueError:
                    continue    # unroutable mid-fault: nothing to relay
                if cands:
                    down.setdefault(cands[0], []).append(node)
                continue
            e = t.entries.get(port)
            refs_left = self._release_port_ref(t, port)
            # the sub-envelope continues toward the member: downstream
            # switches release their share, and the member host itself
            # learns it is out (a graceful leaver quiesces its QP and
            # confirms to the master from there)
            down.setdefault(port, []).append(node)
            if e is not None and (
                    (e.type == CONNECTED and e.dest_ip == ip)
                    or (e.type == FORWARDED and refs_left == 0)):
                t.remove_port(port)
        for port, nodes in down.items():
            q = p.copy()
            q.payload = {**info, "nodes": nodes}
            q.size = pk.HDR + 8 + 11 * len(nodes)
            emits.append((port, q))
        if not t.port_refs:
            # last member behind this switch is gone: uninstall the
            # table (memory + residual port load released via on_remove)
            self.tables.remove(g)
            return emits
        if t.ack_out_port is not None and self._agg_entries(t):
            emits.extend(self._generate(t, now))
        return emits

    # --------------------------------------------- fault plane (pruning)

    def _drop_member(self, t: GroupTable, ip: int) -> bool:
        """Release one member's local registration (dead host or a
        repair that moved it): give back its port ref and drop the
        entry when it was the last user.  Returns True if local state
        changed (the caller re-runs Alg. 3 to un-wedge)."""
        port = t.member_port.pop(ip, None)
        if port is None:
            return False
        e = t.entries.get(port)
        refs_left = self._release_port_ref(t, port)
        if e is not None and (
                (e.type == CONNECTED and e.dest_ip == ip)
                or (e.type == FORWARDED and refs_left == 0)):
            t.remove_port(port)
        return True

    def _toward_master(self, t: GroupTable, info: dict) -> Optional[int]:
        """Egress port for a switch-originated confirm: the aggregation
        reverse path when learned, else unicast toward the master."""
        if t is not None and t.ack_out_port is not None:
            return t.ack_out_port
        mip = (t.master_ip if t is not None else 0) or info.get(
            "master_ip", 0)
        mhost = self.ip_host.get(mip)
        if mhost is None:
            return None
        try:
            return self.topo.next_hop_port(self.name, mhost,
                                           flow_key=info["group_ip"])
        except ValueError:
            return None

    def prune_dead_member(self, ip: int, now: float,
                          group_ip: Optional[int] = None) -> List[Emit]:
        """Switch-originated teardown (fault plane): the access link to
        a member went permanently dark.  Prune the member from every
        group table serving it through this switch, re-run Alg. 3 so
        local aggregation un-wedges WITHOUT a master round-trip, and
        send a ``prune`` envelope along the aggregation reverse path —
        each upstream tree switch prunes hop-by-hop and the master host
        finally receives it as the teardown-confirm.

        ``group_ip`` scopes the teardown to ONE group's table: the
        fault plane drives this per group (each group's fault plan
        carries its own events), which also keeps batched ``run_many``
        scenarios independent experiments — a fault injected by one
        scenario must not prune another scenario's staged tables."""
        emits: List[Emit] = []
        host = self.ip_host.get(ip)
        dead_ports = {port for port, (peer, _)
                      in self.topo.ports[self.name].items() if peer == host}
        items = list(self.tables.tables.items()) if group_ip is None else \
            [(group_ip, self.tables.get(group_ip))]
        for g, t in items:
            if t is None:
                continue
            if t.ack_out_port in dead_ports:
                # the dead host was this table's DATA SOURCE: everything
                # this switch fed is severed from the stream, not just
                # the member entry.  Tear the local table down and relay
                # a ``sever`` out of each tree edge so the whole
                # orphaned tree unwinds hop-by-hop (a re-elected master
                # re-floods a fresh tree afterwards; without this the
                # old root's branch is off the new tree, no repair
                # envelope ever visits it, and its MFT entries leak
                # until group teardown).
                info = {"group_ip": g, "master_ip": t.master_ip,
                        "mft_op": "sever"}
                self.stats.envelopes += 1
                for port, e in sorted(t.entries.items()):
                    if e.type == FORWARDED and port not in dead_ports:
                        q = pk.Packet(pk.ENVELOPE, 0, info["master_ip"],
                                      size=pk.HDR + 8 + 11, payload=info)
                        emits.append((port, q))
                self.tables.remove(g)
                continue
            if ip not in t.member_port:
                continue
            info = {"group_ip": g, "master_ip": t.master_ip,
                    "mft_op": "prune", "nodes": [{"ip": ip}]}
            self._drop_member(t, ip)
            self.stats.envelopes += 1
            if not t.port_refs:
                self.tables.remove(g)
                t = None
            elif t.ack_out_port is not None and self._agg_entries(t):
                emits.extend(self._generate(t, now))
            out = self._toward_master(t, info)
            if out is not None:
                q = pk.Packet(pk.ENVELOPE, 0, info["master_ip"],
                              size=pk.HDR + 8 + 11, payload=info)
                emits.append((out, q))
        return emits

    def _envelope_sever(self, p: pk.Packet, in_port: int,
                        now: float) -> List[Emit]:
        """One hop of the dead-source teardown: the upstream neighbor
        toward the (dead) source unwound its table.  If data really
        entered through that edge (``ack_out_port`` — or it was never
        learned, i.e. the stream never started), this switch's subtree
        is severed too: uninstall and relay out of every remaining tree
        edge.  A switch that already re-rooted away from the severed
        upstream just prunes the dead edge and keeps serving."""
        info = p.payload
        t = self.tables.get(info["group_ip"])
        if t is None:
            return []
        if t.ack_out_port is not None and t.ack_out_port != in_port:
            if not t.port_refs.get(in_port):
                t.remove_port(in_port)
            return []
        emits: List[Emit] = [
            (port, p.copy()) for port, e in sorted(t.entries.items())
            if e.type == FORWARDED and port != in_port]
        self.tables.remove(info["group_ip"])
        return emits

    def _envelope_prune(self, p: pk.Packet, in_port: int,
                        now: float) -> List[Emit]:
        """One hop of the switch-originated teardown-confirm: prune the
        dead member locally, un-wedge aggregation, relay toward the
        master.  A non-tree switch (fallback unicast routing) just
        relays."""
        info = p.payload
        t = self.tables.get(info["group_ip"])
        emits: List[Emit] = []
        if t is not None:
            changed = False
            for node in info["nodes"]:
                changed |= self._drop_member(t, node["ip"])
            if not t.port_refs:
                self.tables.remove(info["group_ip"])
                t = None
            elif changed and t.ack_out_port is not None \
                    and self._agg_entries(t):
                emits.extend(self._generate(t, now))
        out = self._toward_master(t, info)
        if out is not None and out != in_port:
            emits.append((out, p))
        return emits
