"""Fluid (flow-level) simulator — the scalable companion to packetsim
for the §5.3 large-scale experiments (the paper parallelized ns-3; the
standard scalable substitute is max-min fair fluid flows).

Model:
- directed links with capacity (bytes/s), taken from the Topology;
- a **UnicastFlow** occupies the links of its path;
- a **MulticastFlow** (Gleam) occupies the union of its distribution-tree
  links but is ONE flow: every tree link must sustain the same rate (the
  switch replicates; the sender transmits once) — rate = min fair share
  over tree links.  Feedback aggregation keeps ACK load negligible, so
  only the data plane is modeled;
- progressive-filling (water-filling) max-min allocation, vectorized with
  numpy over the link-flow incidence;
- event loop advances to the next flow completion and re-allocates.

Under HPL's symmetric workloads flows complete in large simultaneous
waves, so even 16384-host topologies run in seconds.

``LinkMap`` (topology -> dense directed-link ids, unicast paths, multicast
tree link sets) is shared with the vectorized JAX backend
(``flowsim_jax``) so both flow engines route identically; only the
max-min solver differs.  The overlay *transports* of the Workload IR
(multiunicast / ring / binary-tree — ``core/workload.py``) route
through the same ``unicast_links`` per relay edge, so a baseline and
its Gleam counterpart contend on identical fabric paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fattree import Topology

INF = float("inf")

# --------------------------------------------------------------------------
# Expected-value loss model — calibration constants.
#
# The flow engines replace the packet engine's per-packet drop/NACK/RTO
# machinery with a per-flow rate multiplier plus an additive completion
# tail (docs/ARCHITECTURE.md "Loss & congestion model").  The three CAL_*
# constants below were fitted against fixed-seed packet-engine ground
# truth (32-seed means, testbed topology, 1 MiB flows, window=512,
# gleam + multiunicast x group 4/8 x loss 1e-3/1e-2) and hold every
# fitted point within ~11%:
#
# - GBN_REPLAY_CAL: a drop costs ``W = CAL * sqrt(bdp_flow * bdp_link)``
#   replayed packets (geometric mean of flow- and link-BDP — the NACK
#   turnaround sees the *link* RTT while replay drains at the *flow*
#   rate), giving goodput fraction ``(1-q) / (1-q + q W)``.
# - GBN_MERGE_CAL: multicast NACK aggregation merges rollbacks when
#   independent drops on L > 1 lossy hops land in one window; damps W
#   by ``1 + CAL * q * bdp_link * (1 - 1/L)``.
# - GBN_RTO_CAL: tail-drop recoveries that need a timeout instead of a
#   NACK add an expected stall ``rto * (CAL * n_pkts * q * p + q)``
#   applied to the completion time, not the rate (the bandwidth is
#   free during the stall for OTHER flows, which the packet engine
#   confirms: post-RTO flows catch up at full rate).
GBN_REPLAY_CAL = 0.84
GBN_MERGE_CAL = 0.25
GBN_RTO_CAL = 0.6

# DCQCN equilibrium (endpoint.py:RateState defaults: +5 Gbit/s per 55 us
# recovery period, receiver CNPs paced at 50 us, 1 Gbit/s floor).  At
# the sawtooth fixed point rate-cut == recovery between CNPs, so
# ``alpha_eq = DCQCN_RATE_NUM / rate`` and the mean undershoot below
# the fair share is ``alpha_eq / 4``.
DCQCN_RATE_NUM = 2.0 * (5e9 / 8.0) * 50e-6 / 55e-6      # bytes/s
DCQCN_MIN_RATE = 1e9 / 8.0                              # bytes/s
# a link is ECN-"hot" when >= 2 active flows hold it at capacity
ECN_UTIL_EPS = 1e-3


class LinkMap:
    """Dense directed-link indexing over a Topology, plus routing helpers.

    Link ``i`` is the directed (node, port) egress; ``cap[i]`` is its
    bandwidth in bytes/s and ``delay[i]`` its propagation delay.
    """

    def __init__(self, topo: Topology, shared_cache: bool = True):
        from repro.core.staging import StagingCache
        self.topo = topo
        # routed-path artifacts live in the topology's shared staging
        # cache so sweeps across engine instances derive each path once.
        # ``shared_cache=False`` keeps a private cache — the reference
        # mode for the cache-on/off bit-identity tests.  The link-id
        # assignment below is a pure function of the topology's links
        # dict (insertion-ordered), so cached id tuples are valid across
        # LinkMap instances; any ``connect`` bumps the fingerprint and
        # drops them.
        self.cache = StagingCache.of(topo) if shared_cache \
            else StagingCache(topo)
        arrays = self.cache.sync().misc.get("linkmap")
        if arrays is None:
            link_id: Dict[Tuple[str, int], int] = {}
            caps: List[float] = []
            delays: List[float] = []
            lossy: List[float] = []
            switches = set(topo.switches)
            for (node, port), link in topo.links.items():
                link_id[(node, port)] = len(caps)
                caps.append(link.bw)
                delays.append(link.delay)
                # the packet engine drops only on switch egress (packetsim
                # drops DATA iff from_switch), so host uplinks are lossless
                lossy.append(1.0 if node in switches else 0.0)
            arrays = (link_id, np.asarray(caps, float),
                      np.asarray(delays, float), np.asarray(lossy, float))
            self.cache.misc["linkmap"] = arrays
        self.link_id, self.cap, self.delay, self.lossy = arrays

    def add_many(self, rows) -> List["Flow"]:
        """Bulk ``add``: one Flow per (links, volume, loss) row, in
        order.  Staged layouts already carry immutable link tuples, so
        the per-call defensive ``tuple()`` copy is skipped for them —
        the fleet sweep stages thousands of flows per epoch and the
        per-flow call overhead is measurable."""
        flows = [Flow(links if type(links) is tuple else tuple(links),
                      float(volume), loss=loss)
                 for links, volume, loss in rows]
        self.flows.extend(flows)
        return flows

    def unicast_links(self, src: str, dst: str, key: int = 0):
        """Directed link ids along the ECMP unicast path src -> dst.

        Memoized in the shared staging cache: large-scale staging
        (fig14 meshes both tree links AND per-receiver latency paths)
        asks for the same pair repeatedly, and `run_many` sweeps ask
        again per scenario.
        """
        cache = self.cache.sync()
        memo = cache.paths.get((src, dst, key))
        if memo is None:
            cache.misses += 1
            memo = cache.paths[(src, dst, key)] = tuple(
                self.link_id[hop]
                for hop in self.topo.path_links(src, dst, key))
        else:
            cache.hits += 1
        return memo

    def multicast_tree_links(self, src: str, members: Sequence[str],
                             key: int = 0):
        """Union of unicast paths source -> members; reusing a port = the
        forwarded-entry reuse of Algorithm 4 (one copy per tree link).
        `key` seeds the ECMP choice — distinct groups spread over distinct
        spine planes (Algorithm 4's group-level load balancing).
        Memoized on (source, member frozenset, key)."""
        cache = self.cache.sync()
        mk = (src, frozenset(members), key)
        memo = cache.trees.get(mk)
        if memo is None:
            cache.misses += 1
            links = set()
            for m in members:
                if m != src:
                    links.update(self.unicast_links(src, m, key))
            memo = cache.trees[mk] = tuple(sorted(links))
        else:
            cache.hits += 1
        return memo

    def warm_paths(self, requests: Sequence[Tuple[str, str, int]]) -> None:
        """Batch-derive many unicast paths into the staging cache.

        Deduplicates against cached entries and hands the misses to
        ``Topology.paths_many`` — one shared frontier sweep per
        destination chunk instead of one Python BFS walk per pair.
        Bit-identical to per-pair ``unicast_links`` by construction.
        """
        cache = self.cache.sync()
        missing = sorted({r for r in requests if r not in cache.paths})
        if not missing:
            return
        cache.misses += len(missing)
        hop_lists = self.topo.paths_many(missing)
        link_id = self.link_id
        for req, hops in zip(missing, hop_lists):
            cache.paths[req] = tuple(link_id[h] for h in hops)
        cache.bound()

    def warm_latencies(self, requests) -> None:
        """Batch-fill the latency cache for (src, dst, seg_wire, key)
        requests whose paths are already cached (see ``warm_paths``).

        The per-segment reductions run in the same left-to-right order
        as the scalar ``FlowEngine._path_latency`` sums, so warmed
        entries are bit-identical to lazily computed ones.
        """
        cache = self.cache.sync()
        missing = [r for r in requests if r not in cache.lat]
        if not missing:
            return
        ids_list = [cache.paths.get((s, d, k)) for (s, d, _, k) in missing]
        lazy = [i for i, ids in enumerate(ids_list) if ids is None]
        if lazy:
            self.warm_paths([(missing[i][0], missing[i][1], missing[i][3])
                             for i in lazy])
            for i in lazy:
                s, d, _, k = missing[i]
                ids_list[i] = cache.paths[(s, d, k)]
        lens = np.fromiter((len(x) for x in ids_list), np.int64,
                           len(ids_list))
        total = int(lens.sum())
        if not total:
            for req in missing:
                cache.lat[req] = (0.0, 0.0)
            return
        flat = np.fromiter((i for x in ids_list for i in x), np.int64,
                           total)
        starts = np.cumsum(lens) - lens
        segs = np.fromiter((r[2] for r in missing), float, len(missing))
        delays = self.delay[flat]
        # store-and-forward terms seg/cap per hop, first hop zeroed (its
        # serialization is part of the message wire time)
        sf_terms = np.repeat(segs, lens) / self.cap[flat]
        sf_terms[starts[lens > 0]] = 0.0
        nz = lens > 0
        prop = np.zeros(len(missing))
        sf = np.zeros(len(missing))
        prop[nz] = np.add.reduceat(delays, starts[nz])
        sf[nz] = np.add.reduceat(sf_terms, starts[nz])
        for req, p, s in zip(missing, prop, sf):
            cache.lat[req] = (float(p + s), float(p))
        cache.bound()

    def segment_rates_many(self, problems) -> List[float]:
        """Solve a batch of dynamic-segment fairness snapshots.

        Each problem is ``(link_sets, loss)``: a tuple of link-id
        tuples (the OWN flow last, exactly the layout
        ``engine._stage_dynamic``'s per-segment ``fair()`` closure
        passes to ``static_maxmin``) plus the own flow's ``LossParams``
        (or None).  Returns the own flow's solved rate per problem,
        loss-factor-adjusted when loss params are given.

        This numpy fallback is the ORACLE the JAX override
        (``flowsim_jax.JaxFlowSim.segment_rates_many``) is tested
        against (<= 1e-6 relative) — per-problem it is bit-identical
        to the legacy per-segment path.
        """
        out = []
        for link_sets, lp in problems:
            rates = static_maxmin(self.cap, link_sets)
            r = float(rates[-1])
            if lp is not None:
                r *= segment_loss_factor(self.cap, link_sets, rates, lp)
            out.append(r)
        return out


@dataclasses.dataclass(frozen=True)
class LossParams:
    """Pre-folded per-flow loss-model inputs (see module constants).

    ``q`` is the per-packet probability that at least one tree copy is
    dropped; ``wsq`` folds the calibrated replay window and NACK-merge
    damping so the replay cost in packets is ``sqrt(rate * wsq)``
    (capped at ``wnd``, the go-back-N window); ``tail`` is the expected
    additive RTO stall added to the completion time; ``ecn`` turns on
    the DCQCN correction for shared saturated links.
    """

    q: float
    wsq: float
    wnd: float
    tail: float
    ecn: bool = False

    @classmethod
    def build(cls, *, loss_rate: float, lossy_hops: float, rtt: float,
              pkt_wire: float, cap_min: float, window: float,
              n_pkts: float, rto: float, ecn: bool = False,
              parallel: float = 1.0) -> Optional["LossParams"]:
        """Fold raw scenario parameters into solver inputs.

        ``parallel`` is the number of sibling lossy flows racing to the
        same op completion (a multiunicast/overlay fan-out finishes at
        the MAX over its K independent flows; the RTO stall is
        exponential-tailed, so the expected max exceeds the per-flow
        expectation by ~``ln K`` stall scales — Gumbel's correction).
        Returns None when the flow is unaffected (zero effective loss
        and no ECN marking) so callers can keep the exact lossless
        code path — the zero-loss flow results stay bit-identical.
        """
        hops = max(float(lossy_hops), 0.0)
        p = float(loss_rate)
        q = 1.0 - (1.0 - p) ** hops if p > 0.0 and hops > 0.0 else 0.0
        if q <= 0.0 and not ecn:
            return None
        bdp_link = cap_min * rtt / pkt_wire         # link BDP, packets
        merge = 1.0 + GBN_MERGE_CAL * q * bdp_link * (1.0 - 1.0 / hops) \
            if hops > 1.0 else 1.0
        wsq = (GBN_REPLAY_CAL / merge) ** 2 * (rtt / pkt_wire) * bdp_link
        tail = rto * (GBN_RTO_CAL * n_pkts * q * p + q) \
            * (1.0 + math.log(max(float(parallel), 1.0)))
        return cls(q=q, wsq=wsq, wnd=float(window), tail=tail,
                   ecn=bool(ecn))


@dataclasses.dataclass(slots=True)
class Flow:
    """One staged flow.  ``volume`` is the STAGED byte count and is
    never mutated by the solvers — metrics and re-run inspection rely
    on it; ``remaining`` is the solver's working countdown."""

    links: Tuple[int, ...]          # directed link ids
    volume: float                   # bytes staged (immutable after add)
    remaining: float = -1.0         # bytes left to serve (solver state)
    done_t: float = -1.0
    rate: float = 0.0
    tag: object = None
    loss: Optional[LossParams] = None

    def __post_init__(self):
        if self.remaining < 0.0:
            self.remaining = self.volume


def static_maxmin_loops(cap: np.ndarray,
                        link_sets: Sequence[Sequence[int]]):
    """Per-flow-loop progressive filling — the original implementation.

    Kept verbatim as the bit-identity oracle for the vectorized
    ``static_maxmin`` (the regression tests assert exact equality) and
    as the honest "before" leg of the ``dyn_segments`` benchmark.
    """
    flow_links = [np.asarray(ls, int) for ls in link_sets]
    n = len(flow_links)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    cap = np.asarray(cap, float).copy()
    for _ in range(64):                     # bottleneck rounds
        cnt = np.zeros(len(cap))
        for i, ls in enumerate(flow_links):
            if not frozen[i]:
                cnt[ls] += 1.0
        hot = cnt > 0
        if not hot.any():
            break
        share = np.full(len(cap), INF)
        share[hot] = cap[hot] / cnt[hot]
        # each unfrozen flow is limited by its tightest link
        limit = np.array([share[ls].min() if not frozen[i] else INF
                          for i, ls in enumerate(flow_links)])
        b = limit.min()
        # freeze flows crossing a bottleneck link (share == b)
        newly = (~frozen) & (limit <= b * (1 + 1e-12))
        if not newly.any():
            break
        for i in np.where(newly)[0]:
            rates[i] = b
            cap[flow_links[i]] -= b
            frozen[i] = True
        cap = np.maximum(cap, 0.0)
        if frozen.all():
            break
    return np.maximum(rates, 1e-9)


def static_maxmin(cap: np.ndarray, link_sets: Sequence[Sequence[int]]):
    """Max-min fair rates for a static flow set by progressive filling.

    ``cap`` is the dense capacity vector (bytes/s, NOT mutated);
    ``link_sets`` one link-id sequence per flow (link ids unique within
    a flow — trees and simple paths never repeat a link).  Returns (F,)
    rates.  Shared by the solver hot path (``FlowSim._allocate``) and
    the engine's piecewise-membership fairness snapshots
    (``engine.FlowEngine._stage_dynamic``).

    CSR-vectorized: one ``np.add.at`` scatter for per-link demand and
    one ``np.minimum.reduceat`` gather for per-flow limits replace the
    per-flow Python loop of ``static_maxmin_loops``; the element-wise
    operation sequences are identical (ordered scatters, exact min
    reductions), so the results are bit-identical.
    """
    n = len(link_sets)
    if n == 0:
        return np.maximum(np.zeros(0), 1e-9)
    lens = np.fromiter((len(ls) for ls in link_sets), np.int64, n)
    if not lens.all():           # empty set: no constraint — rare, and
        return static_maxmin_loops(cap, link_sets)    # not vectorizable
    total = int(lens.sum())
    flat = np.fromiter((i for ls in link_sets for i in ls), np.int64,
                       total)
    starts = np.cumsum(lens) - lens
    row = np.repeat(np.arange(n), lens)
    rates = np.zeros(n)
    frozen = np.zeros(n, bool)
    cap = np.asarray(cap, float).copy()
    live = np.ones(total, bool)             # per-entry ~frozen[row]
    for _ in range(64):                     # bottleneck rounds
        cnt = np.zeros(len(cap))
        np.add.at(cnt, flat[live], 1.0)
        hot = cnt > 0
        if not hot.any():
            break
        share = np.full(len(cap), INF)
        share[hot] = cap[hot] / cnt[hot]
        # each unfrozen flow is limited by its tightest link
        limit = np.minimum.reduceat(share[flat], starts)
        limit[frozen] = INF
        b = limit.min()
        # freeze flows crossing a bottleneck link (share == b)
        newly = (~frozen) & (limit <= b * (1 + 1e-12))
        if not newly.any():
            break
        rates[newly] = b
        # unbuffered ordered scatter == the loop's sequential per-flow
        # ``cap[links] -= b`` (row-major order, one op per element)
        np.subtract.at(cap, flat[newly[row]], b)
        frozen |= newly
        live = ~frozen[row]
        cap = np.maximum(cap, 0.0)
        if frozen.all():
            break
    return np.maximum(rates, 1e-9)


def segment_loss_factor(cap: np.ndarray, link_sets, rates, lp) -> float:
    """Expected-value loss/DCQCN rate factor for the LAST flow of a
    solved segment problem — the scalar numpy twin of
    ``kernels/ref.py:loss_factors_reference`` (same math as
    ``FlowSim._apply_loss``, evaluated for one flow against the whole
    segment's solved rates).  Used by the batched dynamic-segment
    solver so churn-under-loss fairness snapshots are loss-native."""
    util = np.zeros(len(cap))
    cnt = np.zeros(len(cap))
    for ls, r in zip(link_sets, rates):
        ids = np.asarray(ls, int)
        util[ids] += r
        cnt[ids] += 1.0
    hot = (cnt >= 2.0) & (util >= cap * (1.0 - ECN_UTIL_EPS))
    r = float(rates[-1])
    w = min(math.sqrt(max(r * lp.wsq, 0.0)), lp.wnd)
    gbn = (1.0 - lp.q) / max(1.0 - lp.q + lp.q * w, 1e-30)
    dc = 1.0
    if lp.ecn and hot[np.asarray(link_sets[-1], int)].any():
        alpha = min(DCQCN_RATE_NUM / max(r, 1e-30), 1.0)
        dc = max(1.0 - 0.25 * alpha,
                 min(DCQCN_MIN_RATE / max(r, 1e-30), 1.0))
    return min(max(gbn * dc, 1e-9), 1.0)


class FlowSim(LinkMap):
    def __init__(self, topo: Topology, shared_cache: bool = True):
        super().__init__(topo, shared_cache)
        self.flows: List[Flow] = []
        self.now = 0.0

    # ------------------------------------------------------------ engine

    def add(self, links, volume, tag=None, loss=None) -> Flow:
        f = Flow(tuple(links), float(volume), tag=tag, loss=loss)
        self.flows.append(f)
        return f

    def _allocate(self, active: List[Flow]):
        """Max-min fair rates by progressive filling (vectorized)."""
        if not active:
            return
        rates = static_maxmin(self.cap, [f.links for f in active])
        for f, r in zip(active, rates):
            f.rate = r

    def _apply_loss(self, active: List[Flow]):
        """Scale solved rates by the expected-value loss/DCQCN factors.

        The numpy twin of ``kernels/ref.py:loss_factors_reference``:
        identical math, applied to ``Flow.rate`` in place.
        """
        util = np.zeros(len(self.cap))
        cnt = np.zeros(len(self.cap))
        for f in active:
            ls = np.asarray(f.links, int)
            util[ls] += f.rate
            cnt[ls] += 1.0
        hot = (cnt >= 2.0) & (util >= self.cap * (1.0 - ECN_UTIL_EPS))
        for f in active:
            lp = f.loss
            if lp is None:
                continue
            w = min(math.sqrt(max(f.rate * lp.wsq, 0.0)), lp.wnd)
            gbn = (1.0 - lp.q) / max(1.0 - lp.q + lp.q * w, 1e-30)
            dc = 1.0
            if lp.ecn and hot[np.asarray(f.links, int)].any():
                alpha = min(DCQCN_RATE_NUM / max(f.rate, 1e-30), 1.0)
                dc = max(1.0 - 0.25 * alpha,
                         min(DCQCN_MIN_RATE / max(f.rate, 1e-30), 1.0))
            f.rate *= min(max(gbn * dc, 1e-9), 1.0)

    def run(self) -> float:
        """Run until every flow completes; returns the final time."""
        active = [f for f in self.flows if f.done_t < 0]
        lossy = any(f.loss is not None for f in active)
        while active:
            self._allocate(active)
            if lossy:
                self._apply_loss(active)
            dt = min(f.remaining / f.rate for f in active)
            self.now += dt
            still = []
            for f in active:
                f.remaining -= f.rate * dt
                if f.remaining <= 1e-6 * max(f.rate, 1.0):
                    # RTO stalls delay completion but free the fabric:
                    # the tail is added to done_t, not simulated time
                    f.done_t = self.now + (f.loss.tail if f.loss else 0.0)
                    f.remaining = 0.0
                else:
                    still.append(f)
            active = still
        if self.flows:
            return max(self.now, max(f.done_t for f in self.flows))
        return self.now
