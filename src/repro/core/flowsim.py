"""Fluid (flow-level) simulator — the scalable companion to packetsim
for the §5.3 large-scale experiments (the paper parallelized ns-3; the
standard scalable substitute is max-min fair fluid flows).

Model:
- directed links with capacity (bytes/s), taken from the Topology;
- a **UnicastFlow** occupies the links of its path;
- a **MulticastFlow** (Gleam) occupies the union of its distribution-tree
  links but is ONE flow: every tree link must sustain the same rate (the
  switch replicates; the sender transmits once) — rate = min fair share
  over tree links.  Feedback aggregation keeps ACK load negligible, so
  only the data plane is modeled;
- progressive-filling (water-filling) max-min allocation, vectorized with
  numpy over the link-flow incidence;
- event loop advances to the next flow completion and re-allocates.

Under HPL's symmetric workloads flows complete in large simultaneous
waves, so even 16384-host topologies run in seconds.

``LinkMap`` (topology -> dense directed-link ids, unicast paths, multicast
tree link sets) is shared with the vectorized JAX backend
(``flowsim_jax``) so both flow engines route identically; only the
max-min solver differs.  The overlay *transports* of the Workload IR
(multiunicast / ring / binary-tree — ``core/workload.py``) route
through the same ``unicast_links`` per relay edge, so a baseline and
its Gleam counterpart contend on identical fabric paths.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.fattree import Topology

INF = float("inf")


class LinkMap:
    """Dense directed-link indexing over a Topology, plus routing helpers.

    Link ``i`` is the directed (node, port) egress; ``cap[i]`` is its
    bandwidth in bytes/s and ``delay[i]`` its propagation delay.
    """

    def __init__(self, topo: Topology):
        self.topo = topo
        self.link_id: Dict[Tuple[str, int], int] = {}
        caps: List[float] = []
        delays: List[float] = []
        for (node, port), link in topo.links.items():
            self.link_id[(node, port)] = len(caps)
            caps.append(link.bw)
            delays.append(link.delay)
        self.cap = np.asarray(caps, float)
        self.delay = np.asarray(delays, float)
        self._path_memo: Dict[Tuple[str, str, int], Tuple[int, ...]] = {}

    def unicast_links(self, src: str, dst: str, key: int = 0):
        """Directed link ids along the ECMP unicast path src -> dst.

        Memoized: large-scale staging (fig14 meshes both tree links AND
        per-receiver latency paths) asks for the same pair repeatedly.
        """
        memo = self._path_memo.get((src, dst, key))
        if memo is None:
            memo = self._path_memo[(src, dst, key)] = tuple(
                self.link_id[hop]
                for hop in self.topo.path_links(src, dst, key))
        return memo

    def multicast_tree_links(self, src: str, members: Sequence[str],
                             key: int = 0):
        """Union of unicast paths source -> members; reusing a port = the
        forwarded-entry reuse of Algorithm 4 (one copy per tree link).
        `key` seeds the ECMP choice — distinct groups spread over distinct
        spine planes (Algorithm 4's group-level load balancing)."""
        links = set()
        for m in members:
            if m != src:
                links.update(self.unicast_links(src, m, key))
        return tuple(sorted(links))


@dataclasses.dataclass
class Flow:
    """One staged flow.  ``volume`` is the STAGED byte count and is
    never mutated by the solvers — metrics and re-run inspection rely
    on it; ``remaining`` is the solver's working countdown."""

    links: Tuple[int, ...]          # directed link ids
    volume: float                   # bytes staged (immutable after add)
    remaining: float = -1.0         # bytes left to serve (solver state)
    done_t: float = -1.0
    rate: float = 0.0
    tag: object = None

    def __post_init__(self):
        if self.remaining < 0.0:
            self.remaining = self.volume


class FlowSim(LinkMap):
    def __init__(self, topo: Topology):
        super().__init__(topo)
        self.flows: List[Flow] = []
        self.now = 0.0

    # ------------------------------------------------------------ engine

    def add(self, links, volume, tag=None) -> Flow:
        f = Flow(tuple(links), float(volume), tag=tag)
        self.flows.append(f)
        return f

    def _allocate(self, active: List[Flow]):
        """Max-min fair rates by progressive filling (vectorized)."""
        if not active:
            return
        flow_links = [np.asarray(f.links, int) for f in active]
        n = len(active)
        rates = np.zeros(n)
        frozen = np.zeros(n, bool)
        cap = self.cap.copy()
        for _ in range(64):                     # bottleneck rounds
            cnt = np.zeros(len(cap))
            for i, ls in enumerate(flow_links):
                if not frozen[i]:
                    cnt[ls] += 1.0
            hot = cnt > 0
            if not hot.any():
                break
            share = np.full(len(cap), INF)
            share[hot] = cap[hot] / cnt[hot]
            # each unfrozen flow is limited by its tightest link
            limit = np.array([share[ls].min() if not frozen[i] else INF
                              for i, ls in enumerate(flow_links)])
            b = limit.min()
            # freeze flows crossing a bottleneck link (share == b)
            newly = (~frozen) & (limit <= b * (1 + 1e-12))
            if not newly.any():
                break
            for i in np.where(newly)[0]:
                rates[i] = b
                cap[flow_links[i]] -= b
                frozen[i] = True
            cap = np.maximum(cap, 0.0)
            if frozen.all():
                break
        for f, r in zip(active, rates):
            f.rate = max(r, 1e-9)

    def run(self) -> float:
        """Run until every flow completes; returns the final time."""
        active = [f for f in self.flows if f.done_t < 0]
        while active:
            self._allocate(active)
            dt = min(f.remaining / f.rate for f in active)
            self.now += dt
            still = []
            for f in active:
                f.remaining -= f.rate * dt
                if f.remaining <= 1e-6 * max(f.rate, 1.0):
                    f.done_t = self.now
                    f.remaining = 0.0
                else:
                    still.append(f)
            active = still
        return self.now
