"""Event-driven packet-level simulator (the ns-3 analogue of §5.3).

Models: per-egress FIFO serialization at link rate, propagation delay,
ECN marking on backlog, random packet discard at switch egress ("emulated
via randomly discarding packets in the middle switches"), RC endpoints
(endpoint.QP) on hosts, Gleam switches (switch.GleamSwitch) in the fabric.

The engine is deliberately simple: a heapq of (time, seq, fn) events.
Hosts emit through a single NIC egress; data-plane pacing is ACK-clocked
go-back-N + DCQCN rate limiting inside the QPs.

A packet addressed to a QPN a host does not own is counted in
``no_qp_drops`` — this is exactly the Fig. 3 incompatibility (traditional
L3 multicast forwarding delivers packets no RC QP matches), which the
tests reproduce.
"""
from __future__ import annotations

import heapq
import itertools
import random
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.core import packet as pk
from repro.core.endpoint import INF, QP
from repro.core.fattree import Topology, host_ip_map
from repro.core.switch import GleamSwitch


class Host:
    def __init__(self, name: str, ip: int, sim: "PacketSim"):
        self.name = name
        self.ip = ip
        self.sim = sim
        self.qps: Dict[int, QP] = {}
        self.ctrl: deque = deque()          # feedback/control, priority
        self.no_qp_drops = 0
        self.on_envelope: Optional[Callable] = None
        self.on_envelope_ack: Optional[Callable] = None
        self._qp_rr = 0
        self._kick_t = INF
        # per-message CPU submission overhead (storage-stack model, §5.2.2)
        self.overhead = 0.0

    def add_qp(self, qp: QP) -> QP:
        self.qps[qp.qpn] = qp
        return qp

    # ------------------------------------------------------------ receive

    def on_packet(self, p: pk.Packet, now: float) -> None:
        if p.kind == pk.DATA:
            qp = self.qps.get(p.dst_qpn)
            if qp is None:
                self.no_qp_drops += 1       # Fig. 3: no matching QP
                return
            for fb in qp.on_data(p, now):
                self.ctrl.append(fb)
            self.sim.kick(self, now)
            return
        if p.kind in (pk.ACK, pk.NACK, pk.CNP):
            qp = self.qps.get(p.dst_qpn)
            if qp is None:
                self.no_qp_drops += 1
                return
            if p.kind == pk.ACK:
                qp.on_ack(p.psn, now)
            elif p.kind == pk.NACK:
                qp.on_nack(p.psn, now)
            else:
                qp.on_cnp(now)
            self.sim.arm_timer(qp, self)
            self.sim.kick(self, now)
            return
        if p.kind == pk.ENVELOPE:
            if self.on_envelope:
                self.on_envelope(p, now)
            return
        if p.kind == pk.ENVELOPE_ACK and self.on_envelope_ack:
            self.on_envelope_ack(p, now)

    # ------------------------------------------------------------ emit

    def next_emission(self, now: float):
        """(packet or None, next time anything becomes ready)."""
        if self.ctrl:
            return self.ctrl.popleft(), now
        qpns = [q for q in self.qps.values() if q.sq_psn != q.snd_nxt
                or q.snd_una != q.sq_psn]
        earliest = INF
        for i in range(len(qpns)):
            qp = qpns[(self._qp_rr + i) % len(qpns)]
            p, t = qp.next_packet(now)
            if p is not None:
                self._qp_rr = (self._qp_rr + i + 1) % max(len(qpns), 1)
                self.sim.arm_timer(qp, self)
                return p, t
            earliest = min(earliest, t)
        return None, earliest


class PacketSim:
    def __init__(self, topo: Topology, *, loss_rate: float = 0.0,
                 seed: int = 0, p4_mode: bool = False,
                 ecn_backlog: float = INF, drop_feedback: bool = False):
        self.topo = topo
        self.loss_rate = loss_rate
        self.drop_feedback = drop_feedback
        self.rng = random.Random(seed)
        self.ecn_backlog = ecn_backlog      # seconds of egress backlog
        self.host_ip = host_ip_map(topo)
        self.hosts: Dict[str, Host] = {
            h: Host(h, ip, self) for h, ip in self.host_ip.items()}
        self.by_ip: Dict[int, Host] = {h.ip: h for h in self.hosts.values()}
        self.switches: Dict[str, GleamSwitch] = {
            s: GleamSwitch(s, topo, self.host_ip, p4_mode=p4_mode)
            for s in topo.switches}
        self._q: List = []
        self._seq = itertools.count()
        self._free: Dict[tuple, float] = {}   # (node, port) -> egress free t
        self.now = 0.0
        self.events = 0
        self.dropped = 0
        self.tx_bytes = 0

    # ------------------------------------------------------------ engine

    def schedule(self, t: float, fn: Callable[[float], None]) -> None:
        heapq.heappush(self._q, (t, next(self._seq), fn))

    def run(self, until: float = INF, max_events: int = 50_000_000) -> float:
        while self._q:
            t, _, fn = heapq.heappop(self._q)
            if t > until:
                self.now = until
                break
            self.now = t
            fn(t)
            self.events += 1
            if self.events > max_events:
                raise RuntimeError("event budget exceeded")
        return self.now

    # ------------------------------------------------------------ links

    def send(self, node: str, port: int, p: pk.Packet, now: float) -> None:
        link = self.topo.link(node, port)
        key = (node, port)
        start = max(now, self._free.get(key, 0.0))
        done = start + p.size / link.bw
        self._free[key] = done
        self.tx_bytes += p.size
        if done - now > self.ecn_backlog and p.kind == pk.DATA:
            p.ecn = True
        peer, peer_port = self.topo.peer(node, port)
        is_switch = node in self.switches
        if is_switch and self.loss_rate > 0.0 and (
                p.kind == pk.DATA or self.drop_feedback):
            if self.rng.random() < self.loss_rate:
                self.dropped += 1
                return
        self.schedule(done + link.delay,
                      lambda t, pr=peer, pp=peer_port, q=p:
                      self._arrive(pr, pp, q, t))

    def _arrive(self, node: str, in_port: int, p: pk.Packet,
                now: float) -> None:
        sw = self.switches.get(node)
        if sw is not None:
            for out_port, q in sw.on_packet(p, in_port, now):
                self.send(node, out_port, q, now)
            return
        self.hosts[node].on_packet(p, now)

    # ------------------------------------------------------------ hosts

    def kick(self, host: Host, now: float) -> None:
        """Run the host NIC emission loop now (packet arrival, submit).

        Does NOT touch the wakeup marker — only _fire consumes it — so
        repeated kicks while the NIC is serializing dedupe to a single
        scheduled wakeup instead of multiplying events."""
        self._run_host(host, now)

    def _run_host(self, host: Host, now: float) -> None:
        key = (host.name, 0)
        free = self._free.get(key, 0.0)
        if free > now + 1e-15:              # NIC serializing: come back
            self._arm_kick(host, free)
            return
        p, t_next = host.next_emission(now)
        if p is not None:
            self.send(host.name, 0, p, now)
            self._arm_kick(host, self._free[key])
        elif t_next < INF:
            self._arm_kick(host, t_next)

    def _arm_kick(self, host: Host, t: float) -> None:
        if host._kick_t <= t + 1e-15:
            return                          # earlier wakeup already armed
        host._kick_t = t
        self.schedule(t, lambda tt, h=host: self._fire(h, tt))

    def _fire(self, host: Host, now: float) -> None:
        if host._kick_t < now - 1e-15:
            return                          # superseded by an earlier fire
        host._kick_t = INF                  # consume the marker
        self._run_host(host, now)

    # ------------------------------------------------------------ timers

    def arm_timer(self, qp: QP, host: Host) -> None:
        t = qp.timer_deadline
        if t == INF:
            return
        pending = getattr(qp, "_timer_ev", INF)
        if pending <= t + 1e-15:
            return
        qp._timer_ev = t
        self.schedule(t, lambda tt, q=qp, h=host: self._timer_fire(q, h, tt))

    def _timer_fire(self, qp: QP, host: Host, now: float) -> None:
        qp._timer_ev = INF
        if qp.timer_deadline <= now + 1e-12:
            qp.on_timeout(now)
            self.kick(host, now)
        self.arm_timer(qp, host)

    # ------------------------------------------------------- convenience

    def host_of_ip(self, ip: int) -> Host:
        return self.by_ip[ip]

    def send_control(self, host: Host, p: pk.Packet, now: float) -> None:
        host.ctrl.append(p)
        self.kick(host, now)
