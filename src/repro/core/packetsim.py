"""Event-driven packet-level simulator (the ns-3 analogue of §5.3).

Models: per-egress FIFO serialization at link rate, propagation delay,
ECN marking on backlog, random packet discard at switch egress ("emulated
via randomly discarding packets in the middle switches"), RC endpoints
(endpoint.QP) on hosts, Gleam switches (switch.GleamSwitch) in the fabric.

The engine is a heapq of **typed event records** — plain tuples
``(t, seq, kind, ...)`` dispatched by an integer kind in the run loop:

- ``ARRIVE_SW (0)`` / ``ARRIVE_HOST (4)`` — ``(t, seq, kind, handler,
  in_port, packet)``: a packet reaches the far end of a link; the
  destination switch/host object is resolved once per link (see
  ``_link_info``) and dispatched without any per-hop closure or name
  lookup;
- ``HOST (1)``     — ``(t, seq, 1, host)``: a deferred NIC wakeup
  (the dedup marker ``host._kick_t`` still guards against multiplying
  these);
- ``TIMER (2)``    — ``(t, seq, 2, qp, host)``: a QP retransmission
  timer may have expired;
- ``CALL (3)``     — ``(t, seq, 3, fn)``: generic callback, the escape
  hatch ``schedule()`` keeps for external users (overlay relays, tests).

The ``seq`` tiebreaker makes heap comparisons never reach the payload
and preserves FIFO order among same-time events, so the dispatch is
bit-identical to the old ``(t, seq, lambda)`` loop while allocating no
closures on the per-packet path.

Hosts emit through a single NIC egress; data-plane pacing is ACK-clocked
go-back-N + DCQCN rate limiting inside the QPs.  Each host maintains a
**ready-QP set** — the QPs whose sender side has work pending
(``sq_psn != snd_nxt or snd_una != sq_psn``), kept in sync by the QP's
submit/ACK/NACK/timeout transitions — so ``next_emission`` round-robins
over exactly the QPs the old code's full rescan would have selected,
without rebuilding the list per packet.

Terminal packets are recycled through ``packet.release``'s free list:
a packet consumed by a host's RC logic, absorbed by a switch without
being re-emitted, or discarded by the loss model provably has no other
live references (switch replication always emits fresh copies).

A packet addressed to a QPN a host does not own is counted in
``no_qp_drops`` — this is exactly the Fig. 3 incompatibility (traditional
L3 multicast forwarding delivers packets no RC QP matches), which the
tests reproduce.
"""
from __future__ import annotations

import heapq
import itertools
import random
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.core import packet as pk
from repro.core.endpoint import INF, QP
from repro.core.fattree import Topology, host_ip_map
from repro.core.switch import GleamSwitch

# typed event kinds (index 2 of every heap tuple).  Arrival events carry
# the destination handler OBJECT (switch or host), resolved once at send
# time through the link memo, so the dispatch does no name lookups.
EV_ARRIVE_SW = 0                 # (t, seq, 0, switch, in_port, packet)
EV_HOST = 1                      # (t, seq, 1, host)
EV_TIMER = 2                     # (t, seq, 2, qp, host)
EV_CALL = 3                      # (t, seq, 3, fn)
EV_ARRIVE_HOST = 4               # (t, seq, 4, host, in_port, packet)

# hot-path constant aliases (module globals: no attribute chasing)
_DATA = pk.DATA
_ACK = pk.ACK
_NACK = pk.NACK
_CNP = pk.CNP
_ENV = pk.ENVELOPE
_ENV_ACK = pk.ENVELOPE_ACK


class EventBudgetExceeded(RuntimeError):
    """``PacketSim.run`` popped more events than ``max_events`` allows.

    A ``RuntimeError`` subclass so existing broad handlers keep working.
    The simulator is left fully inspectable: ``events`` and ``now``
    mirror the engine state at raise time, the event queue keeps its
    remaining events, and ``run()`` may simply be called again with a
    larger budget to continue the run.
    """

    def __init__(self, events: int, now: float):
        super().__init__(
            f"event budget exceeded after {events} events at t={now:.9e}s")
        self.events = events
        self.now = now


class Host:
    def __init__(self, name: str, ip: int, sim: "PacketSim"):
        self.name = name
        self.ip = ip
        self.sim = sim
        self.qps: Dict[int, QP] = {}
        self.ctrl: deque = deque()          # feedback/control, priority
        self.no_qp_drops = 0
        self.dead_drops = 0                 # traffic to deactivated QPs
        self.dark = False                   # NIC gone dark (fault plane)
        self.on_envelope: Optional[Callable] = None
        self.on_envelope_ack: Optional[Callable] = None
        self._qp_rr = 0
        self._kick_t = INF
        # single-NIC egress link record (see PacketSim._links); filled in
        # by PacketSim.__init__ for every host with a port-0 uplink
        self._nic: Optional[list] = [0.0, 0.0, 0, None, 0, False, 0.0,
                                     False]
        # per-message CPU submission overhead (storage-stack model, §5.2.2)
        self.overhead = 0.0
        # ready-QP set: QPs with sender-side work pending, maintained by
        # QP._ready_sync on every pending-predicate transition.  The
        # iteration list is rebuilt (in QP registration order, matching
        # the old full-scan order) only when membership changes.
        self._ready: Dict[int, QP] = {}
        self._ready_list: List[QP] = []
        self._ready_stale = False

    def add_qp(self, qp: QP) -> QP:
        qp._host = self
        qp._order = len(self.qps)
        self.qps[qp.qpn] = qp
        qp._ready_sync()
        return qp

    def _mark_ready(self, qp: QP) -> None:
        if qp.qpn not in self._ready:
            self._ready[qp.qpn] = qp
            self._ready_stale = True

    def _mark_idle(self, qp: QP) -> None:
        if self._ready.pop(qp.qpn, None) is not None:
            self._ready_stale = True

    # ------------------------------------------------------------ receive

    def on_packet(self, p: pk.Packet, now: float) -> None:
        if self.dark:                       # gone-dark NIC: silent sink
            self.dead_drops += 1
            return
        kind = p.kind
        if kind == _DATA:
            qp = self.qps.get(p.dst_qpn)
            if qp is None:
                self.no_qp_drops += 1       # Fig. 3: no matching QP
                return
            if not qp.alive:
                self.dead_drops += 1        # failed member: silent sink
                return
            fb = qp.on_data(p, now)
            if fb:
                self.ctrl.extend(fb)
            self.sim._run_host(self, now)
            return
        if kind == _ACK or kind == _NACK or kind == _CNP:
            qp = self.qps.get(p.dst_qpn)
            if qp is None:
                self.no_qp_drops += 1
                return
            if not qp.alive:
                self.dead_drops += 1
                return
            if kind == _ACK:
                qp.on_ack(p.psn, now)
            elif kind == _NACK:
                qp.on_nack(p.psn, now)
            else:
                qp.on_cnp(now)
            sim = self.sim
            sim.arm_timer(qp, self)
            sim._run_host(self, now)
            return
        if kind == _ENV:
            if self.on_envelope:
                self.on_envelope(p, now)
            return
        if kind == _ENV_ACK and self.on_envelope_ack:
            self.on_envelope_ack(p, now)

    # ------------------------------------------------------------ emit

    def next_emission(self, now: float):
        """(packet or None, next time anything becomes ready).

        Round-robins over the ready set only; membership is exactly the
        pending predicate the old implementation evaluated by scanning
        every QP, and the iteration order (QP registration order) and
        ``_qp_rr`` arithmetic are unchanged, so emission interleaving is
        bit-identical."""
        if self.ctrl:
            return self.ctrl.popleft(), now
        if self._ready_stale:
            self._ready_list = sorted(self._ready.values(),
                                      key=lambda q: q._order)
            self._ready_stale = False
        qpns = self._ready_list
        n = len(qpns)
        earliest = INF
        rr = self._qp_rr
        for i in range(n):
            qp = qpns[(rr + i) % n]
            p, t = qp.next_packet(now)
            if p is not None:
                self._qp_rr = (rr + i + 1) % n
                self.sim.arm_timer(qp, self)
                return p, t
            if t < earliest:
                earliest = t
        return None, earliest


class PacketSim:
    def __init__(self, topo: Topology, *, loss_rate: float = 0.0,
                 seed: int = 0, p4_mode: bool = False,
                 ecn_backlog: float = INF, drop_feedback: bool = False):
        self.topo = topo
        self.loss_rate = loss_rate
        self.drop_feedback = drop_feedback
        self.seed = seed
        self.rng = random.Random(seed)
        self.ecn_backlog = ecn_backlog      # seconds of egress backlog
        self.host_ip = host_ip_map(topo)
        self.hosts: Dict[str, Host] = {
            h: Host(h, ip, self) for h, ip in self.host_ip.items()}
        self.by_ip: Dict[int, Host] = {h.ip: h for h in self.hosts.values()}
        self.switches: Dict[str, GleamSwitch] = {
            s: GleamSwitch(s, topo, self.host_ip, p4_mode=p4_mode)
            for s in topo.switches}
        self._q: List = []
        self._seq = itertools.count()
        # (node, port) -> [bw, delay, arrive_kind, handler, peer_port,
        #                  from_switch, free_t, down]: lazily-memoized
        # link facts (the topology is immutable while a sim exists) plus
        # the mutable egress-free time and fault-plane down flag in the
        # same record, so the per-hop path does one dict probe total.
        # ``_out`` indexes the same records as node -> port-indexed list
        # (string keys hash faster than fresh tuples on the per-copy
        # emission path).
        self._links: Dict[tuple, list] = {}
        self._out: Dict[str, List[Optional[list]]] = {}
        self.now = 0.0
        self.events = 0
        self.dropped = 0
        self.fault_dropped = 0              # black-holed on a downed link
        self.tx_bytes = 0
        self._faulted = False               # any fault API called since
                                            # the last clear_faults()
        self._dark_deactivated: list = []   # QPs host_dark() silenced
        for h in self.hosts.values():       # hosts emit through port 0
            if 0 in topo.ports.get(h.name, ()):
                h._nic = self._link_info(h.name, 0)

    @property
    def _free(self) -> Dict[tuple, float]:
        """Egress-occupied-until view, (node, port) -> t (diagnostics)."""
        return {k: v[6] for k, v in self._links.items() if v[6] > 0.0}

    def reset_free(self) -> None:
        """Clear every egress reservation (scenario quiesce)."""
        for info in self._links.values():
            info[6] = 0.0

    # ------------------------------------------------------- fault plane
    #
    # The engine lowers each FaultEvent to one of these calls on the
    # typed event loop.  Fabric faults flip the down flag in the
    # memoized link records (so the hot path pays one truthiness test,
    # no dict probe) *and* in the topology (so repair-time route
    # recomputation sees the survivors); host faults silence the NIC.
    # clear_faults() restores everything at scenario quiesce.

    def _flag_link(self, a: str, b: str, down: bool) -> None:
        pa, pb = self.topo._link_ports(a, b)
        for node, port in ((a, pa), (b, pb)):
            info = self._links.get((node, port))
            if info is not None:
                info[7] = down

    def _routes_dirty(self) -> None:
        for sw in self.switches.values():
            sw._nh_memo.clear()

    def link_down(self, a: str, b: str) -> None:
        self._faulted = True
        self.topo.set_link_down(a, b, True)
        self._flag_link(a, b, True)
        self._routes_dirty()

    def link_up(self, a: str, b: str) -> None:
        self.topo.set_link_down(a, b, False)
        self._flag_link(a, b, False)
        self._routes_dirty()

    def switch_down(self, name: str) -> None:
        self._faulted = True
        self.topo.set_switch_down(name, True)
        for port, (peer, pport) in sorted(self.topo.ports[name].items()):
            for node, p in ((name, port), (peer, pport)):
                info = self._links.get((node, p))
                if info is not None:
                    info[7] = True
        self._routes_dirty()

    def host_dark(self, name: str) -> None:
        """Host NIC goes silently dark: drops everything, emits nothing.
        The fabric links stay up — detection is the neighbours' job."""
        self._faulted = True
        host = self.hosts[name]
        host.dark = True
        host.ctrl.clear()
        for qp in host.qps.values():
            if qp.alive:
                self._dark_deactivated.append(qp)
                qp.deactivate()

    def retire_qp(self, qp) -> None:
        """Permanently decommission a QP silenced by ``host_dark``: the
        scenario reset (``clear_faults``) revives darkened QPs so OTHER
        groups sharing the host keep working across ``run_many``
        scenarios — but the faulted group's own QP must never come
        back.  Its group excised the member (re-election / teardown
        confirm) and a revived sender would replay its frozen
        outstanding window into tables that no longer exist, stealing
        NIC bandwidth from the next scenario."""
        try:
            self._dark_deactivated.remove(qp)
        except ValueError:
            pass

    def clear_faults(self) -> None:
        """Undo every injected fault (scenario quiesce).  Reactivation
        matters: cached static groups share host QPs across run_many
        scenarios, so a QP silenced by host_dark must come back."""
        if not self._faulted:
            return
        self._faulted = False
        for info in self._links.values():
            info[7] = False
        self.topo.clear_down()
        for h in self.hosts.values():
            h.dark = False
        for qp in self._dark_deactivated:
            qp.alive = True
            qp._ready_sync()
        self._dark_deactivated.clear()
        self._routes_dirty()

    # ------------------------------------------------------------ engine

    def schedule(self, t: float, fn: Callable[[float], None]) -> None:
        """Generic callback event — the non-hot-path escape hatch."""
        heapq.heappush(self._q, (t, next(self._seq), EV_CALL, fn))

    def reseed_scenario(self, index: int) -> None:
        """Give scenario ``index`` its own deterministic RNG stream,
        derived from the constructor seed only — never from how many
        draws earlier scenarios consumed.  This is what makes serial and
        process-parallel ``run_many`` bit-identical (and doubles as the
        multi-seed axis of the loss sweeps)."""
        self.rng.seed(self.seed ^ (0x9E3779B97F4A7C15 * (index + 1)))

    def run(self, until: float = INF, max_events: int = 50_000_000) -> float:
        q = self._q
        pop = heapq.heappop
        release = pk.release
        events = self.events
        try:
            while q:
                if q[0][0] > until:
                    self.now = until
                    break
                ev = pop(q)
                t = ev[0]
                self.now = t
                kind = ev[2]
                if kind == 4:                           # EV_ARRIVE_HOST
                    p = ev[5]
                    ev[3].on_packet(p, t)
                    k = p.kind
                    if k != _ENV and k != _ENV_ACK:
                        release(p)
                elif kind == 0:                         # EV_ARRIVE_SW
                    sw = ev[3]
                    p = ev[5]
                    kept = False
                    name = sw.name
                    for out_port, c in sw.on_packet(p, ev[4], t):
                        if c is p:
                            kept = True
                        self.send(name, out_port, c, t)
                    if not kept:
                        release(p)
                elif kind == 1:                         # EV_HOST
                    self._fire(ev[3], t)
                elif kind == 2:                         # EV_TIMER
                    self._timer_fire(ev[3], ev[4], t)
                else:                                   # EV_CALL
                    ev[3](t)
                events += 1
                if events > max_events:
                    self.events = events
                    raise EventBudgetExceeded(events, self.now)
        finally:
            self.events = events
        return self.now

    # ------------------------------------------------------------ links

    def _link_info(self, node: str, port: int) -> list:
        link = self.topo.link(node, port)
        peer, peer_port = self.topo.peer(node, port)
        sw = self.switches.get(peer)
        kind = EV_ARRIVE_SW if sw is not None else EV_ARRIVE_HOST
        handler = sw if sw is not None else self.hosts[peer]
        info = self._links[(node, port)] = [
            link.bw, link.delay, kind, handler, peer_port,
            node in self.switches, 0.0,
            self.topo.is_down(node, port)]
        by_port = self._out.setdefault(node, [])
        while len(by_port) <= port:
            by_port.append(None)
        by_port[port] = info
        return info

    def send(self, node: str, port: int, p: pk.Packet, now: float) -> None:
        by_port = self._out.get(node)
        info = by_port[port] \
            if by_port is not None and port < len(by_port) else None
        if info is None:
            info = self._link_info(node, port)
        self._send_via(info, p, now)

    def _send_via(self, info: list, p: pk.Packet, now: float) -> None:
        if info[7]:                         # fault plane: link is down —
            self.fault_dropped += 1         # black-hole, no feedback
            pk.release(p)
            return
        start = info[6]
        if start < now:
            start = now
        done = start + p.size / info[0]
        info[6] = done
        self.tx_bytes += p.size
        if done - now > self.ecn_backlog and p.kind == _DATA:
            p.ecn = True
        if info[5] and self.loss_rate > 0.0 and (
                p.kind == _DATA or self.drop_feedback):
            if self.rng.random() < self.loss_rate:
                self.dropped += 1
                pk.release(p)
                return
        heapq.heappush(self._q, (done + info[1], next(self._seq),
                                 info[2], info[3], info[4], p))

    def _arrive(self, node: str, in_port: int, p: pk.Packet,
                now: float) -> None:
        """Out-of-loop arrival dispatch (tests / direct injection).  The
        run loop inlines this, adding terminal-packet recycling."""
        sw = self.switches.get(node)
        if sw is not None:
            for out_port, q in sw.on_packet(p, in_port, now):
                self.send(node, out_port, q, now)
            return
        self.hosts[node].on_packet(p, now)

    # ------------------------------------------------------------ hosts

    def _run_host(self, host: Host, now: float) -> None:
        free = host._nic[6]
        if free > now + 1e-15:              # NIC serializing: come back
            self._arm_kick(host, free)
            return
        if not host.ctrl and not host._ready:
            return      # nothing to emit: exactly next_emission's no-op
        p, t_next = host.next_emission(now)
        if p is not None:
            nic = host._nic
            self._send_via(nic, p, now)
            if host.ctrl or host._ready:
                self._arm_kick(host, nic[6])
            # else: nothing left to emit — every source of new work
            # (arrival, submit, timeout) kicks the host itself, so the
            # serialization-done wakeup would fire into a guaranteed
            # no-op; skip the event instead of scheduling it
        elif t_next < INF:
            self._arm_kick(host, t_next)

    # Kicks run the host NIC emission loop now (packet arrival, submit).
    # They do NOT touch the wakeup marker — only _fire consumes it — so
    # repeated kicks while the NIC is serializing dedupe to a single
    # scheduled wakeup instead of multiplying events.
    kick = _run_host

    def _arm_kick(self, host: Host, t: float) -> None:
        if host._kick_t <= t + 1e-15:
            return                          # earlier wakeup already armed
        host._kick_t = t
        heapq.heappush(self._q, (t, next(self._seq), EV_HOST, host))

    def _fire(self, host: Host, now: float) -> None:
        if host._kick_t < now - 1e-15:
            return                          # superseded by an earlier fire
        host._kick_t = INF                  # consume the marker
        self._run_host(host, now)

    # ------------------------------------------------------------ timers

    def arm_timer(self, qp: QP, host: Host) -> None:
        t = qp.timer_deadline
        if t == INF:
            return
        if qp._timer_ev <= t + 1e-15:
            return
        qp._timer_ev = t
        heapq.heappush(self._q, (t, next(self._seq), EV_TIMER, qp, host))

    def _timer_fire(self, qp: QP, host: Host, now: float) -> None:
        qp._timer_ev = INF
        if qp.timer_deadline <= now + 1e-12:
            qp.on_timeout(now)
            self.kick(host, now)
        self.arm_timer(qp, host)

    # ------------------------------------------------------- convenience

    def host_of_ip(self, ip: int) -> Host:
        return self.by_ip[ip]

    def send_control(self, host: Host, p: pk.Packet, now: float) -> None:
        host.ctrl.append(p)
        self.kick(host, now)
