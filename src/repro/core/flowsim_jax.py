"""Vectorized JAX flow-level backend — the scale path of the SimEngine.

Same fluid model as ``flowsim.FlowSim`` (max-min fair shares over the
link-flow incidence; a Gleam multicast tree is ONE flow across the union
of its tree links), but the whole simulation is dense-array loops:

- **inner loop**: progressive-filling max-min fair allocation, one
  *fused round* per iteration (``kernels/maxmin.py`` — a Pallas kernel
  on TPU, its pure-jnp reference on CPU).  Each round scatter-adds the
  unfrozen flows onto their links, computes every link's fair share,
  gathers each flow's tightest share, and freezes the bottleneck group.
  Terminates in at most F rounds (whole bottleneck groups freeze
  together, so in practice a handful).
- **outer loop** (``_simulate``): classic fluid event loop — advance
  time to the next flow completion at the current rates, zero finished
  flows, re-allocate.  Epochs whose completions are link-disjoint from
  every surviving flow *warm-start*: the previous rate vector is reused
  and the filling is skipped entirely (max-min allocations decompose
  over connected components of the flow-link interference graph).

Flows are stored as an (F, H) matrix of link ids padded with a sentinel
link of infinite capacity (H = longest link list in the batch), NOT a
dense (F, L) incidence: a 16k-host fat-tree has ~50k directed links and
fig14's unicast baseline meshes stage ~32k flows, so the dense form
would need gigabytes while the padded form stays at a few MB.

**Shape bucketing**: F and H are padded up to power-of-two buckets
(``_bucket``) before the jit boundary, so nearby problem sizes share
one compiled executable — a fig14 sweep or a fig12/13 message-size
ladder compiles once, not once per point.  ``solve_many`` goes further:
independent epochs are padded to a common bucket, stacked, and solved
by ONE ``jax.vmap``-ed executable (the batched path behind
``SimEngine.run_many``); a byte-budget planner (``_plan_batches``)
splits shape-incompatible epochs so a 32k-flow unicast mesh is never
padded to a multicast tree's hop count.

**Precision**: volumes and capacities solve in float32 until the
largest staged volume exceeds the float32 safe-integer range (2^24
bytes ~ 16MB); beyond that (the multi-GB fig12/13 replication regime)
the solve auto-promotes to float64 under ``jax.experimental.enable_x64``
so completion times keep full precision.  ``solve_dtype`` records the
choice.

The module degrades gracefully: ``HAS_JAX`` is False when JAX is not
importable and ``core.engine`` silently falls back to the numpy solver.
Flows, link ids, and routing come from ``flowsim.LinkMap`` so the two
flow backends are numerically interchangeable (tested to 0.1%).
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading
import time
from typing import List, Sequence

import numpy as np

from repro.core.fattree import Topology
from repro.core.flowsim import Flow, LinkMap

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAS_JAX = True
except Exception:                               # pragma: no cover - gated
    HAS_JAX = False

#: volumes above this lose integer precision in float32 (2^24 bytes)
F32_SAFE_MAX = float(1 << 24)

#: padded-batch budget for ``_plan_batches`` (int32 link-id bytes)
MAX_BATCH_BYTES = 64 << 20

#: split a batch when the padded per-round work exceeds this multiple
#: of the epochs' individual work (e.g. a 2048-flow unicast mesh padded
#: next to a 64-flow multicast epoch would cost ~50x per round)
MAX_PAD_WASTE = 4.0

#: device-time telemetry, accumulated by every solve; ``tools/bench.py``
#: reads it to split python staging from on-device solver time
SOLVE_STATS = {"solve_s": 0.0, "calls": 0, "shapes": []}
_STATS_LOCK = threading.Lock()

#: dynamic-segment solves mirror the numpy ``flowsim.static_maxmin``
#: filling: float64, the same relative freeze slack, the same 64-round
#: cap — so the batched fairness snapshots match the per-segment
#: oracle to <= 1e-6 (reduction-order rounding only)
SEG_TOL = 1e-12
SEG_ROUNDS = 64


def reset_solve_stats():
    SOLVE_STATS.update(solve_s=0.0, calls=0, shapes=[])


_CACHE_READY = False


def _enable_persistent_cache():
    """Point XLA's persistent compilation cache at a local directory
    (once per process) so repeat sweeps skip compilation entirely.

    Honors an existing ``JAX_COMPILATION_CACHE_DIR``/config setting;
    ``REPRO_JAX_CACHE=0`` opts out.  Best-effort: any failure (read-only
    home, old jax) silently falls back to in-memory-only caching.
    """
    global _CACHE_READY
    if _CACHE_READY or os.environ.get("REPRO_JAX_CACHE", "1") == "0":
        return
    _CACHE_READY = True
    try:                                        # pragma: no cover - env
        if jax.config.jax_compilation_cache_dir is None:
            jax.config.update(
                "jax_compilation_cache_dir",
                os.path.expanduser("~/.cache/repro-jax"))
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          0.1)
    except Exception:
        pass


def _bucket(n: int, lo: int) -> int:
    """Smallest power of two >= max(n, lo) — the jit-cache shape key."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


if HAS_JAX:

    def _maxmin_rates(flow_links, cap, active, mode):
        """Max-min fair rates for the active flows (progressive filling
        over the fused round of ``kernels/maxmin.py``)."""
        from repro.kernels.maxmin import maxmin_rates
        return maxmin_rates(flow_links, cap, active, mode=mode)

    def _simulate(flow_links, cap, vol, loss=None, mode="auto", warm=True):
        """Fluid event loop: completion times (F,) for every flow.

        ``warm`` compiles in the completion-epoch warm start: when an
        epoch's completed flows are link-disjoint from every survivor,
        the previous rate vector is reused and the filling skipped.
        The batched (vmapped) solver sets ``warm=False``: under vmap
        ``lax.cond`` lowers to a select that executes both branches, so
        the skip can never fire and the dirty tracking would be pure
        per-epoch overhead.

        ``loss`` (a ``(q, wsq, wnd, ecn)`` tuple of (F,) arrays, or
        None) compiles in the expected-value loss/DCQCN correction: the
        solved max-min rates are scaled by ``kernels/maxmin.py``'s
        fused ``loss_factors`` each epoch.  The loop state carries the
        RAW max-min rates (so the warm start stays valid and factors
        are never applied twice); only ``dt`` and the drained bytes use
        the effective rates.  ``loss=None`` traces the exact lossless
        graph — zero-loss results are bit-identical.
        """
        n_flows = flow_links.shape[0]
        n_caps = cap.shape[0]
        eps = vol * 1e-6 + 1.0                  # completion slack (bytes)
        if loss is not None:
            from repro.core.flowsim import DCQCN_MIN_RATE, DCQCN_RATE_NUM
            from repro.kernels.maxmin import loss_factors
            q, wsq, wnd, ecn = loss

        def cond(st):
            _, rem, _, _, _, it = st
            return jnp.logical_and(jnp.any(rem > 0.0), it <= n_flows)

        def body(st):
            t, rem, done, rates, dirty, it = st
            active = rem > 0.0
            if warm:
                rates = lax.cond(
                    dirty,
                    lambda r: _maxmin_rates(flow_links, cap, active,
                                            mode),
                    lambda r: r, rates)
            else:
                rates = _maxmin_rates(flow_links, cap, active, mode)
            eff = rates
            if loss is not None:
                eff = rates * loss_factors(
                    flow_links, rates, active.astype(cap.dtype), cap,
                    q, wsq, wnd, ecn, dcqcn_num=DCQCN_RATE_NUM,
                    dcqcn_min=DCQCN_MIN_RATE, mode=mode)
            dt = jnp.min(jnp.where(active, rem / eff, jnp.inf))
            t = t + dt
            rem = jnp.where(active, rem - eff * dt, 0.0)
            fin = active & (rem <= eps)
            done = jnp.where(fin, t, done)
            rem = jnp.where(fin, 0.0, rem)
            if warm:
                touched = jnp.zeros(n_caps, cap.dtype).at[flow_links].add(
                    jnp.broadcast_to(fin.astype(cap.dtype)[:, None],
                                     flow_links.shape))
                touched = touched.at[-1].set(0.0)   # sentinel: no contention
                survive = active & ~fin
                dirty = jnp.any(
                    survive & (jnp.max(touched[flow_links], axis=1) > 0.0))
            return t, rem, done, rates, dirty, it + 1

        zero = jnp.asarray(0.0, cap.dtype)
        init = (zero, vol, jnp.zeros(n_flows, cap.dtype),
                jnp.zeros(n_flows, cap.dtype), jnp.bool_(True),
                jnp.int32(0))
        _, _, done, _, _, _ = lax.while_loop(cond, body, init)
        return done

    def _solver(batched: bool, mode: str = "auto", lossy: bool = False):
        """Jitted solver, one per (batched, kernel-mode, lossy) flavor.

        ``mode`` is the resolved ``kernels/maxmin.py`` dispatch (part
        of the jit cache key, so a ``REPRO_MAXMIN`` change takes effect
        immediately instead of hitting a stale executable).  ``lossy``
        selects the flavor that threads the per-flow loss arrays —
        lossless solves keep their exact pre-existing executable.
        """
        # normalize BEFORE the lru_cache: positional and defaulted
        # calls must land on the same memoized jit object (the
        # cache-hit tests introspect it via the two-arg form)
        return _solver_impl(bool(batched), mode, bool(lossy))

    @functools.lru_cache(maxsize=None)
    def _seg_solver(mode: str):
        """Jitted, vmapped dynamic-segment solver, one per kernel mode.

        One lane = one fairness-snapshot problem: a padded (F, H)
        link-id matrix, its active-row mask, and the index of the OWN
        flow.  The lane solves max-min rates under the numpy-matched
        ``SEG_TOL``/``SEG_ROUNDS`` regime, applies the fused loss/DCQCN
        factors (all-zero loss rows give factor exactly 1.0, so one
        always-lossy executable covers lossless problems bit-exactly),
        and returns the own flow's corrected rate.
        """
        from repro.core.flowsim import DCQCN_MIN_RATE, DCQCN_RATE_NUM
        from repro.kernels.maxmin import loss_factors, maxmin_rates

        def one(fl, active, own, cap, loss):
            rates = maxmin_rates(fl, cap, active, mode=mode, tol=SEG_TOL,
                                 max_rounds=SEG_ROUNDS)
            fac = loss_factors(fl, rates, active, cap, *loss,
                               dcqcn_num=DCQCN_RATE_NUM,
                               dcqcn_min=DCQCN_MIN_RATE, mode=mode)
            return rates[own] * fac[own]

        return jax.jit(jax.vmap(one, in_axes=(0, 0, 0, None,
                                              (0, 0, 0, 0))))

    @functools.lru_cache(maxsize=None)
    def _solver_impl(batched: bool, mode: str, lossy: bool):
        """``donate_argnums`` hands the volume buffer back to XLA (a
        no-op on backends without donation support, e.g. CPU)."""
        sim = functools.partial(_simulate, mode=mode, warm=not batched)
        if batched:
            fn = jax.vmap(sim, in_axes=(0, None, 0, 0) if lossy
                          else (0, None, 0))
        else:
            fn = sim
        donate = (2,) if jax.default_backend() not in ("cpu",) else ()
        return jax.jit(fn, donate_argnums=donate)


class JaxFlowSim(LinkMap):
    """Drop-in for ``flowsim.FlowSim`` backed by the jitted solver.

    ``add()`` stages flows; ``run()`` builds the padded link-id matrix
    once (bucketed — see module docstring) and solves every completion
    epoch on-device; ``solve_many()`` solves a list of INDEPENDENT flow
    batches in one vmapped executable.  Requires ``HAS_JAX``.
    """

    #: class-level toggle so benchmarks can measure the unbucketed
    #: (PR-1 style, jit-per-exact-shape) solver against the same code
    bucketing = True
    F_BUCKET_MIN = 16
    H_BUCKET_MIN = 8

    def __init__(self, topo: Topology, shared_cache: bool = True):
        if not HAS_JAX:
            raise RuntimeError("JaxFlowSim needs jax; use flowsim.FlowSim")
        super().__init__(topo, shared_cache)
        _enable_persistent_cache()
        self.flows: List[Flow] = []
        self.now = 0.0
        self.solve_dtype = None          # dtype of the last solve

    def add(self, links, volume, tag=None, loss=None) -> Flow:
        links = tuple(links)
        assert links, "a flow must traverse at least one link"
        f = Flow(links, float(volume), tag=tag, loss=loss)
        self.flows.append(f)
        return f

    # --------------------------------------------------------- solver glue

    def _select_dtype(self, flows: Sequence[Flow]):
        """float32 until volumes outgrow its integer precision."""
        vmax = max((f.volume for f in flows), default=0.0)
        return np.float64 if vmax > F32_SAFE_MAX else np.float32

    def _pack(self, flows: Sequence[Flow], dtype, f_pad: int, h_pad: int):
        """(f_pad, h_pad) link-id matrix + (f_pad,) volumes; padding
        rows/columns point at the infinite-capacity sentinel link."""
        sentinel = len(self.cap)
        n = len(flows)
        fl = np.full((f_pad, h_pad), sentinel, np.int32)
        vol = np.zeros(f_pad, dtype)
        if n:
            # one flat scatter instead of a per-flow Python row loop —
            # packing a 32k-flow unicast mesh is staging-path work
            lens = np.fromiter((len(f.links) for f in flows), np.int64, n)
            total = int(lens.sum())
            flat = np.fromiter((l for f in flows for l in f.links),
                               np.int32, total)
            rows = np.repeat(np.arange(n), lens)
            cols = np.arange(total) - np.repeat(np.cumsum(lens) - lens,
                                                lens)
            fl[rows, cols] = flat
            vol[:n] = np.fromiter((f.volume for f in flows), np.float64, n)
        return fl, vol

    def _shape(self, flows: Sequence[Flow]):
        n = len(flows)
        h = max(len(f.links) for f in flows)
        if self.bucketing:
            return _bucket(n, self.F_BUCKET_MIN), \
                _bucket(h, self.H_BUCKET_MIN)
        return n, h

    def _pack_loss(self, flows: Sequence[Flow], dtype, f_pad: int):
        """(q, wsq, wnd, ecn) per-flow loss-model rows, each (f_pad,).

        All-zero rows — padding and lossless flows — solve at factor
        exactly 1, so mixing lossy and lossless flows in one epoch is
        fine.
        """
        arrs = np.zeros((4, f_pad), dtype)
        lossy = [(i, f.loss) for i, f in enumerate(flows)
                 if f.loss is not None]
        if lossy:
            ii = np.fromiter((i for i, _ in lossy), np.int64, len(lossy))
            arrs[0, ii] = [lp.q for _, lp in lossy]
            arrs[1, ii] = [lp.wsq for _, lp in lossy]
            arrs[2, ii] = [lp.wnd for _, lp in lossy]
            arrs[3, ii] = [1.0 if lp.ecn else 0.0 for _, lp in lossy]
        return tuple(arrs)

    def _cap_ext(self, dtype):
        return np.append(self.cap, np.inf).astype(dtype)

    def _dispatch(self, batched: bool, fl, cap, vol, dtype,
                  loss=None) -> np.ndarray:
        """Run the jitted solver (under x64 when promoted), timed.

        The ``jnp.asarray`` conversions MUST happen inside the x64
        scope: without it enabled, float64 inputs silently downcast to
        float32 and the promotion is lost.
        """
        from repro.kernels.maxmin import _resolve_mode
        solve = _solver(batched, _resolve_mode(), loss is not None)
        ctx = enable_x64() if dtype == np.float64 \
            else contextlib.nullcontext()
        t0 = time.perf_counter()
        with ctx:
            args = [jnp.asarray(fl), jnp.asarray(cap), jnp.asarray(vol)]
            if loss is not None:
                args.append(tuple(jnp.asarray(a) for a in loss))
            done = np.asarray(solve(*args))
        with _STATS_LOCK:
            SOLVE_STATS["solve_s"] += time.perf_counter() - t0
            SOLVE_STATS["calls"] += 1
            SOLVE_STATS["shapes"].append(tuple(fl.shape))
        return done

    def _finish(self, flows: Sequence[Flow], done: np.ndarray) -> float:
        """Back-fill completion bookkeeping WITHOUT touching volumes.

        A flow's expected RTO stall (``LossParams.tail``) lands here:
        it delays the completion timestamp without occupying fabric
        time in the solve (the bandwidth is free during the stall).
        """
        n = len(flows)
        # one float64 conversion + tolist() instead of a per-flow
        # float() call; the loss-tail add stays scalar per lossy flow
        # so the float addition order matches the original exactly
        dts = np.asarray(done[:n], np.float64).tolist()
        end = 0.0
        for f, d in zip(flows, dts):
            if f.loss is not None:
                d += f.loss.tail
            f.done_t = d
            f.remaining = 0.0
            if d > end:
                end = d
        return end

    def run(self) -> float:
        if not self.flows:
            return self.now
        flows = self.flows
        dtype = self._select_dtype(flows)
        self.solve_dtype = dtype
        f_pad, h_pad = self._shape(flows)
        fl, vol = self._pack(flows, dtype, f_pad, h_pad)
        loss = self._pack_loss(flows, dtype, f_pad) \
            if any(f.loss is not None for f in flows) else None
        done = self._dispatch(False, fl, self._cap_ext(dtype), vol, dtype,
                              loss)
        self.now = self._finish(flows, done)
        return self.now

    # ------------------------------------------------------- batched solve

    def _plan_batches(self, epochs, indices, shapes=None):
        """Group epoch ``indices`` into padded stacks.

        Two constraints per batch: stay under ``MAX_BATCH_BYTES``, and
        keep the padded per-round work within ``MAX_PAD_WASTE`` of the
        epochs' individual (F_bucket * H_bucket) work — so a 32k-flow
        unicast mesh (H ~ 8) is never padded to a multicast epoch's hop
        count (H ~ hundreds) or vice versa.  Epochs are sorted by H
        bucket first, which makes shape-compatible epochs adjacent."""
        if shapes is None:
            shapes = {i: self._shape(epochs[i]) for i in indices}
        shaped = sorted(indices, key=lambda i: shapes[i][::-1])
        batches, cur = [], []
        f_max = h_max = own = 0
        for i in shaped:
            f, h = shapes[i]
            nf, nh = max(f_max, f), max(h_max, h)
            ne = len(cur) + 1
            if cur and (ne * nf * nh * 4 > MAX_BATCH_BYTES
                        or ne * nf * nh > MAX_PAD_WASTE * (own + f * h)):
                batches.append(cur)
                cur, nf, nh, own = [], f, h, 0
            cur.append(i)
            f_max, h_max, own = nf, nh, own + f * h
        if cur:
            batches.append(cur)
        return batches

    def solve_many(self, epochs: Sequence[Sequence[Flow]]):
        """Solve INDEPENDENT flow batches (epochs) in one vmapped call.

        Every epoch is an isolated fabric: flows in different epochs do
        not share bandwidth, and every epoch's clock starts at 0.  All
        epochs in a batch are padded to a common (F, H) bucket and the
        batched solver runs once per batch.  Returns the per-epoch
        completion time; per-flow ``done_t`` is filled in as by
        ``run()``.
        """
        epochs = [list(ep) for ep in epochs]
        out = [0.0] * len(epochs)
        nonempty = [i for i, ep in enumerate(epochs) if ep]
        if not nonempty:
            return out
        vmax = max(max(f.volume for f in epochs[i]) for i in nonempty)
        dtype = np.float64 if vmax > F32_SAFE_MAX else np.float32
        self.solve_dtype = dtype
        cap = self._cap_ext(dtype)
        shapes = {i: self._shape(epochs[i]) for i in nonempty}
        batches = self._plan_batches(epochs, nonempty, shapes)

        def solve_batch(batch):
            f_pad = h_pad = 0
            for i in batch:
                f, h = shapes[i]
                f_pad, h_pad = max(f_pad, f), max(h_pad, h)
            packed = [self._pack(epochs[i], dtype, f_pad, h_pad)
                      for i in batch]
            fl = np.stack([p[0] for p in packed])
            vol = np.stack([p[1] for p in packed])
            loss = None
            if any(f.loss is not None for i in batch for f in epochs[i]):
                rows = [self._pack_loss(epochs[i], dtype, f_pad)
                        for i in batch]
                loss = tuple(np.stack([r[k] for r in rows])
                             for k in range(4))
            return self._dispatch(True, fl, cap, vol, dtype, loss)

        # batches solve sequentially: concurrent XLA compiles thrash on
        # small hosts (XLA's own compile parallelism saturates the
        # cores), and the persistent compilation cache already removes
        # repeat-compile cost
        dones = [solve_batch(b) for b in batches]
        for batch, done in zip(batches, dones):
            for row, i in enumerate(batch):
                out[i] = self._finish(epochs[i], done[row])
        self.now = max([self.now] + out)
        return out

    # --------------------------------------------- dynamic-segment solve

    def segment_rates_many(self, problems) -> List[float]:
        """Batched device override of ``LinkMap.segment_rates_many``.

        Same contract as the numpy fallback (one ``(link_sets, loss)``
        problem per dynamic segment, OWN flow last; returns the own
        flow's loss-corrected rate), but every problem becomes one vmap
        lane: problems are bucketed by padded (F, H) shape through the
        same ``_plan_batches`` planner as the epoch solver and solved
        in one jitted call per batch, in float64 under the
        ``SEG_TOL``/``SEG_ROUNDS`` regime that mirrors the numpy
        oracle's filling (matches it to <= 1e-6 relative — only
        reduction-order rounding differs).
        """
        out = [0.0] * len(problems)
        if not problems:
            return out
        from repro.kernels.maxmin import _resolve_mode
        dtype = np.float64
        self.solve_dtype = dtype
        cap = self._cap_ext(dtype)
        sentinel = len(self.cap)
        shapes = {}
        for i, (sets, _) in enumerate(problems):
            f, h = len(sets), max(len(ls) for ls in sets)
            shapes[i] = (_bucket(f, self.F_BUCKET_MIN),
                         _bucket(h, self.H_BUCKET_MIN)) \
                if self.bucketing else (f, h)
        batches = self._plan_batches(problems, list(range(len(problems))),
                                     shapes)
        solve = _seg_solver(_resolve_mode())
        for batch in batches:
            f_pad = max(shapes[i][0] for i in batch)
            h_pad = max(shapes[i][1] for i in batch)
            nb = len(batch)
            fl = np.full((nb, f_pad, h_pad), sentinel, np.int32)
            act = np.zeros((nb, f_pad), dtype)
            own = np.zeros(nb, np.int32)
            lrows = np.zeros((nb, 4, f_pad), dtype)
            for r, i in enumerate(batch):
                sets, lp = problems[i]
                n = len(sets)
                lens = np.fromiter((len(ls) for ls in sets), np.int64, n)
                total = int(lens.sum())
                flat = np.fromiter((l for ls in sets for l in ls),
                                   np.int32, total)
                rows = np.repeat(np.arange(n), lens)
                cols = np.arange(total) - np.repeat(
                    np.cumsum(lens) - lens, lens)
                fl[r, rows, cols] = flat
                act[r, :n] = 1.0
                own[r] = n - 1
                if lp is not None:
                    lrows[r, :, n - 1] = (lp.q, lp.wsq, lp.wnd,
                                          1.0 if lp.ecn else 0.0)
            t0 = time.perf_counter()
            with enable_x64():
                vals = np.asarray(solve(
                    jnp.asarray(fl), jnp.asarray(act), jnp.asarray(own),
                    jnp.asarray(cap),
                    tuple(jnp.asarray(lrows[:, k]) for k in range(4))))
            with _STATS_LOCK:
                SOLVE_STATS["solve_s"] += time.perf_counter() - t0
                SOLVE_STATS["calls"] += 1
                SOLVE_STATS["shapes"].append(tuple(fl.shape))
            for r, i in enumerate(batch):
                out[i] = float(vals[r])
        return out
