"""Vectorized JAX flow-level backend — the scale path of the SimEngine.

Same fluid model as ``flowsim.FlowSim`` (max-min fair shares over the
link-flow incidence; a Gleam multicast tree is ONE flow across the union
of its tree links), but the whole simulation is two nested
``lax.while_loop``s over dense arrays:

- **inner loop** (``_maxmin_rates``): progressive-filling max-min fair
  allocation.  Each round scatter-adds the unfrozen flows onto their
  links to get per-link demand, computes every link's fair share
  ``cap_remaining / n_unfrozen_flows`` in one shot, takes each flow's
  tightest share with a ``jax.vmap``-ed gather over its link list,
  freezes the flows that hit the global bottleneck, and subtracts their
  bandwidth.  Terminates in at most F rounds (>= 1 flow freezes per
  round; in practice a handful — whole bottleneck groups freeze
  together).
- **outer loop** (``_simulate``): classic fluid event loop — advance time
  to the next flow completion at the current rates, zero finished flows,
  re-allocate.  At most F epochs; symmetric workloads complete in waves.

Flows are stored as an (F, H) matrix of link ids padded with a sentinel
link of infinite capacity (H = longest link list in the batch), NOT a
dense (F, L) incidence: a 16k-host fat-tree has ~50k directed links and
fig14's unicast baseline meshes stage ~32k flows, so the dense form
would need gigabytes while the padded form stays at a few MB.

Everything is jit-compiled per (F, H, L) shape, so a 1024-host fat-tree
sweep with hundreds of concurrent multicast epochs runs in seconds where
the pure-Python event loop needs minutes to hours.

The module degrades gracefully: ``HAS_JAX`` is False when JAX is not
importable and ``core.engine`` silently falls back to the numpy solver.
Flows, link ids, and routing come from ``flowsim.LinkMap`` so the two
flow backends are numerically interchangeable (tested to 0.1%).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.fattree import Topology
from repro.core.flowsim import Flow, LinkMap

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    HAS_JAX = True
except Exception:                               # pragma: no cover - gated
    HAS_JAX = False


if HAS_JAX:

    def _maxmin_rates(flow_links, cap, active):
        """Max-min fair rates for the active flows (progressive filling).

        flow_links: (F, H) int32 link ids, padded with the sentinel
        (last) index of ``cap``; cap: (L+1,) bytes/s with cap[-1] = inf;
        active: (F,) bool.  Returns (F,) rates; inactive flows get ~0.
        """
        n_flows = flow_links.shape[0]
        n_caps = cap.shape[0]

        def cond(st):
            _, frozen, _, it = st
            return jnp.logical_and(jnp.any(~frozen), it <= n_flows)

        def body(st):
            rates, frozen, cap_rem, it = st
            live = (~frozen).astype(cap.dtype)
            # per-link demand: scatter each live flow onto its links
            cnt = jnp.zeros(n_caps, cap.dtype).at[flow_links].add(
                jnp.broadcast_to(live[:, None], flow_links.shape))
            share = jnp.where(cnt > 0.0,
                              cap_rem / jnp.maximum(cnt, 1.0), jnp.inf)
            # each flow's tightest link share (sentinel gathers inf)
            tightest = jax.vmap(lambda ls: jnp.min(share[ls]))(flow_links)
            limit = jnp.where(frozen, jnp.inf, tightest)
            b = jnp.min(limit)
            newly = (~frozen) & (limit <= b * (1.0 + 1e-6))
            rates = jnp.where(newly, b, rates)
            used = jnp.zeros(n_caps, cap.dtype).at[flow_links].add(
                jnp.broadcast_to((newly.astype(cap.dtype) * b)[:, None],
                                 flow_links.shape))
            cap_rem = jnp.maximum(cap_rem - used, 0.0)
            return rates, frozen | newly, cap_rem, it + 1

        init = (jnp.zeros(n_flows, cap.dtype), ~active, cap, jnp.int32(0))
        rates, _, _, _ = lax.while_loop(cond, body, init)
        return jnp.maximum(rates, 1e-9)

    def _simulate(flow_links, cap, vol):
        """Fluid event loop: completion times (F,) for every flow."""
        n_flows = flow_links.shape[0]
        eps = vol * 1e-6 + 1.0                  # completion slack (bytes)

        def cond(st):
            _, rem, _, it = st
            return jnp.logical_and(jnp.any(rem > 0.0), it <= n_flows)

        def body(st):
            t, rem, done, it = st
            active = rem > 0.0
            rates = _maxmin_rates(flow_links, cap, active)
            dt = jnp.min(jnp.where(active, rem / rates, jnp.inf))
            t = t + dt
            rem = jnp.where(active, rem - rates * dt, 0.0)
            fin = active & (rem <= eps)
            done = jnp.where(fin, t, done)
            rem = jnp.where(fin, 0.0, rem)
            return t, rem, done, it + 1

        init = (jnp.zeros((), cap.dtype), vol,
                jnp.zeros(n_flows, cap.dtype), jnp.int32(0))
        _, _, done, _ = lax.while_loop(cond, body, init)
        return done

    _simulate_jit = jax.jit(_simulate)


class JaxFlowSim(LinkMap):
    """Drop-in for ``flowsim.FlowSim`` backed by the jitted solver.

    ``add()`` stages flows; ``run()`` builds the padded link-id matrix
    once and solves every completion epoch on-device.  Requires
    ``HAS_JAX``.
    """

    def __init__(self, topo: Topology):
        if not HAS_JAX:
            raise RuntimeError("JaxFlowSim needs jax; use flowsim.FlowSim")
        super().__init__(topo)
        self.flows: List[Flow] = []
        self.now = 0.0

    def add(self, links, volume, tag=None) -> Flow:
        links = tuple(links)
        assert links, "a flow must traverse at least one link"
        f = Flow(links, float(volume), tag=tag)
        self.flows.append(f)
        return f

    def run(self) -> float:
        if not self.flows:
            return self.now
        n_flows = len(self.flows)
        sentinel = len(self.cap)                # extra link, infinite cap
        max_hops = max(len(f.links) for f in self.flows)
        fl = np.full((n_flows, max_hops), sentinel, np.int32)
        for i, f in enumerate(self.flows):
            fl[i, :len(f.links)] = f.links
        cap = np.append(self.cap, np.inf).astype(np.float32)
        vol = np.asarray([f.volume for f in self.flows], np.float32)
        done = np.asarray(_simulate_jit(jnp.asarray(fl), jnp.asarray(cap),
                                        jnp.asarray(vol)))
        for f, d in zip(self.flows, done):
            f.done_t = float(d)
            f.volume = 0.0
        self.now = float(done.max())
        return self.now
